"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container this suite runs in bakes only the jax toolchain, so property
tests guard their ``hypothesis`` import and fall back to this module.  It
implements just the surface the suite uses — ``given``, ``settings`` and the
``integers`` / ``floats`` / ``lists`` / ``sampled_from`` strategies — and
replaces shrinking search with a fixed number of deterministic pseudo-random
examples (seeded per test name, so failures reproduce run to run).
"""
from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def sample(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(size)]
        return _Strategy(sample)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])


st = strategies


def settings(*, max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Decorator: records ``max_examples`` on the ``given`` wrapper below."""
    def apply(fn):
        fn._max_examples = max_examples
        return fn
    return apply


def given(*strats: _Strategy):
    """Run the test body over deterministic samples of each strategy."""
    def decorate(fn):
        # NB: deliberately not functools.wraps — copying __wrapped__ would
        # make pytest introspect the original signature and treat the drawn
        # arguments as fixtures.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = [s.sample(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 — re-raise with example
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example #{i}: "
                        f"{drawn!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return decorate
