"""Per-architecture smoke tests (harness deliverable f).

For every assigned arch: instantiate the REDUCED same-family config, run one
forward + one train-grad step + prefill/decode on CPU; assert output shapes
and the absence of NaNs.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model, decode_step, init_cache, prefill

B, S = 2, 32


def _batch(cfg, key, seq=S, with_labels=True):
    kt, kf, ki = jax.random.split(key, 3)
    s = seq + (1 if with_labels else 0)
    batch = {"tokens": jax.random.randint(kt, (B, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, seq, cfg.d_model),
                                            jnp.float32).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ki, (B, cfg.num_image_tokens, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1), with_labels=False)
        logits = model.forward(params, batch, kv_chunk=16)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()

    def test_train_step_grads_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        batch = _batch(cfg, jax.random.PRNGKey(3))

        loss, grads = jax.value_and_grad(
            lambda p: model.train_loss(p, batch, kv_chunk=16))(params)
        assert np.isfinite(float(loss))
        flat = jax.tree_util.tree_leaves(grads)
        assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
        # at least one grad must be non-zero (the graph is connected)
        assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
                   for g in flat)

    def test_prefill_then_decode(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(4))
        batch = _batch(cfg, jax.random.PRNGKey(5), with_labels=False)
        max_len = S + 4

        logits_p, cache = prefill(model, params, batch, max_len=max_len,
                                  kv_chunk=16)
        assert logits_p.shape == (B, S, cfg.vocab_size)
        assert int(cache["len"]) == S

        tok = jnp.argmax(logits_p[:, -1:, :], axis=-1).astype(jnp.int32)
        logits_d, cache = decode_step(model, params, cache, tok)
        assert logits_d.shape == (B, 1, cfg.vocab_size)
        assert int(cache["len"]) == S + 1
        assert np.isfinite(np.asarray(logits_d, np.float32)).all()


@pytest.mark.parametrize("arch", ["starcoder2-15b", "mamba2-780m",
                                  "zamba2-2.7b", "minicpm3-4b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match the full-sequence forward logits —
    the cache path computes the same function as the parallel path."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    batch = _batch(cfg, jax.random.PRNGKey(7), seq=16, with_labels=False)

    full = model.forward(params, batch, kv_chunk=16)        # [B, 16, V]

    pre = {**batch, "tokens": batch["tokens"][:, :15]}
    if cfg.family == "encdec":
        pre["frames"] = batch["frames"]
    _, cache = prefill(model, params, pre, max_len=16, kv_chunk=16)
    logits_d, _ = decode_step(model, params, cache,
                              batch["tokens"][:, 15:16])
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full[:, 15], np.float32), rtol=0.15, atol=0.15)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    spec = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "mamba2-780m": (48, 1536, 24, 24, 0, 50280),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == l, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # family extras
    assert get_config("olmoe-1b-7b").num_experts == 64
    assert get_config("olmoe-1b-7b").experts_per_token == 8
    assert get_config("qwen2-moe-a2.7b").num_experts == 60
    assert get_config("qwen2-moe-a2.7b").experts_per_token == 4
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("mamba2-780m").ssm_state == 128
    assert get_config("minicpm3-4b").use_mla
    assert get_config("whisper-large-v3").num_encoder_layers == 32
    assert get_config("llama-3.2-vision-90b").cross_attn_every == 5
