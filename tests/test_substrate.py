"""Substrate tests: optimizer, checkpoint (+elastic restore), fault
tolerance, gradient compression, data pipeline, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.distributed.compression import (compression_ratio,
                                           dequantize_int8, ef_allreduce_tree,
                                           init_error_tree, quantize_int8)
from repro.models import Model
from repro.train import (AdamW, WatchdogPolicy, constant_lr, latest_step,
                         plan_remesh, prune_checkpoints, restore_checkpoint,
                         run_with_recovery, save_checkpoint, warmup_cosine)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        opt = AdamW(lr=constant_lr(0.1), weight_decay=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        opt = AdamW(lr=constant_lr(0.1), clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        _, _, stats = opt.update({"w": jnp.full(3, 1e6)}, state, params)
        assert float(stats["grad_norm"]) > 1e5   # reported pre-clip

    def test_warmup_cosine_shape(self):
        sched = warmup_cosine(1e-3, warmup=10, total=100)
        lrs = [float(sched(jnp.int32(s))) for s in range(100)]
        assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
        assert lrs[99] < lrs[50] < lrs[12]

    def test_moments_match_param_tree(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=constant_lr(1e-3))
        state = opt.init(params)
        assert (jax.tree_util.tree_structure(state.m)
                == jax.tree_util.tree_structure(params))


class TestCheckpoint:
    def _tree(self, key):
        k1, k2 = jax.random.split(key)
        return {"a": jax.random.normal(k1, (4, 8)),
                "nested": {"b": jax.random.normal(k2, (3,)),
                           "step": jnp.int32(7)}}

    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            tree = self._tree(jax.random.PRNGKey(0))
            save_checkpoint(d, 5, tree, extra={"note": "x"})
            restored, step, extra = restore_checkpoint(d, tree)
            assert step == 5 and extra["note"] == "x"
            for a, b in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_prune(self):
        with tempfile.TemporaryDirectory() as d:
            tree = self._tree(jax.random.PRNGKey(1))
            for s in (1, 2, 3, 4):
                save_checkpoint(d, s, tree)
            assert latest_step(d) == 4
            prune_checkpoints(d, keep=2)
            assert latest_step(d) == 4
            with pytest.raises(Exception):
                restore_checkpoint(d, tree, step=1)

    def test_atomicity_no_partial_dir_visible(self):
        with tempfile.TemporaryDirectory() as d:
            tree = self._tree(jax.random.PRNGKey(2))
            save_checkpoint(d, 9, tree)
            names = os.listdir(d)
            assert names == ["step_00000009"], names  # no .tmp left behind

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, {"a": jnp.zeros((2, 2))})
            with pytest.raises(ValueError):
                restore_checkpoint(d, {"a": jnp.zeros((3, 3))})


class TestFaultTolerance:
    def test_watchdog_flags_stragglers(self):
        w = WatchdogPolicy(warmup_steps=3, multiplier=2.0, min_deadline_s=0.0)
        for _ in range(10):
            w.record(1.0)
        assert not w.is_straggler(1.5)
        assert w.is_straggler(3.0)

    def test_plan_remesh(self):
        assert plan_remesh(256) == (16, 16)
        assert plan_remesh(255) == (15, 16)   # one dead chip drops a TP group
        assert plan_remesh(15) is None

    def test_recovery_restores_and_completes(self):
        calls = {"fails": 0}
        completed = []
        saved = {"step": 0}

        def step_fn(step):
            if step == 5 and calls["fails"] < 2:
                calls["fails"] += 1
                raise RuntimeError("simulated preemption")
            completed.append(step)
            return {}

        def save(step):
            saved["step"] = step

        def restore():
            return saved["step"]

        final = run_with_recovery(step_fn, start_step=0, num_steps=10,
                                  save_fn=save, restore_fn=restore,
                                  checkpoint_every=2, max_retries=3)
        assert final == 10
        assert calls["fails"] == 2
        assert 9 in completed

    def test_recovery_gives_up_after_max_retries(self):
        def step_fn(step):
            raise RuntimeError("hard failure")

        with pytest.raises(RuntimeError):
            run_with_recovery(step_fn, start_step=0, num_steps=3,
                              save_fn=lambda s: None,
                              restore_fn=lambda: 0, max_retries=2)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
        q, scale = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
        assert err.max() <= float(scale) / 2 + 1e-6

    def test_ratio(self):
        tree = {"w": jnp.zeros((128, 128))}
        assert compression_ratio(tree) < 0.26

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_quantize_idempotent_signs(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
        q, scale = quantize_int8(x)
        deq = np.asarray(dequantize_int8(q, scale))
        big = np.abs(np.asarray(x)) > float(scale)
        assert np.all(np.sign(deq[big]) == np.sign(np.asarray(x)[big]))

    def test_error_feedback_mean_preserved_over_steps(self):
        """EF accumulates: the *running sum* of compressed reductions tracks
        the running sum of true means (the EF-SGD guarantee)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        if jax.device_count() < 1:
            pytest.skip("no devices")
        mesh = make_mesh((1,), ("pod",))

        grads = {"w": jax.random.normal(jax.random.PRNGKey(3), (1, 64))}
        err = init_error_tree({"w": jnp.zeros((1, 64))})

        def f(g, e):
            return ef_allreduce_tree(g, e, "pod")

        fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), check_rep=False)
        total_reduced = jnp.zeros(64)
        for _ in range(10):
            red, err = fn(grads, err)
            total_reduced = total_reduced + red["w"][0]
        true_total = grads["w"][0] * 10
        # EF guarantee: cumulative error stays bounded by one quantisation step
        q, scale = quantize_int8(grads["w"][0])
        assert float(jnp.abs(total_reduced - true_total).max()) \
            <= float(scale) + 1e-5


class TestPipeline:
    def test_deterministic_and_resumable(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        pipe = TokenPipeline(cfg, 8, 16, seed=3)
        a = pipe.batch_at(7)
        b = pipe.batch_at(7)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = pipe.batch_at(8)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))

    def test_host_slice_consistent(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        pipe = TokenPipeline(cfg, 8, 16, seed=3)
        part = pipe.batch_at(5, lo=0, hi=4)
        assert part["tokens"].shape == (4, 17)

    def test_family_extras(self):
        for arch in ("whisper-large-v3", "llama-3.2-vision-90b"):
            cfg = get_config(arch, smoke=True)
            pipe = TokenPipeline(cfg, 2, 8)
            b = pipe.batch_at(0)
            if cfg.family == "encdec":
                assert b["frames"].shape == (2, 8, cfg.d_model)
            if cfg.family == "vlm":
                assert b["image_embeds"].shape == (
                    2, cfg.num_image_tokens, cfg.d_model)


class TestTrainDriver:
    def test_loss_decreases_and_resumes(self, tmp_path):
        # "periodic" token data has next-token-predictable structure, so
        # the loss trend is real learning rather than noise around the
        # entropy floor (the old "uniform" mode made this flaky: random
        # tokens have nothing to learn and the trend was a coin flip).
        # Seed 0 at lr=3e-3 / 24 steps drops ~0.7 nats on CPU.
        from repro.launch.train import train
        ckpt = str(tmp_path / "ck")
        _, losses = train("internlm2-1.8b", smoke=True, steps=24, batch=4,
                          seq=32, ckpt_dir=ckpt, checkpoint_every=12,
                          lr=3e-3, kv_chunk=32, seed=0,
                          data_mode="periodic")
        assert losses[-1] < losses[0]
        assert latest_step(ckpt) == 24
        # resume continues from the checkpoint
        _, losses2 = train("internlm2-1.8b", smoke=True, steps=4, batch=4,
                           seq=32, ckpt_dir=ckpt, checkpoint_every=100,
                           lr=3e-3, kv_chunk=32, seed=0,
                           data_mode="periodic")
        assert len(losses2) == 4


class TestEngine:
    def test_batched_serving_drains(self):
        from repro.serve import Engine, Request
        cfg = get_config("internlm2-1.8b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, slots=2, max_len=48)
        rng = np.random.default_rng(0)
        for rid in range(4):
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(0, cfg.vocab_size, 8,
                                                   dtype=np.int32),
                               max_new_tokens=5))
        reqs = list(eng.queue)
        eng.run_until_drained(max_ticks=200)
        assert not eng.queue
        for r in reqs:
            assert r.done and len(r.generated) >= 5
