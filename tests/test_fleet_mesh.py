"""Mesh-resident fleet fan-out acceptance tests.

The acceptance contract: ``IndexFleet.query(placement="mesh")`` — the
single-shard_map fan-out over device-resident stacked shard stores — is
**bit-identical** to the host-loop oracle (``placement="host"``) on 1/2/4
device CPU meshes, for routed and exhaustive fan-out, with a shard count
that does not divide the mesh (S=3), and with a live delta.

Multi-device runs happen in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the parent jax is
already initialised with 1 device); the 1-device mesh cases run in-process.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.launch.mesh import make_mesh
from repro.utils.config import ClimberConfig

REPO = Path(__file__).resolve().parents[1]
K = 10

SETUP = """
    from repro.data import make_dataset, make_queries
    from repro.fleet import FleetConfig, IndexFleet
    from repro.launch.mesh import make_mesh
    from repro.utils.config import ClimberConfig

    cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                        prefix_len=5, capacity=128, sample_frac=0.3,
                        max_centroids=12, k=10, candidate_groups=4,
                        adaptive_factor=4)
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1800, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 5))
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   auto_compact=False))
    for i in range(3):                      # S=3: ragged on 2 and 4 devices
        fleet.add_shard(f"t{i}", data[i * 600:(i + 1) * 600])
    fleet.insert(np.asarray(make_dataset("randomwalk",
                                         jax.random.PRNGKey(5), 80, 64)))
"""


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


def run_subprocess(body: str, timeout: int = 600) -> dict:
    """Run SETUP + ``body`` on 8 host devices; body prints one JSON line."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8, jax.device_count()
    """) + textwrap.dedent(SETUP) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = small_cfg()
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1800, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 5))
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   auto_compact=False))
    for i in range(3):
        fleet.add_shard(f"t{i}", data[i * 600:(i + 1) * 600])
    fleet.insert(np.asarray(make_dataset("randomwalk",
                                         jax.random.PRNGKey(5), 80, 64)))
    return fleet, queries


class TestSingleDeviceMesh:
    def test_mesh_bit_identical_to_host(self, fleet_setup):
        """1-device mesh: results and per-query metrics match the oracle
        exactly, routed and exhaustive."""
        fleet, queries = fleet_setup
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            for routing in ("exhaustive", "signature"):
                for variant in ("adaptive", "exhaustive"):
                    dh, gh, ih = fleet.query(queries, K, routing=routing,
                                             variant=variant,
                                             placement="host")
                    dm, gm, im = fleet.query(queries, K, routing=routing,
                                             variant=variant,
                                             placement="mesh")
                    np.testing.assert_array_equal(gh, gm)
                    np.testing.assert_array_equal(dh, dm)
                    np.testing.assert_array_equal(ih.partitions_touched,
                                                  im.partitions_touched)
                    np.testing.assert_array_equal(ih.candidates_scanned,
                                                  im.candidates_scanned)
                    np.testing.assert_array_equal(ih.routed_mask,
                                                  im.routed_mask)
        finally:
            fleet.mesh = None
            fleet._placement = None

    def test_default_placement_follows_mesh(self, fleet_setup):
        """placement=None resolves to mesh iff a mesh is attached."""
        fleet, queries = fleet_setup
        d_host, g_host, _ = fleet.query(queries, K)     # no mesh → host
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            d_mesh, g_mesh, _ = fleet.query(queries, K)  # mesh default
            np.testing.assert_array_equal(g_host, g_mesh)
            np.testing.assert_array_equal(d_host, d_mesh)
        finally:
            fleet.mesh = None
            fleet._placement = None

    def test_engine_mesh_matches_host(self, fleet_setup):
        fleet, queries = fleet_setup
        mesh = make_mesh((1,), ("data",))
        try:
            eng_m = FleetEngine(fleet, batch_size=4, k=K, mesh=mesh,
                                placement="mesh", routing="exhaustive")
            dm, gm, _ = eng_m.run(queries)
            eng_h = FleetEngine(fleet, batch_size=4, k=K, placement="host",
                                routing="exhaustive")
            dh, gh, _ = eng_h.run(queries)
            np.testing.assert_array_equal(gm, gh)
            np.testing.assert_array_equal(dm, dh)
        finally:
            fleet.mesh = None
            fleet._placement = None

    def test_scan_exact_uses_attached_mesh(self, fleet_setup):
        fleet, queries = fleet_setup
        d0, g0 = fleet.scan_exact(queries, K)
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            d1, g1 = fleet.scan_exact(queries, K)
            np.testing.assert_array_equal(g0, g1)
            np.testing.assert_array_equal(d0, d1)
        finally:
            fleet.mesh = None
            fleet._placement = None

    def test_compact_invalidates_placement(self):
        """Sealing the delta changes the sealed set: the next mesh query
        must see the new shard (re-laid-out placement), and stay identical
        to the host loop."""
        cfg = small_cfg()
        data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(3),
                                       1200, 64))
        queries = np.asarray(make_queries(jax.random.PRNGKey(4),
                                          jnp.asarray(data), 4))
        fleet = IndexFleet(FleetConfig(shard_cfg=cfg, auto_compact=False),
                           mesh=make_mesh((1,), ("data",)))
        fleet.add_shard("t0", data[:600])
        fleet.add_shard("t1", data[600:])
        fleet.query(queries, K, placement="mesh")   # placement built (S=2)
        assert fleet._placement is not None and \
            fleet._placement.num_shards == 2
        fleet.insert(np.asarray(make_dataset("randomwalk",
                                             jax.random.PRNGKey(6), 64, 64)))
        fleet.compact()
        dm, gm, _ = fleet.query(queries, K, routing="exhaustive",
                                placement="mesh")
        assert fleet._placement.num_shards == 3
        dh, gh, _ = fleet.query(queries, K, routing="exhaustive",
                                placement="host")
        np.testing.assert_array_equal(gm, gh)
        np.testing.assert_array_equal(dm, dh)


class TestPlacementValidation:
    def test_mesh_placement_without_mesh_raises(self, fleet_setup):
        fleet, queries = fleet_setup
        with pytest.raises(ValueError, match="mesh"):
            fleet.query(queries, K, placement="mesh")

    def test_unknown_placement_raises(self, fleet_setup):
        fleet, queries = fleet_setup
        with pytest.raises(ValueError, match="placement"):
            fleet.query(queries, K, placement="gpu")
        with pytest.raises(ValueError, match="placement"):
            FleetEngine(fleet, placement="gpu")


class TestMultiDeviceMesh:
    def test_2_and_4_device_bit_identity(self):
        """Acceptance: mesh fan-out ≡ host loop on 2- and 4-device meshes,
        S=3 shards (S % n_dev != 0 on both), routed + exhaustive, with a
        live delta."""
        out = run_subprocess("""
            oracle = {}
            for routing in ("exhaustive", "signature"):
                d, g, info = fleet.query(queries, 10, routing=routing,
                                         variant="adaptive",
                                         placement="host")
                oracle[routing] = (d, g, info)

            results = {}
            for n_dev in (2, 4):
                fleet.attach_mesh(make_mesh((n_dev,), ("data",)))
                for routing in ("exhaustive", "signature"):
                    dm, gm, im = fleet.query(queries, 10, routing=routing,
                                             variant="adaptive",
                                             placement="mesh")
                    dh, gh, ih = oracle[routing]
                    results[f"{n_dev}/{routing}"] = bool(
                        np.array_equal(dm, dh) and np.array_equal(gm, gh)
                        and np.array_equal(im.partitions_touched,
                                           ih.partitions_touched)
                        and np.array_equal(im.candidates_scanned,
                                           ih.candidates_scanned))
                # padded shard slots: S=3 rounds up to a multiple of n_dev
                results[f"{n_dev}/slots"] = fleet._placement.num_slots
            print(json.dumps(results))
        """)
        for key in ("2/exhaustive", "2/signature", "4/exhaustive",
                    "4/signature"):
            assert out[key], f"mesh != host at {key}: {out}"
        assert out["2/slots"] == 4 and out["4/slots"] == 4, out

    def test_4_device_exhaustive_variant_and_scan(self):
        """Exact mode end-to-end on 4 devices: mesh fan-out with the
        exhaustive planner ≡ host loop ≡ sharded scan_exact."""
        out = run_subprocess("""
            dh, gh, _ = fleet.query(queries, 10, routing="exhaustive",
                                    variant="exhaustive", placement="host")
            fleet.attach_mesh(make_mesh((4,), ("data",)))
            dm, gm, _ = fleet.query(queries, 10, routing="exhaustive",
                                    variant="exhaustive", placement="mesh")
            ds, gs = fleet.scan_exact(queries, 10)
            print(json.dumps({
                "mesh": bool(np.array_equal(dm, dh)
                             and np.array_equal(gm, gh)),
                "scan": bool(np.array_equal(ds, dh)
                             and np.array_equal(gs, gh)),
            }))
        """)
        assert out["mesh"], out
        assert out["scan"], out
