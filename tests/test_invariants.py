"""System-invariant property tests (hypothesis) across both planes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import build_index, knn_query
from repro.core.query import compact_plan, plan_adaptive
from repro.data import make_dataset
from repro.models import layers as L
from repro.utils.config import ClimberConfig


@pytest.fixture(scope="module")
def tiny_index():
    cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                        prefix_len=5, capacity=128, sample_frac=0.3,
                        max_centroids=12, k=10, candidate_groups=4,
                        adaptive_factor=4)
    data = make_dataset("randomwalk", jax.random.PRNGKey(0), 3000, 64)
    return build_index(jax.random.PRNGKey(1), data, cfg), data


class TestIndexInvariants:
    def test_full_coverage(self, tiny_index):
        """Every record lands in exactly one partition (Def. 12: disjoint +
        full coverage)."""
        index, data = tiny_index
        gids = np.asarray(index.store.rec_gid).ravel()
        live = gids[gids >= 0]
        assert len(live) == data.shape[0]
        assert len(set(live)) == data.shape[0]

    def test_dfs_tags_within_group_intervals(self, tiny_index):
        """A record's DFS tag must lie inside its group root's interval."""
        index, _ = tiny_index
        f = index.forest
        part_group = np.zeros(f.num_partitions, dtype=int)
        for g in range(len(f.group_root)):
            root = f.group_root[g]
            for pid in f.node_partitions(root):
                part_group[pid] = g
        rec_dfs = np.asarray(index.store.rec_dfs)
        for pid in range(f.num_partitions):
            g = part_group[pid]
            root = f.group_root[g]
            tags = rec_dfs[pid][rec_dfs[pid] >= 0]
            assert np.all(tags >= f.dfs_in[root])
            assert np.all(tags < f.dfs_out[root])

    def test_compact_plan_lossless(self, tiny_index):
        """compact_plan must preserve the query answers when the slot budget
        covers the real entries (the production query path relies on it)."""
        index, data = tiny_index
        q = data[:6]
        p4r, _ = index.featurize(q)
        plan = plan_adaptive(index, p4r)
        budget = int(np.asarray((plan.sel_part >= 0).sum(axis=-1)).max())
        cp = compact_plan(plan, max_slots=budget)
        from repro.core.refine import refine
        d1, g1 = refine(index.store, q, plan.sel_part, plan.sel_lo,
                        plan.sel_hi, 10)
        d2, g2 = refine(index.store, q, cp.sel_part, cp.sel_lo, cp.sel_hi, 10)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


class TestLayerInvariants:
    def test_cache_write_modes_equivalent(self):
        """DUS vs masked one-hot cache writes must be bit-identical."""
        cache = jnp.zeros((2, 16, 4, 8), jnp.bfloat16)
        new = jnp.ones((2, 1, 4, 8), jnp.float32) * 3
        pos = jnp.int32(5)
        a = L._cache_write(cache, new, pos)
        L.set_cache_update_masked(True)
        try:
            b = L._cache_write(cache, new, pos)
        finally:
            L.set_cache_update_masked(False)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
    def test_flash_matches_naive_softmax(self, seed, g):
        """Chunked online softmax == naive attention, any GQA group size."""
        key = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(key, 3)
        b, sq, kvh, hd = 2, 8, 2, 16
        h = kvh * g
        q = jax.random.normal(kq, (b, sq, h, hd), jnp.float32)
        k = jax.random.normal(kk, (b, sq, kvh, hd), jnp.float32)
        v = jax.random.normal(kv, (b, sq, kvh, hd), jnp.float32)
        out = L.flash_attention(q, k, v, causal=True, kv_chunk=4)

        k_e = jnp.repeat(k, g, axis=2)
        v_e = jnp.repeat(v, g, axis=2)
        s = jnp.einsum("bqhd,bchd->bqhc", q * hd ** -0.5, k_e)
        mask = jnp.tril(jnp.ones((sq, sq), bool))
        s = jnp.where(mask[None, :, None, :], s, -1e30)
        ref = jnp.einsum("bqhc,bchd->bqhd", jax.nn.softmax(s, axis=-1), v_e)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_flash_bf16_close_to_f32(self):
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (2, 16, 4, 16), jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 2, 16),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 2, 16),
                              jnp.bfloat16)
        a = L.flash_attention(q, k, v, causal=True, kv_chunk=8)
        L.set_flash_bf16(True)
        try:
            b = L.flash_attention(q, k, v, causal=True, kv_chunk=8)
        finally:
            L.set_flash_bf16(False)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)
