"""Baseline correctness + the paper's headline qualitative result:
CLIMBER recall > TARDIS-like > DPiSAX-like at comparable data touched."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import (build_dpisax, build_tardis, dpisax_knn,
                             exact_knn, recall, sax_breakpoints, sax_word,
                             tardis_knn)
from repro.core import build_index, knn_query
from repro.data import make_dataset, make_queries
from repro.utils.config import ClimberConfig


class TestDss:
    def test_exact_matches_numpy(self):
        data = make_dataset("randomwalk", jax.random.PRNGKey(0), 500, 64)
        q = data[:5]
        dist, idx = exact_knn(q, data, 10)
        dn, qn = np.asarray(data), np.asarray(q)
        for i in range(5):
            ref = np.argsort(((qn[i] - dn) ** 2).sum(-1))[:10]
            assert set(np.asarray(idx[i])) == set(ref)

    def test_chunked_matches_single_pass(self):
        data = make_dataset("eeg", jax.random.PRNGKey(1), 700, 64)
        q = data[:4]
        d1, i1 = exact_knn(q, data, 8)
        d2, i2 = exact_knn(q, data, 8, chunk=128)
        # float32 norm-trick noise floor ~1e-2 on near-zero distances
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=2e-2)
        for a, b in zip(np.asarray(i1), np.asarray(i2)):
            assert set(a) == set(b)

    def test_self_recall_is_one(self):
        data = make_dataset("sift", jax.random.PRNGKey(2), 300, 64)
        _, idx = exact_knn(data[:3], data, 5)
        assert recall(idx, idx) == 1.0


class TestSAX:
    def test_breakpoints_symmetric(self):
        bp = np.asarray(sax_breakpoints(8))
        assert len(bp) == 7
        np.testing.assert_allclose(bp, -bp[::-1], atol=1e-5)
        assert bp[3] == pytest.approx(0.0, abs=1e-6)

    def test_word_range(self):
        data = make_dataset("randomwalk", jax.random.PRNGKey(3), 100, 64)
        w = np.asarray(sax_word(data, 8, 8))
        assert w.shape == (100, 8)
        assert w.min() >= 0 and w.max() < 8

    def test_identical_series_same_word(self):
        x = make_dataset("randomwalk", jax.random.PRNGKey(4), 1, 64)
        w1 = sax_word(x, 8, 8)
        w2 = sax_word(x, 8, 8)
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


@pytest.fixture(scope="module")
def bench_setup():
    # Paper-regime proportions: capacity small vs N so both baselines must
    # pick among many partitions (the 200GB/64MB-block ratio, scaled down).
    data = make_dataset("randomwalk", jax.random.PRNGKey(10), 8000, 128)
    queries = make_queries(jax.random.PRNGKey(11), data, 24)
    k = 50
    _, exact_ids = exact_knn(queries, data, k)
    return data, queries, k, exact_ids


class TestBaselineIndexes:
    def test_dpisax_end_to_end(self, bench_setup):
        data, queries, k, exact_ids = bench_setup
        index = build_dpisax(data, segments=16, cardinality=8, capacity=512)
        dist, gid = dpisax_knn(index, queries, k)
        gid = np.asarray(gid)
        assert gid.shape == (24, k)
        r = recall(gid, exact_ids)
        assert 0.0 <= r <= 1.0
        # every returned id must exist
        assert np.all(gid[gid >= 0] < data.shape[0])

    def test_tardis_end_to_end(self, bench_setup):
        data, queries, k, exact_ids = bench_setup
        index = build_tardis(jax.random.PRNGKey(12), data, segments=16,
                             cardinality=8, capacity=512, sample_frac=0.2)
        dist, gid = tardis_knn(index, queries, k)
        r = recall(np.asarray(gid), exact_ids)
        assert 0.0 <= r <= 1.0

    def test_headline_recall_ordering(self, bench_setup):
        """Paper Fig. 7(b): CLIMBER > TARDIS >= DPiSAX in recall."""
        data, queries, k, exact_ids = bench_setup
        cfg = ClimberConfig(series_len=128, paa_segments=16, num_pivots=96,
                            prefix_len=10, capacity=128, sample_frac=0.2,
                            max_centroids=32, k=k, candidate_groups=8,
                            adaptive_factor=4)
        climber = build_index(jax.random.PRNGKey(13), data, cfg)
        _, gid_c, _ = knn_query(climber, queries, k, variant="adaptive")
        r_climber = recall(np.asarray(gid_c), exact_ids)

        dp = build_dpisax(data, segments=16, cardinality=8, capacity=128)
        _, gid_d = dpisax_knn(dp, queries, k)
        r_dpisax = recall(np.asarray(gid_d), exact_ids)

        td = build_tardis(jax.random.PRNGKey(14), data, segments=16,
                          cardinality=8, capacity=128, sample_frac=0.2)
        _, gid_t = tardis_knn(td, queries, k)
        r_tardis = recall(np.asarray(gid_t), exact_ids)

        assert r_climber > r_dpisax, (r_climber, r_tardis, r_dpisax)
        assert r_climber > r_tardis, (r_climber, r_tardis, r_dpisax)
        assert r_climber > 0.4, f"CLIMBER recall too low: {r_climber}"
