"""Roofline machinery tests + a reduced-mesh dry-run integration test."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.utils.roofline import (RooflineReport, collective_bytes,
                                  model_flops, _shape_bytes)

REPO = Path(__file__).resolve().parents[1]


class TestCollectiveParse:
    HLO = """
HloModule test
fused_computation {
  x = bf16[8,128]{1,0} parameter(0)
  ROOT y = bf16[8,128]{1,0} add(x, x)
}
ENTRY main {
  p0 = bf16[8,128]{1,0} parameter(0)
  ag = bf16[128,128]{1,0} all-gather(p0), dimensions={0}
  ar = f32[64]{0} all-reduce(something), to_apply=add
  rs = f32[4,16]{1,0} reduce-scatter(ar2), dimensions={0}
  cp = bf16[8,128]{1,0} collective-permute(p0)
  ags = (bf16[256]{0}, bf16[256]{0}) all-gather-start(p1)
  agd = bf16[256]{0} all-gather-done(ags)
  consumer = bf16[128,128]{1,0} add(ag, ag)
}
"""

    def test_counts_each_kind_once(self):
        out = collective_bytes(self.HLO)
        # plain ag result + the -start tuple's payload member (not the alias)
        assert out["all-gather"] == 128 * 128 * 2 + 256 * 2
        assert out["all-reduce"] == 64 * 4
        assert out["reduce-scatter"] == 4 * 16 * 4
        assert out["collective-permute"] == 8 * 128 * 2

    def test_plain_ops_not_counted(self):
        out = collective_bytes("ENTRY e {\n  a = f32[10]{0} add(x, y)\n}")
        assert sum(out.values()) == 0

    def test_shape_bytes(self):
        assert _shape_bytes("bf16[2,3]") == 12
        assert _shape_bytes("f32[]") == 4
        assert _shape_bytes("s8[100]") == 100


class TestRooflineReport:
    def _report(self, **kw):
        base = dict(arch="a", shape="s", mesh="m",
                    flops_per_device=197e12,      # exactly 1s of compute
                    bytes_per_device=819e9 / 2,   # 0.5s of memory
                    coll_bytes_per_device=50e9 / 4,  # 0.25s of collective
                    coll_breakdown={},
                    model_flops_per_device=197e12 / 2)
        base.update(kw)
        return RooflineReport(**base)

    def test_terms_and_bottleneck(self):
        r = self._report()
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(0.5)
        assert r.collective_s == pytest.approx(0.25)
        assert r.bottleneck == "compute"
        assert r.roofline_fraction == pytest.approx(0.5)

    def test_decode_bandwidth_utility(self):
        r = self._report(flops_per_device=1e9, model_flops_per_device=1e6,
                         bytes_per_device=819e9,
                         model_bytes_per_device=819e9 / 2)
        assert r.bottleneck == "memory"
        assert r.roofline_fraction == pytest.approx(0.5, rel=1e-3)

    def test_model_flops(self):
        assert model_flops(1e9, 100, "train") == 6e11
        assert model_flops(1e9, 100, "serve") == 2e11
        assert model_flops(1e9, 100, "serve", active_params=5e8) == 1e11


@pytest.mark.slow
class TestDryRunReduced:
    """End-to-end dry-run semantics on a 16-virtual-device mesh (fast)."""

    def test_lower_compile_and_analyze(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import json, jax
            import repro.launch.dryrun as DR
            import repro.launch.mesh as MESH

            # shrink the production mesh for the test
            MESH.make_production_mesh = lambda multi_pod=False: \\
                MESH.make_mesh((2, 2, 4) if multi_pod else (4, 4),
                               ("pod", "data", "model") if multi_pod
                               else ("data", "model"))
            DR.make_production_mesh = MESH.make_production_mesh

            res = DR.run_cell("internlm2-1.8b", "train_4k", multi_pod=False,
                              kv_chunk=2048, verbose=False)
            res_m = DR.run_cell("olmoe-1b-7b", "decode_32k", multi_pod=True,
                                kv_chunk=2048, verbose=False, skip_cost=True)
            print(json.dumps({"single": res["status"],
                              "flops": res["flops_per_device"],
                              "bottleneck": res["bottleneck"],
                              "multi": res_m["status"]}))
        """)
        env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
        import os
        env.update({k: v for k, v in os.environ.items()
                    if k not in ("XLA_FLAGS",)})
        env["PYTHONPATH"] = str(REPO / "src")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=900, env=env)
        assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
        out = json.loads(r.stdout.strip().splitlines()[-1])
        assert out["single"] == "ok" and out["multi"] == "ok"
        assert out["flops"] > 1e11     # real per-device work was counted
        assert out["bottleneck"] in ("compute", "memory", "collective")
