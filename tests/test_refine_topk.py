"""Streaming fused refine kernel — parity grid + edge shapes.

The fused kernel (``repro.kernels.refine_topk``) must match the dense
refine path: gids exactly (both sides share the lowest-flat-index
tie-break), distances to fp rounding of the blocked dot, and the
``PAD_DIST``/gid=-1 sentinel convention bit-for-bit wherever fewer than k
candidates exist.  Everything runs in Pallas interpret mode on CPU — the
exact TPU kernel body, executed by the interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import PartitionStore
from repro.core.refine import (PAD_DIST, _sort_by_partition, refine,
                               resolve_use_kernel)
from repro.kernels import ref
from repro.kernels.refine_topk import (DEFAULT_BLOCK_C, pick_block_c,
                                       refine_topk)

DTOL = dict(rtol=1e-5, atol=1e-5)


def _mkstore(rng, p, cap, n, pad_frac=0.25, dfs_hi=50):
    data = rng.normal(size=(p, cap, n)).astype(np.float32)
    gid = np.arange(p * cap, dtype=np.int32).reshape(p, cap)
    gid[rng.random((p, cap)) < pad_frac] = -1
    dfs = rng.integers(0, dfs_hi, size=(p, cap)).astype(np.int32)
    return PartitionStore(
        data=jnp.asarray(data), norms=jnp.asarray((data ** 2).sum(-1)),
        rec_dfs=jnp.asarray(dfs), rec_gid=jnp.asarray(gid),
        count=jnp.asarray((gid >= 0).sum(1).astype(np.int32)))


def _mkplan(rng, q, mp, p, dfs_hi=50):
    sp = jnp.asarray(rng.integers(-1, p, size=(q, mp)).astype(np.int32))
    lo = rng.integers(0, dfs_hi - 10, size=(q, mp)).astype(np.int32)
    hi = jnp.asarray(lo + rng.integers(0, 30, size=(q, mp)).astype(np.int32))
    return sp, jnp.asarray(lo), hi


def _fused(store, queries, sp, lo, hi, k, **kw):
    """Kernel call with the refine() wrapper conventions applied."""
    ssp, slo, shi = _sort_by_partition(sp, lo, hi)
    d2, gid = refine_topk(store.data, store.norms, store.rec_dfs,
                          store.rec_gid, queries, ssp, slo, shi, k,
                          interpret=True, **kw)
    return np.sqrt(np.asarray(d2)), np.asarray(
        jnp.where(d2 >= 3.4e38, -1, gid))


class TestParityGrid:
    """Acceptance: fused ≡ dense across the Q×slots×cap×k sweep."""

    @pytest.mark.parametrize("q,mp,cap,k,block_c", [
        (1, 1, 8, 1, None),      # degenerate single-everything
        (3, 4, 12, 5, None),
        (5, 9, 12, 7, None),     # multiple entries per partition (dedupe live)
        (2, 6, 33, 20, None),    # cap not a lane multiple
        (4, 3, 16, 10, None),
        (3, 5, 40, 8, 16),       # explicit non-default block (cap % bc != 0)
        (3, 5, 12, 6, 256),      # explicit block far above cap (clamped)
    ])
    def test_matches_dense_refine(self, q, mp, cap, k, block_c):
        rng = np.random.default_rng(q * 101 + mp * 7 + cap)
        store = _mkstore(rng, 6, cap, 32)
        queries = jnp.asarray(rng.normal(size=(q, 32)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, q, mp, 6)
        d_ref, g_ref = refine(store, queries, sp, lo, hi, k,
                              use_kernel=False)
        kw = {} if block_c is None else {"block_c": block_c}
        dist, gid = _fused(store, queries, sp, lo, hi, k, **kw)
        np.testing.assert_array_equal(np.asarray(g_ref), gid)
        np.testing.assert_allclose(np.asarray(d_ref), dist, **DTOL)

    @pytest.mark.parametrize("q,mp,cap,k", [(3, 5, 12, 6), (2, 8, 24, 15)])
    def test_matches_ref_oracle(self, q, mp, cap, k):
        """Kernel vs the package's own dense oracle (kernels/ref.py)."""
        rng = np.random.default_rng(q + mp + cap)
        store = _mkstore(rng, 5, cap, 16)
        queries = jnp.asarray(rng.normal(size=(q, 16)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, q, mp, 5)
        ssp, slo, shi = _sort_by_partition(sp, lo, hi)
        d2, gid = refine_topk(store.data, store.norms, store.rec_dfs,
                              store.rec_gid, queries, ssp, slo, shi, k,
                              interpret=True)
        d2_ref, g_ref = ref.refine_topk_ref(
            store.data, store.norms, store.rec_dfs, store.rec_gid,
            queries, ssp, slo, shi, k)
        np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(gid))
        np.testing.assert_allclose(np.asarray(d2_ref), np.asarray(d2),
                                   rtol=1e-4, atol=1e-4)

    def test_refine_use_kernel_flag_routes_to_fused(self):
        """refine(use_kernel=True) is the fused kernel, sentinel included."""
        rng = np.random.default_rng(3)
        store = _mkstore(rng, 4, 12, 16)
        queries = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, 3, 5, 4)
        d_k, g_k = refine(store, queries, sp, lo, hi, 6, use_kernel=True)
        dist, gid = _fused(store, queries, sp, lo, hi, 6)
        np.testing.assert_array_equal(np.asarray(g_k), gid)
        np.testing.assert_array_equal(np.asarray(d_k), dist)


class TestEdgeShapes:
    """Satellite: cap % block ≠ 0, all-masked plans, pools smaller than k."""

    @pytest.mark.parametrize("cap,block_c", [
        (12, 5),    # ragged last block
        (12, 12),   # exactly one block
        (12, 4),    # even split
        (7, 16),    # block larger than cap (clamped)
    ])
    def test_cap_not_multiple_of_block(self, cap, block_c):
        rng = np.random.default_rng(cap * 31 + block_c)
        store = _mkstore(rng, 5, cap, 16)
        queries = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, 4, 6, 5)
        d_ref, g_ref = refine(store, queries, sp, lo, hi, 5,
                              use_kernel=False)
        dist, gid = _fused(store, queries, sp, lo, hi, 5, block_c=block_c)
        np.testing.assert_array_equal(np.asarray(g_ref), gid)
        np.testing.assert_allclose(np.asarray(d_ref), dist, **DTOL)

    def test_all_masked_plan(self):
        """Every entry padded / every interval empty → pure PAD output,
        identical to the dense path."""
        rng = np.random.default_rng(0)
        store = _mkstore(rng, 4, 10, 16)
        queries = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        empty_part = jnp.full((3, 5), -1, jnp.int32)     # all pad entries
        zeros = jnp.zeros((3, 5), jnp.int32)
        live_part = jnp.asarray(
            rng.integers(0, 4, size=(3, 5)).astype(np.int32))
        for sp, lo, hi in [
            (empty_part, zeros, zeros + 10),   # no partition selected
            (live_part, zeros + 7, zeros + 7),  # empty DFS intervals
        ]:
            d_ref, g_ref = refine(store, queries, sp, lo, hi, 5,
                                  use_kernel=False)
            dist, gid = _fused(store, queries, sp, lo, hi, 5)
            np.testing.assert_array_equal(gid, -1)
            np.testing.assert_array_equal(dist, np.float32(PAD_DIST))
            np.testing.assert_array_equal(np.asarray(g_ref), gid)
            np.testing.assert_array_equal(np.asarray(d_ref), dist)

    def test_pool_smaller_than_k(self):
        """cap·slots < k must emit PAD_DIST/gid=-1 exactly like dense."""
        rng = np.random.default_rng(1)
        store = _mkstore(rng, 3, 6, 16, pad_frac=0.5)
        queries = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, 2, 2, 3)
        k = 40                                  # > 2 slots × 6 cap
        d_ref, g_ref = refine(store, queries, sp, lo, hi, k,
                              use_kernel=False)
        dist, gid = _fused(store, queries, sp, lo, hi, k)
        assert np.all(gid[:, -10:] == -1)       # tail is certainly padded
        np.testing.assert_array_equal(np.asarray(g_ref), gid)
        pads = gid < 0
        np.testing.assert_array_equal(dist[pads], np.float32(PAD_DIST))
        np.testing.assert_allclose(np.asarray(d_ref)[~pads], dist[~pads],
                                   **DTOL)

    def test_duplicate_coverage_dedupe(self):
        """A node and its ancestor both selected: each record must be
        counted once — no duplicate gids, and parity with dense."""
        rng = np.random.default_rng(2)
        store = _mkstore(rng, 4, 12, 16, pad_frac=0.0)
        queries = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        # same partition selected thrice with nested/overlapping intervals
        sp = jnp.asarray(np.tile([2, 2, 2, 1], (3, 1)).astype(np.int32))
        lo = jnp.asarray(np.tile([0, 5, 10, 0], (3, 1)).astype(np.int32))
        hi = jnp.asarray(np.tile([20, 15, 50, 50], (3, 1)).astype(np.int32))
        d_ref, g_ref = refine(store, queries, sp, lo, hi, 10,
                              use_kernel=False)
        dist, gid = _fused(store, queries, sp, lo, hi, 10)
        np.testing.assert_array_equal(np.asarray(g_ref), gid)
        np.testing.assert_allclose(np.asarray(d_ref), dist, **DTOL)
        for row in gid:
            real = row[row >= 0]
            assert len(set(real.tolist())) == len(real)

    def test_empty_batch_and_empty_plan(self):
        rng = np.random.default_rng(4)
        store = _mkstore(rng, 3, 8, 16)
        d, g = refine_topk(store.data, store.norms, store.rec_dfs,
                           store.rec_gid,
                           jnp.zeros((0, 16), jnp.float32),
                           jnp.zeros((0, 4), jnp.int32),
                           jnp.zeros((0, 4), jnp.int32),
                           jnp.zeros((0, 4), jnp.int32), 5, interpret=True)
        assert d.shape == (0, 5) and g.shape == (0, 5)


class TestEndToEnd:
    def test_knn_query_kernel_parity(self):
        """Fused refine through the full featurize→plan→refine pipeline."""
        from repro.core import build_index, knn_query
        from repro.data import make_dataset, make_queries
        from repro.utils.config import ClimberConfig
        cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                            prefix_len=5, capacity=64, sample_frac=0.3,
                            max_centroids=12, k=10, candidate_groups=4,
                            adaptive_factor=4)
        data = make_dataset("randomwalk", jax.random.PRNGKey(0), 1500, 64)
        index = build_index(jax.random.PRNGKey(1), data, cfg)
        queries = np.asarray(make_queries(jax.random.PRNGKey(2), data, 5))
        for variant in ("knn", "adaptive"):
            d0, g0, _ = knn_query(index, queries, 10, variant=variant,
                                  use_kernel=False)
            d1, g1, _ = knn_query(index, queries, 10, variant=variant,
                                  use_kernel=True)
            np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
            np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                       **DTOL)

    def test_backend_default_resolution(self):
        """None resolves to the backend default; explicit flags win."""
        assert resolve_use_kernel(True) is True
        assert resolve_use_kernel(False) is False
        # fused kernel on accelerators, dense oracle elsewhere (CPU CI)
        assert resolve_use_kernel(None) == (jax.default_backend() == "tpu")


class TestBlockAutotune:
    """First autotuning step: BLOCK_C picked at trace time from cap."""

    def test_pick_is_capped_next_pow2(self):
        assert pick_block_c(1) == 1
        assert pick_block_c(12) == 16            # pow2 cover, no 512 padding
        assert pick_block_c(100) == 128
        assert pick_block_c(512) == DEFAULT_BLOCK_C
        assert pick_block_c(4096) == DEFAULT_BLOCK_C  # streams in 512 blocks

    @pytest.mark.parametrize("cap", [12, 100, 600])
    def test_auto_block_parity(self, cap):
        """The default (auto) block matches dense — including the small-cap
        case where the single auto block exceeds cap and the tail is
        index-masked."""
        rng = np.random.default_rng(cap)
        store = _mkstore(rng, 5, cap, 16)
        queries = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        sp, lo, hi = _mkplan(rng, 3, 4, 5)
        d_ref, g_ref = refine(store, queries, sp, lo, hi, 6,
                              use_kernel=False)
        dist, gid = _fused(store, queries, sp, lo, hi, 6)   # block_c=None
        np.testing.assert_array_equal(np.asarray(g_ref), gid)
        np.testing.assert_allclose(np.asarray(d_ref), dist, **DTOL)
