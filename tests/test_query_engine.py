"""ClimberEngine + unified query-path tests.

Covers the serving-layer acceptance contract (engine ≡ per-query knn_query,
bit-identical, on every execution backend), the planner registry, budgeted
plan compaction through the public knn_query knob, and refine_sharded ≡
refine on multi-device host meshes including ragged partition counts.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QueryPlan, build_index, candidates_scanned,
                        compact_plan, default_slot_budget, get_planner,
                        knn_query, plan, plan_knn, planner_names,
                        register_planner)
from repro.core.index import PartitionStore
from repro.core.refine import refine
from repro.data import make_dataset, make_queries
from repro.serve import ClimberEngine, QueryRequest
from repro.utils.config import ClimberConfig

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def small_index():
    cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                        prefix_len=5, capacity=128, sample_frac=0.3,
                        max_centroids=12, k=10, candidate_groups=4,
                        adaptive_factor=4)
    data = make_dataset("randomwalk", jax.random.PRNGKey(0), 3000, 64)
    index = build_index(jax.random.PRNGKey(1), data, cfg)
    queries = np.asarray(make_queries(jax.random.PRNGKey(2), data, 11))
    return index, queries


# ----------------------------------------------------------------------
# Engine ≡ per-query knn_query (acceptance criterion), dense + kernel
# ----------------------------------------------------------------------
class TestEngineParity:
    @pytest.mark.parametrize("variant", ["knn", "adaptive", "od_smallest"])
    def test_dense_bit_identical(self, small_index, variant):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, variant=variant, k=10)
        dist, gid, metrics = engine.run(queries)
        assert len(metrics) == len(queries)
        for i in range(len(queries)):
            d1, g1, _ = knn_query(index, queries[i:i + 1], 10,
                                  variant=variant)
            np.testing.assert_array_equal(np.asarray(g1)[0], gid[i])
            np.testing.assert_array_equal(np.asarray(d1)[0], dist[i])

    @pytest.mark.parametrize("variant", ["knn", "adaptive", "od_smallest"])
    def test_kernel_bit_identical(self, small_index, variant):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, variant=variant, k=10,
                               use_kernel=True)
        dist, gid, _ = engine.run(queries[:6])
        for i in range(6):
            d1, g1, _ = knn_query(index, queries[i:i + 1], 10,
                                  variant=variant, use_kernel=True)
            np.testing.assert_array_equal(np.asarray(g1)[0], gid[i])
            np.testing.assert_array_equal(np.asarray(d1)[0], dist[i])

    def test_batch_size_invariance(self, small_index):
        """The batch a query rides in must not change its answer."""
        index, queries = small_index
        out = {}
        for bs in (1, 3, 8):
            engine = ClimberEngine(index, batch_size=bs, k=10)
            _, out[bs], _ = engine.run(queries)
        np.testing.assert_array_equal(out[1], out[3])
        np.testing.assert_array_equal(out[1], out[8])

    def test_queue_mode_matches_run(self, small_index):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, k=10)
        _, gid, _ = engine.run(queries)
        reqs = [QueryRequest(rid=i, series=queries[i], k=5)
                for i in range(len(queries))]
        for r in reqs:
            engine.submit(r)
        engine.run_until_drained()
        for r in reqs:
            assert r.done and r.metrics is not None
            assert r.metrics.partitions_touched >= 1
            assert r.metrics.candidates_scanned >= r.metrics.partitions_touched
            np.testing.assert_array_equal(r.gid, gid[r.rid][:5])

    def test_rejects_malformed_requests(self, small_index):
        """Admission validates requests so one bad series can't poison a
        batch, and an over-k ask fails loudly instead of silently clamping."""
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, k=10)
        with pytest.raises(ValueError, match="series shape"):
            engine.submit(QueryRequest(rid=0, series=queries[0][:7]))
        with pytest.raises(ValueError, match="exceeds the engine"):
            engine.submit(QueryRequest(rid=1, series=queries[0], k=99))
        with pytest.raises(ValueError, match="exceeds the engine"):
            engine.run(queries[:2], k=99)
        with pytest.raises(ValueError, match="batch_size"):
            ClimberEngine(index, batch_size=0)
        assert not engine.queue

    def test_empty_run(self, small_index):
        index, _ = small_index
        engine = ClimberEngine(index, batch_size=4, k=10)
        dist, gid, metrics = engine.run(np.zeros((0, 64), np.float32))
        assert dist.shape == (0, 10) and gid.shape == (0, 10)
        assert metrics == []

    def test_stats_aggregate(self, small_index):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, k=10)
        engine.run(queries)
        s = engine.stats
        assert s.queries == len(queries)
        assert s.queries_per_sec > 0
        assert s.mean_partitions_touched >= 1.0


# ----------------------------------------------------------------------
# Query plan cache (LRU on the P4→ signature prefix)
# ----------------------------------------------------------------------
class TestPlanCache:
    def test_repeat_queries_hit_and_stay_bit_identical(self, small_index):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, k=10)
        d1, g1, _ = engine.run(queries)
        assert engine.stats.plan_cache_misses == len(queries)
        assert engine.stats.plan_cache_hits == 0
        d2, g2, _ = engine.run(queries)          # identical workload: all hit
        assert engine.stats.plan_cache_hits == len(queries)
        assert engine.stats.plan_cache_misses == len(queries)
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(d1, d2)
        assert 0.0 < engine.stats.plan_cache_hit_rate < 1.0

    def test_cached_plan_matches_knn_query(self, small_index):
        """Answers served off the cache equal the uncached oracle."""
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=2, k=10)
        engine.run(queries[:4])
        dist, gid, _ = engine.run(queries[:4])   # fully cached pass
        for i in range(4):
            d1, g1, _ = knn_query(index, queries[i:i + 1], 10)
            np.testing.assert_array_equal(np.asarray(g1)[0], gid[i])
            np.testing.assert_array_equal(np.asarray(d1)[0], dist[i])

    def test_disabled_cache_counts_nothing(self, small_index):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=4, k=10, plan_cache_size=0)
        engine.run(queries)
        engine.run(queries)
        assert engine.stats.plan_cache_hits == 0
        assert engine.stats.plan_cache_misses == 0
        assert engine.stats.plan_cache_hit_rate == 0.0

    def test_lru_evicts_oldest_signature(self, small_index):
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=1, k=10, plan_cache_size=2)
        engine.run(queries[0:1])
        engine.run(queries[1:2])
        engine.run(queries[2:3])                 # evicts queries[0]
        assert len(engine._plan_cache) == 2
        engine.run(queries[0:1])                 # must miss again
        assert engine.stats.plan_cache_hits == 0
        assert engine.stats.plan_cache_misses == 4
        engine.run(queries[2:3])                 # still resident
        assert engine.stats.plan_cache_hits == 1

    def test_cache_only_keys_live_rows(self, small_index):
        """Zero-padded tail rows of a partial batch must not enter the
        cache or the counters."""
        index, queries = small_index
        engine = ClimberEngine(index, batch_size=8, k=10)
        engine.run(queries[:3])
        assert engine.stats.plan_cache_misses == 3
        assert len(engine._plan_cache) == 3


# ----------------------------------------------------------------------
# Planner registry
# ----------------------------------------------------------------------
class TestPlannerRegistry:
    def test_builtins_registered(self):
        assert {"knn", "adaptive", "od_smallest"} <= set(planner_names())

    def test_unknown_variant_raises(self, small_index):
        index, queries = small_index
        with pytest.raises(KeyError, match="registered"):
            knn_query(index, queries[:1], 5, variant="nope")
        with pytest.raises(KeyError):
            ClimberEngine(index, variant="nope")

    def test_custom_planner_end_to_end(self, small_index):
        index, queries = small_index
        register_planner("knn_alias", plan_knn)
        try:
            d1, g1, qp = knn_query(index, queries[:3], 5, variant="knn_alias")
            d2, g2, _ = knn_query(index, queries[:3], 5, variant="knn")
            np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
            assert get_planner("knn_alias") is plan_knn
            # no lossless bound is knowable for a custom planner: its plans
            # must not be compacted unless a budget is configured
            assert default_slot_budget(index, "knn_alias") is None
            p4r, _ = index.featurize(jnp.asarray(queries[:3]))
            raw = plan_knn(index, p4r)
            assert qp.sel_part.shape == raw.sel_part.shape
        finally:
            from repro.core import query as query_mod
            query_mod._PLANNERS.pop("knn_alias", None)


# ----------------------------------------------------------------------
# Budgeted plan compaction (satellite: compact_plan wired into knn_query)
# ----------------------------------------------------------------------
class TestPlanCompaction:
    def test_default_budget_halves_adaptive_plan(self, small_index):
        index, queries = small_index
        p4r, _ = index.featurize(jnp.asarray(queries))
        raw = get_planner("adaptive")(index, p4r)
        budgeted = plan(index, p4r, variant="adaptive")
        assert budgeted.sel_part.shape[-1] == \
            default_slot_budget(index, "adaptive")
        assert budgeted.sel_part.shape[-1] < raw.sel_part.shape[-1]

    def test_compaction_lossless_paper_default_cap(self, small_index):
        """Regression: the default budget must not drop live entries for the
        paper-default adaptive cap (T=4, Adaptive-4X)."""
        index, queries = small_index
        assert index.cfg.candidate_groups == 4
        assert index.cfg.adaptive_factor == 4
        p4r, _ = index.featurize(jnp.asarray(queries))
        raw = get_planner("adaptive")(index, p4r)
        budgeted = plan(index, p4r, variant="adaptive")
        live_raw = np.asarray((raw.sel_part >= 0).sum(-1))
        live_b = np.asarray((budgeted.sel_part >= 0).sum(-1))
        np.testing.assert_array_equal(live_raw, live_b)
        # and the answers through the public knob are identical
        d1, g1, _ = knn_query(index, queries, 10, max_slots=10**6)
        d2, g2, _ = knn_query(index, queries, 10)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))

    def test_config_knob(self, small_index):
        """cfg.query_max_slots drives compaction through knn_query."""
        index, queries = small_index
        cfg2 = index.cfg.replace(query_max_slots=4)
        import dataclasses
        index2 = dataclasses.replace(index, cfg=cfg2)
        _, _, qp = knn_query(index2, queries, 10)
        assert qp.sel_part.shape[-1] == 4

    def test_candidates_scanned_counts_distinct(self, small_index):
        index, _ = small_index
        store = index.store
        sel = jnp.asarray([[0, 0, 1, -1]], jnp.int32)
        qp = QueryPlan(sel_part=sel, sel_lo=jnp.zeros_like(sel),
                       sel_hi=jnp.zeros_like(sel),
                       node=jnp.zeros(1, jnp.int32),
                       pathlen=jnp.zeros(1, jnp.int32))
        got = int(candidates_scanned(qp, store)[0])
        want = int(store.count[0]) + int(store.count[1])
        assert got == want


# ----------------------------------------------------------------------
# refine_sharded ≡ refine on host CPU meshes (2 and 4 devices, ragged P)
# ----------------------------------------------------------------------
def _run_subprocess(body: str, n_dev: int, timeout: int = 420) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_dev}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == {n_dev}, jax.device_count()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


_SHARDED_REFINE_BODY = """
    from repro.core.index import PartitionStore
    from repro.core.refine import refine, refine_sharded
    from repro.distributed import shard_store
    from repro.launch.mesh import make_mesh

    # synthetic ragged store: P=%d partitions (not divisible by %d devices)
    rng = np.random.default_rng(0)
    P, cap, n, Q, MP, k = %d, 12, 32, 5, 9, 7
    data = rng.normal(size=(P, cap, n)).astype(np.float32)
    gid = np.arange(P * cap, dtype=np.int32).reshape(P, cap)
    gid[rng.random((P, cap)) < 0.25] = -1
    dfs = rng.integers(0, 50, size=(P, cap)).astype(np.int32)
    store = PartitionStore(
        data=jnp.asarray(data), norms=jnp.asarray((data ** 2).sum(-1)),
        rec_dfs=jnp.asarray(dfs), rec_gid=jnp.asarray(gid),
        count=jnp.asarray((gid >= 0).sum(1).astype(np.int32)))
    q = jnp.asarray(rng.normal(size=(Q, n)).astype(np.float32))
    sp = jnp.asarray(rng.integers(-1, P, size=(Q, MP)).astype(np.int32))
    lo = rng.integers(0, 40, size=(Q, MP)).astype(np.int32)
    hi = jnp.asarray(lo + rng.integers(0, 30, size=(Q, MP)).astype(np.int32))
    lo = jnp.asarray(lo)

    d1, g1 = refine(store, q, sp, lo, hi, k)
    mesh = make_mesh((%d,), ("data",))
    store_s = shard_store(store, mesh)
    assert store_s.num_partitions %% %d == 0
    d2, g2 = refine_sharded(store_s, q, sp, lo, hi, k, mesh=mesh)
    d3, g3 = refine_sharded(store, q, sp, lo, hi, k, mesh=mesh)  # lazy pad
    print(json.dumps({
        "gid_match": bool(np.array_equal(np.asarray(g1), np.asarray(g2))),
        "dist_match": bool(np.array_equal(np.asarray(d1), np.asarray(d2))),
        "lazy_pad_match": bool(np.array_equal(np.asarray(g2),
                                              np.asarray(g3))),
    }))
"""


@pytest.mark.parametrize("n_dev,P", [(2, 7), (4, 7), (4, 8)])
def test_refine_sharded_matches_refine(n_dev, P):
    out = _run_subprocess(
        _SHARDED_REFINE_BODY % (P, n_dev, P, n_dev, n_dev), n_dev)
    assert out["gid_match"], out
    assert out["dist_match"], out
    assert out["lazy_pad_match"], out


def test_engine_sharded_bit_identical():
    """Acceptance: 2-device sharded engine ≡ dense per-query knn_query."""
    out = _run_subprocess("""
        from repro.utils.config import ClimberConfig
        from repro.core import build_index, knn_query
        from repro.data import make_dataset, make_queries
        from repro.launch.mesh import make_mesh
        from repro.serve import ClimberEngine

        cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                            prefix_len=5, capacity=128, sample_frac=0.3,
                            max_centroids=12, k=10, candidate_groups=4,
                            adaptive_factor=4)
        data = make_dataset("randomwalk", jax.random.PRNGKey(0), 3000, 64)
        index = build_index(jax.random.PRNGKey(1), data, cfg)
        queries = np.asarray(make_queries(jax.random.PRNGKey(2), data, 9))

        mesh = make_mesh((2,), ("data",))
        ok_gid = ok_dist = True
        gid_adaptive = None
        for variant in ("knn", "adaptive", "od_smallest"):
            engine = ClimberEngine(index, batch_size=4, variant=variant,
                                   k=10, mesh=mesh)
            dist, gid, _ = engine.run(queries)
            if variant == "adaptive":
                gid_adaptive = gid
            for i in range(len(queries)):
                d1, g1, _ = knn_query(index, queries[i:i+1], 10,
                                      variant=variant)
                ok_gid &= bool(np.array_equal(np.asarray(g1)[0], gid[i]))
                ok_dist &= bool(np.array_equal(np.asarray(d1)[0], dist[i]))
        # use_kernel composes with the sharded path
        ek = ClimberEngine(index, batch_size=4, variant="adaptive", k=10,
                           mesh=mesh, use_kernel=True)
        dk, gk, _ = ek.run(queries[:4])
        ok_kernel = bool(np.array_equal(gk, gid_adaptive[:4]))
        print(json.dumps({"gid": ok_gid, "dist": ok_dist,
                          "kernel": ok_kernel}))
    """, n_dev=2)
    assert out["gid"] and out["dist"] and out["kernel"], out
