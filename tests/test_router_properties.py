"""Property tests for SignatureRouter.route / route_adaptive.

The routing mask is the accuracy-critical contract of the fleet: a wrong
row means a query silently skips the shard holding its true neighbours.
These tests pin the mask invariants over randomized score matrices —
``route``/``route_adaptive`` both accept a precomputed ``scores=`` matrix,
so no index build is needed and the properties run over thousands of
shapes.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.fleet.router import SignatureRouter
from repro.utils.config import ClimberConfig


def make_router(num_shards: int) -> SignatureRouter:
    """A router with ``num_shards`` registered dummy summaries (routing
    from explicit ``scores=`` never touches pivots or profiles)."""
    cfg = ClimberConfig(series_len=32, paa_segments=4, num_pivots=8,
                        prefix_len=3, capacity=64, sample_frac=0.5,
                        max_centroids=4, k=5)
    router = SignatureRouter(pivots=None, cfg=cfg)
    for i in range(num_shards):
        router.register(f"s{i}", np.zeros(8, np.float32))
    return router


def random_scores(rng: np.random.Generator, q: int, s: int) -> np.ndarray:
    return rng.standard_normal((q, s)).astype(np.float32)


class TestRouteProperties:
    @settings(max_examples=50)
    @given(st.integers(1, 12), st.integers(1, 8), st.integers(1, 15),
           st.integers(0, 10_000))
    def test_mask_shape_and_row_sums(self, q, s, fanout, seed):
        """[Q, S] boolean mask with exactly min(fanout, S) shards per row."""
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        mask = router.route(np.empty((q, 0)), fanout, scores=scores)
        assert mask.shape == (q, s) and mask.dtype == bool
        assert (mask.sum(axis=1) == min(fanout, s)).all()

    @settings(max_examples=25)
    @given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 10_000))
    def test_fanout_at_least_s_is_all_true(self, q, s, seed):
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        for fanout in (s, s + 1, s + 7):
            assert router.route(np.empty((q, 0)), fanout,
                                scores=scores).all()

    @settings(max_examples=50)
    @given(st.integers(1, 12), st.integers(1, 8), st.integers(1, 15),
           st.integers(0, 10_000))
    def test_top_fanout_selects_best_scores(self, q, s, fanout, seed):
        """Selected shards all score >= every unselected shard."""
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        mask = router.route(np.empty((q, 0)), fanout, scores=scores)
        for i in range(q):
            if mask[i].all():
                continue
            assert scores[i][mask[i]].min() >= scores[i][~mask[i]].max()

    def test_zero_shards(self):
        router = make_router(3)
        router.keys, router._summaries = [], []
        mask = router.route(np.empty((4, 0)), 2)
        assert mask.shape == (4, 0)


class TestRouteAdaptiveProperties:
    @settings(max_examples=50)
    @given(st.integers(1, 12), st.integers(1, 8),
           st.floats(0.0, 1.0), st.integers(0, 10_000))
    def test_superset_of_top1(self, q, s, threshold, seed):
        """Every query keeps at least its best-scoring shard, at any
        threshold — adaptive fan-out never routes to zero shards."""
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        mask = router.route_adaptive(np.empty((q, 0)), threshold,
                                     scores=scores)
        assert (mask.sum(axis=1) >= 1).all()
        rows = np.arange(q)
        assert mask[rows, scores.argmax(axis=1)].all()

    @settings(max_examples=50)
    @given(st.integers(1, 12), st.integers(2, 8),
           st.floats(0.0, 1.0), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    def test_monotone_in_threshold(self, q, s, th_a, th_b, seed):
        """A higher threshold can only widen each query's fan-out."""
        lo, hi = sorted((th_a, th_b))
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        m_lo = router.route_adaptive(np.empty((q, 0)), lo, scores=scores)
        m_hi = router.route_adaptive(np.empty((q, 0)), hi, scores=scores)
        assert (m_hi >= m_lo).all()

    @settings(max_examples=25)
    @given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 10_000))
    def test_threshold_zero_is_top1(self, q, s, seed):
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        mask = router.route_adaptive(np.empty((q, 0)), 0.0, scores=scores)
        assert (mask.sum(axis=1) == 1).all()
        top1 = router.route(np.empty((q, 0)), 1, scores=scores)
        # distinct scores ⇒ the same unique argmax shard (ties may differ
        # between argpartition and the stable adaptive order, so compare
        # only where the max is unique)
        unique = (scores == scores.max(axis=1, keepdims=True)).sum(axis=1) \
            == 1
        assert (mask[unique] == top1[unique]).all()

    @settings(max_examples=25)
    @given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 10_000))
    def test_threshold_one_is_exhaustive(self, q, s, seed):
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        assert router.route_adaptive(np.empty((q, 0)), 1.0,
                                     scores=scores).all()

    @settings(max_examples=25)
    @given(st.integers(1, 8), st.integers(2, 8), st.integers(1, 6),
           st.floats(0.0, 1.0), st.integers(0, 10_000))
    def test_max_fanout_caps_rows(self, q, s, cap, threshold, seed):
        router = make_router(s)
        scores = random_scores(np.random.default_rng(seed), q, s)
        mask = router.route_adaptive(np.empty((q, 0)), threshold,
                                     max_fanout=cap, scores=scores)
        assert (mask.sum(axis=1) <= cap).all()
        assert (mask.sum(axis=1) >= 1).all()

    def test_zero_shards(self):
        router = make_router(1)
        router.keys, router._summaries = [], []
        assert router.route_adaptive(np.empty((4, 0)), 0.5).shape == (4, 0)


class TestLearnThreshold:
    def test_concentrated_hits_learn_small_threshold(self):
        """When all true answers live in the top-scoring shard, a small
        threshold suffices and learn_threshold must not over-spend."""
        router = make_router(4)
        rng = np.random.default_rng(0)
        traces = []
        for _ in range(32):
            sc = rng.uniform(0.1, 0.3, size=4)
            best = rng.integers(4)
            sc[best] += 2.0                       # clear winner
            hits = np.zeros(4)
            hits[best] = 10                       # all answers in it
            traces.append((sc, hits))
        th = router.learn_threshold(traces, target_recall=0.95)
        assert th == router.threshold
        assert th < 0.5

    def test_scattered_hits_learn_large_threshold(self):
        """Uniformly scattered answers force a near-exhaustive threshold."""
        router = make_router(4)
        rng = np.random.default_rng(1)
        traces = [(rng.uniform(size=4), np.full(4, 5.0)) for _ in range(32)]
        th = router.learn_threshold(traces, target_recall=0.99)
        assert th > 0.5

    def test_no_usable_traces_defaults_to_exhaustive(self):
        router = make_router(3)
        th = router.learn_threshold([(np.ones(3), np.zeros(3))])
        assert th == 1.0
