"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles.

All kernels run in interpret mode on CPU (the exact TPU kernel bodies,
executed via the Pallas interpreter).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.l2 import pairwise_l2, qdots
from repro.kernels.paa_kernel import paa as paa_kernel
from repro.kernels.pivot_rank import pivot_rank

DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


class TestPairwiseL2:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("q,c,n", [
        (1, 1, 8), (7, 13, 32), (64, 200, 128), (33, 511, 256), (128, 512, 64),
    ])
    def test_sweep(self, q, c, n, dtype):
        kq, kx = jax.random.split(jax.random.PRNGKey(q * 1000 + c))
        a = _rand(kq, (q, n), dtype)
        b = _rand(kx, (c, n), dtype)
        got = pairwise_l2(a, b, block_q=32, block_c=64, interpret=True)
        want = ref.pairwise_l2_ref(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))

    def test_block_edges(self):
        # shapes exactly at, below and above the block boundary
        for q in (31, 32, 33):
            a = _rand(jax.random.PRNGKey(0), (q, 16), jnp.float32)
            b = _rand(jax.random.PRNGKey(1), (64, 16), jnp.float32)
            got = pairwise_l2(a, b, block_q=32, block_c=32, interpret=True)
            want = ref.pairwise_l2_ref(a, b)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4)


class TestQDots:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("q,c,n", [(1, 4, 8), (5, 37, 64), (16, 256, 128)])
    def test_sweep(self, q, c, n, dtype):
        kq, kr = jax.random.split(jax.random.PRNGKey(c))
        a = _rand(kq, (q, n), dtype)
        rows = _rand(kr, (q, c, n), dtype)
        got = qdots(a, rows, block_c=32, interpret=True)
        want = ref.qdots_ref(a, rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))

    def test_refine_path_matches_einsum(self):
        q = _rand(jax.random.PRNGKey(2), (4, 32), jnp.float32)
        rows = _rand(jax.random.PRNGKey(3), (4, 3, 17, 32), jnp.float32)
        got = ops.batched_query_dots(q, rows)
        want = jnp.einsum("qn,qmcn->qmc", q, rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestPAAKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("b,n,w", [
        (1, 16, 4), (100, 256, 16), (257, 128, 8), (64, 512, 32),
    ])
    def test_sweep(self, b, n, w, dtype):
        x = _rand(jax.random.PRNGKey(b), (b, n), dtype)
        got = paa_kernel(x, w, block_b=64, interpret=True)
        want = ref.paa_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))

    def test_matches_core_paa(self):
        from repro.core import paa as core_paa
        x = _rand(jax.random.PRNGKey(9), (50, 128), jnp.float32)
        np.testing.assert_allclose(np.asarray(paa_kernel(x, 16, interpret=True)),
                                   np.asarray(core_paa(x, 16)),
                                   rtol=1e-5, atol=1e-6)


class TestPivotRank:
    @pytest.mark.parametrize("dtype", [jnp.float32])
    @pytest.mark.parametrize("b,r,w,m", [
        (1, 8, 4, 3), (33, 48, 16, 6), (128, 200, 16, 10), (64, 100, 8, 20),
    ])
    def test_sweep(self, b, r, w, m, dtype):
        kx, kp = jax.random.split(jax.random.PRNGKey(b * 7 + r))
        x = _rand(kx, (b, w), dtype)
        p = _rand(kp, (r, w), dtype)
        got = pivot_rank(x, p, m, block_b=32, interpret=True)
        want = ref.pivot_rank_ref(x, p, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_core_rank_signature(self):
        from repro.core import rank_signature
        x = _rand(jax.random.PRNGKey(4), (64, 16), jnp.float32)
        p = _rand(jax.random.PRNGKey(5), (48, 16), jnp.float32)
        got = pivot_rank(x, p, 6, interpret=True)
        want = rank_signature(x, p, 6)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_duplicate_pivot_tiebreak(self):
        """Two identical pivots: lower id must win, matching top_k."""
        x = jnp.zeros((4, 8), jnp.float32)
        p = jnp.ones((6, 8), jnp.float32)
        got = np.asarray(pivot_rank(x, p, 3, interpret=True))
        np.testing.assert_array_equal(got, np.tile([0, 1, 2], (4, 1)))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 50), st.integers(1, 6))
def test_property_l2_nonnegative_and_symmetric_diag(q, c, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (q, 16))
    b = jax.random.normal(jax.random.PRNGKey(seed + 99), (c, 16))
    d = np.asarray(pairwise_l2(a, b, block_q=16, block_c=16, interpret=True))
    assert np.all(d >= 0.0)
    d_self = np.asarray(pairwise_l2(a, a, block_q=16, block_c=16, interpret=True))
    assert np.all(np.abs(np.diag(d_self)) < 1e-3)
