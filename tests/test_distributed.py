"""Multi-device integration tests.

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the parent process already initialised jax with 1 device).  They exercise
real SPMD semantics: sharded train steps match single-device training,
sharded CLIMBER queries match local queries, checkpoints reshard elastically,
and the compressed cross-pod all-reduce preserves gradient direction.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_subprocess(body: str, timeout: int = 420) -> dict:
    """Run `body` (which must print a final JSON line) on 8 host devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8, jax.device_count()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


class TestShardedTraining:
    def test_sharded_step_matches_local(self):
        out = run_subprocess("""
            from repro.configs import get_config
            from repro.models import Model
            from repro.train.optimizer import AdamW, constant_lr
            from repro.train.train_step import make_train_step, shard_train_step
            from repro.launch.mesh import make_mesh
            from repro.data.tokens import TokenPipeline

            cfg = get_config("internlm2-1.8b", smoke=True)
            pipe = TokenPipeline(cfg, 8, 32, seed=1)
            batch = pipe.batch_at(0)
            opt = AdamW(lr=constant_lr(1e-3))

            # local (single-logical-device semantics)
            model_l = Model(cfg)
            params = model_l.init(jax.random.PRNGKey(0))
            state = opt.init(params)
            fn_l = jax.jit(make_train_step(model_l, opt, kv_chunk=32))
            p1, s1, m1 = fn_l(params, state, batch)

            # sharded on a (4, 2) mesh
            mesh = make_mesh((4, 2), ("data", "model"))
            model_s = Model(cfg, mesh=mesh, batch_axes=("data",))
            shapes = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
            fn_s, (psh, osh, bsh) = shard_train_step(
                model_s, opt, mesh, shapes, kv_chunk=32, donate=False)
            params_s = jax.device_put(params, psh)
            state_s = jax.device_put(state, osh)
            batch_s = jax.device_put(batch, bsh)
            p2, s2, m2 = fn_s(params_s, state_s, batch_s)

            d = abs(float(m1["loss"]) - float(m2["loss"]))
            # compare a couple of updated weights
            w1 = np.asarray(p1["embed"]["out"], np.float32)
            w2 = np.asarray(jax.device_get(p2["embed"]["out"]), np.float32)
            print(json.dumps({
                "loss_delta": d,
                "w_delta": float(np.max(np.abs(w1 - w2))),
                "loss": float(m1["loss"]),
            }))
        """)
        assert out["loss_delta"] < 5e-2, out
        assert out["w_delta"] < 5e-2, out

    def test_microbatched_matches_plain(self):
        out = run_subprocess("""
            from repro.configs import get_config
            from repro.models import Model
            from repro.train.optimizer import AdamW, constant_lr
            from repro.train.train_step import make_train_step
            from repro.data.tokens import TokenPipeline

            cfg = get_config("mamba2-780m", smoke=True)
            pipe = TokenPipeline(cfg, 8, 32, seed=2)
            batch = pipe.batch_at(0)
            opt = AdamW(lr=constant_lr(1e-3))
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            state = opt.init(params)
            f1 = jax.jit(make_train_step(model, opt, kv_chunk=32))
            f4 = jax.jit(make_train_step(model, opt, kv_chunk=32,
                                         microbatches=4))
            _, _, m1 = f1(params, state, batch)
            _, _, m4 = f4(params, state, batch)
            print(json.dumps({"l1": float(m1["loss"]),
                              "l4": float(m4["loss"])}))
        """)
        assert abs(out["l1"] - out["l4"]) < 5e-2, out


class TestShardedClimber:
    def test_sharded_refine_matches_local(self):
        out = run_subprocess("""
            from repro.utils.config import ClimberConfig
            from repro.core import build_index, knn_query, plan_adaptive
            from repro.core.refine import refine, refine_sharded
            from repro.data import make_dataset, make_queries
            from repro.launch.mesh import make_mesh
            from jax.sharding import NamedSharding, PartitionSpec as P

            cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                                prefix_len=5, capacity=128, sample_frac=0.3,
                                max_centroids=12, k=10, candidate_groups=4)
            data = make_dataset("randomwalk", jax.random.PRNGKey(0), 4000, 64)
            index = build_index(jax.random.PRNGKey(1), data, cfg)
            q = make_queries(jax.random.PRNGKey(2), data, 8)

            dist_l, gid_l, plan = knn_query(index, q, 10)

            mesh = make_mesh((8,), ("data",))
            # pad partitions to a multiple of 8 and shard the store
            import jax.numpy as jnp
            store = index.store
            P_total = store.num_partitions
            pad = (-P_total) % 8
            def padp(x):
                return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
            from repro.core.index import PartitionStore
            store_p = PartitionStore(*[padp(getattr(store, f))
                                       for f in store._fields])
            sh = NamedSharding(mesh, P("data"))
            store_s = PartitionStore(*[jax.device_put(x, sh) for x in store_p])
            p4r_q, _ = index.featurize(q)
            plan = plan_adaptive(index, p4r_q)
            dist_s, gid_s = refine_sharded(
                store_s, q, plan.sel_part, plan.sel_lo, plan.sel_hi, 10,
                mesh=mesh)
            match = float((np.sort(np.asarray(gid_l), -1)
                           == np.sort(np.asarray(gid_s), -1)).mean())
            print(json.dumps({"match": match}))
        """)
        assert out["match"] > 0.99, out

    def test_sharded_exact_scan_matches(self):
        out = run_subprocess("""
            from repro.baselines import exact_knn, exact_knn_sharded
            from repro.data import make_dataset
            from repro.launch.mesh import make_mesh

            data = make_dataset("sift", jax.random.PRNGKey(0), 4096, 64)
            q = data[:6]
            d1, i1 = exact_knn(q, data, 9)
            mesh = make_mesh((8,), ("data",))
            d2, i2 = exact_knn_sharded(q, data, 9, mesh=mesh)
            same = all(set(np.asarray(a)) == set(np.asarray(b))
                       for a, b in zip(i1, i2))
            print(json.dumps({"same": bool(same)}))
        """)
        assert out["same"], out


class TestElasticity:
    def test_checkpoint_reshards_to_smaller_mesh(self):
        out = run_subprocess("""
            import tempfile
            from repro.configs import get_config
            from repro.models import Model
            from repro.train.checkpoint import save_checkpoint, restore_checkpoint
            from repro.train.train_step import make_state_shardings
            from repro.train.optimizer import AdamW, constant_lr
            from repro.launch.mesh import make_mesh

            cfg = get_config("internlm2-1.8b", smoke=True)
            opt = AdamW(lr=constant_lr(1e-3))

            mesh8 = make_mesh((4, 2), ("data", "model"))
            model8 = Model(cfg, mesh=mesh8, batch_axes=("data",))
            psh8, _ = make_state_shardings(mesh8, model8)
            params = jax.device_put(model8.init(jax.random.PRNGKey(0)), psh8)

            with tempfile.TemporaryDirectory() as d:
                save_checkpoint(d, 3, params)
                # "pod loss": bring up a (2, 2) mesh — 4 surviving devices
                mesh4 = make_mesh((2, 2), ("data", "model"))
                model4 = Model(cfg, mesh=mesh4, batch_axes=("data",))
                psh4, _ = make_state_shardings(mesh4, model4)
                restored, step, _ = restore_checkpoint(d, params,
                                                       shardings=psh4)
                w0 = np.asarray(jax.device_get(params["embed"]["tok"]),
                                np.float32)
                w1 = np.asarray(jax.device_get(restored["embed"]["tok"]),
                                np.float32)
                ok = bool(np.array_equal(w0, w1)) and step == 3
                nshards = len(restored["embed"]["tok"].sharding.device_set)
            print(json.dumps({"ok": ok, "devices": nshards}))
        """)
        assert out["ok"] and out["devices"] == 4, out


class TestCompressedAllReduce:
    def test_cross_pod_ef_allreduce(self):
        out = run_subprocess("""
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import (ef_allreduce_tree,
                                                       init_error_tree)
            from repro.launch.mesh import make_mesh

            mesh = make_mesh((8,), ("pod",))
            g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
            true_mean = np.asarray(g_global).mean(0)

            def f(g, e):
                return ef_allreduce_tree({"w": g}, {"w": e}, "pod")

            fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                           out_specs=(P("pod"), P("pod")), check_rep=False)
            red, err = fn(g_global, jnp.zeros((8, 256)))
            got = np.asarray(red["w"])[0]
            rel = float(np.abs(got - true_mean).max()
                        / (np.abs(true_mean).max() + 1e-9))
            print(json.dumps({"rel_err": rel}))
        """)
        assert out["rel_err"] < 0.05, out
