"""Online recall sentinel acceptance tests.

The contracts from the issue:
  * **bit-identity**: enabling shadow sampling changes NOTHING about the
    answers the fleet serves — dist and gid are bit-identical with the
    sentinel on or off;
  * the sentinel's online recall estimate lands within ±0.05 of the
    offline evaluation harness's recall for the same routing config;
  * audits feed ``audit_routing(record=True)``-style traces into
    ``fleet.routing_traces`` so ``calibrate_routing`` can re-learn the
    adaptive threshold from production traffic;
  * sampling is bounded (never backpressure) and stale samples — fleet
    contents moved between serve and audit — are discarded, not
    mis-scored;
  * the ``fleet.online_recall`` gauge exports as
    ``repro_fleet_online_recall``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_dataset, make_queries
from repro.eval.metrics import recall_at_k
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.obs import REGISTRY, RecallSentinel, to_prometheus
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


def make_fleet(data: np.ndarray) -> IndexFleet:
    fleet = IndexFleet(FleetConfig(shard_cfg=small_cfg(), fanout=2,
                                   delta_capacity=4096, auto_compact=False))
    for i in range(2):
        fleet.add_shard(f"tenant{i}", data[i * 600:(i + 1) * 600])
    return fleet


@pytest.fixture(scope="module")
def corpus():
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1200, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 32))
    return data, queries


class TestBitIdentity:
    def test_sampling_never_changes_served_answers(self, corpus):
        data, queries = corpus
        plain = make_fleet(data)
        watched = make_fleet(data)
        sentinel = RecallSentinel(watched, sample_rate=1.0, seed=3,
                                  registry=None)
        for routing in ("signature", "adaptive", "exhaustive"):
            d0, g0, _ = plain.query(queries, k=K, routing=routing)
            d1, g1, _ = watched.query(queries, k=K, routing=routing)
            np.testing.assert_array_equal(d0, d1)
            np.testing.assert_array_equal(g0, g1)
        assert sentinel.pending() > 0    # it did sample — just passively

    def test_attaching_mid_stream_is_invisible(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        d0, g0, _ = fleet.query(queries, k=K, routing="signature")
        RecallSentinel(fleet, sample_rate=1.0, registry=None)
        d1, g1, _ = fleet.query(queries, k=K, routing="signature")
        np.testing.assert_array_equal(d0, d1)
        np.testing.assert_array_equal(g0, g1)


class TestOnlineRecall:
    def test_matches_offline_eval_within_tolerance(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, seed=7,
                                  registry=None)
        dist, gid, _ = fleet.query(queries, k=K, routing="signature")
        audited = sentinel.drain()
        assert audited == len(queries)   # rate 1.0: every query sampled
        # offline harness: the same served answers against the same
        # exhaustive ground truth, scored with the same tie-aware metric
        exact_d, exact_g = fleet.scan_exact(queries, K)
        offline = recall_at_k(gid, exact_g, K, approx_dist=dist,
                              exact_dist=exact_d)
        assert abs(sentinel.online_recall - offline) <= 0.05
        snap = sentinel.snapshot()
        assert snap["audits"] == len(queries)
        assert snap["pending"] == 0

    def test_gauge_exports_as_repro_fleet_online_recall(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, seed=1)
        fleet.query(queries[:8], k=K, routing="signature")
        sentinel.drain()
        page = to_prometheus(REGISTRY)
        assert "repro_fleet_online_recall" in page
        assert "repro_sentinel_audits_total" in page

    def test_worker_thread_drains(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, seed=2,
                                  registry=None)
        fleet.query(queries[:8], k=K, routing="signature")
        sentinel.start(interval_s=0.01)
        try:
            deadline = 30.0
            import time
            t0 = time.time()
            while sentinel.pending() and time.time() - t0 < deadline:
                time.sleep(0.02)
        finally:
            sentinel.stop()
        assert sentinel.pending() == 0
        assert sentinel.snapshot()["audits"] == 8


class TestBoundsAndStaleness:
    def test_pending_is_bounded(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, max_pending=16,
                                  registry=None)
        for _ in range(3):
            fleet.query(queries, k=K, routing="signature")
        assert sentinel.pending() == 16  # oldest dropped, never grows

    def test_stale_samples_are_discarded(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, registry=None)
        fleet.query(queries[:8], k=K, routing="signature")
        assert sentinel.pending() == 8
        fleet.insert(data[:4])           # contents moved since serve time
        assert sentinel.drain() == 0     # all stale: discarded, not scored
        assert sentinel.pending() == 0
        assert sentinel.online_recall == 1.0   # no evidence recorded

    def test_rate_zero_never_samples(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=0.0, registry=None)
        fleet.query(queries, k=K, routing="signature")
        assert sentinel.pending() == 0
        with pytest.raises(ValueError):
            RecallSentinel(make_fleet(data), sample_rate=1.5,
                           registry=None)


class TestRoutingFeedback:
    def test_audits_feed_routing_traces(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0, registry=None)
        assert not fleet.routing_traces
        fleet.query(queries[:8], k=K, routing="signature")
        sentinel.drain()
        assert len(fleet.routing_traces) == 8
        scores, hits = fleet.routing_traces[0]
        assert scores.shape == (len(fleet.shards),)
        assert hits.shape == (len(fleet.shards),)
        assert hits.sum() <= K           # per-shard true-hit counts
        # the traces are calibrate_routing fuel
        threshold = fleet.calibrate_routing(0.9)
        assert threshold == fleet.router.threshold

    def test_recalibrate_every_relearns_threshold(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        sentinel = RecallSentinel(fleet, sample_rate=1.0,
                                  recalibrate_every=8, target_recall=0.9,
                                  registry=None)
        fleet.query(queries[:16], k=K, routing="signature")
        sentinel.drain()
        assert sentinel.last_threshold is not None
        assert fleet.router.threshold == sentinel.last_threshold


class TestEngineWiring:
    def test_serving_config_enables_sentinel(self, corpus):
        data, queries = corpus
        fleet = make_fleet(data)
        engine = FleetEngine(fleet, batch_size=4, sentinel_rate=1.0,
                             sentinel_recalibrate_every=4)
        assert engine.sentinel is not None
        assert fleet.sentinel is engine.sentinel
        assert engine.sentinel.recalibrate_every == 4
        fleet.query(queries[:8], k=K, routing="signature")
        before = engine.sentinel.pending()
        assert before == 8
        engine._after_tick()             # the serving loop's drain hook
        assert engine.sentinel.pending() < before

    def test_disabled_by_default(self, corpus):
        data, _ = corpus
        engine = FleetEngine(make_fleet(data), batch_size=4)
        assert engine.sentinel is None
