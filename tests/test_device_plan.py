"""Stacked-trie device planning — parity and cache invalidation tests.

The contract under test (``repro.fleet.device_plan`` + the fused mesh
query pass): stacking ragged per-shard trie skeletons into one padded
``[S_pad, ...]`` table set changes *nothing* — descent over the stacked
tables is row-for-row identical to per-shard host descent (including
edgeless tries, ragged node counts and inert pad shards), device plans
reproduce the host planner bit-for-bit, and the fleet's epoch-keyed plan
cache can never replay a plan across a shard-set change.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrieDevice, build_forest, descend
from repro.core.query import knn_query
from repro.core.refine import PAD_DIST, merge_topk
from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.fleet.device_plan import descend_stacked, stack_tries, trie_row
from repro.launch.mesh import make_mesh
from repro.utils.config import ClimberConfig

K = 10


def _random_forest(seed: int, *, rows: int, num_groups: int, m: int, r: int,
                   capacity: float):
    """A small random TrieForest plus the signatures/groups that built it."""
    rng = np.random.default_rng(seed)
    sigs = np.stack([rng.choice(r, m, replace=False)
                     for _ in range(rows)]).astype(np.int32)
    freqs = rng.integers(1, 20, size=rows)
    groups = rng.integers(0, num_groups, size=rows)
    forest = build_forest(sigs, freqs, groups, num_groups, r,
                          capacity=capacity, sample_frac=1.0)
    return forest, sigs, groups


# ----------------------------------------------------------------------
# stack_tries + descend_stacked ≡ per-shard host descent
# ----------------------------------------------------------------------
class TestStackedDescentParity:
    def test_ragged_shards_match_per_shard_descent(self):
        # deliberately ragged: different row counts, group counts and
        # capacities => different node/edge/partition-list shapes per shard
        m, r = 4, 12
        specs = [(11, 150, 3, 60.0), (12, 40, 2, 25.0), (13, 260, 4, 90.0)]
        forests, sig_l, grp_l = [], [], []
        for seed, rows, g, cap in specs:
            f, s, gr = _random_forest(seed, rows=rows, num_groups=g,
                                      m=m, r=r, capacity=cap)
            forests.append(f)
            sig_l.append(s)
            grp_l.append(gr)
        tries = [TrieDevice.from_forest(f) for f in forests]
        tables = stack_tries(tries)
        assert tables.num_slots == 3
        q = min(len(s) for s in sig_l)
        p4 = jnp.stack([jnp.asarray(s[:q]) for s in sig_l])
        grp = jnp.stack([jnp.asarray(g[:q]) for g in grp_l])
        node_s, plen_s, par_s = descend_stacked(tables, p4, grp,
                                                num_pivots=r)
        for j, t in enumerate(tries):
            node, plen, par = descend(t, p4[j], grp[j])
            np.testing.assert_array_equal(np.asarray(node_s[j]),
                                          np.asarray(node))
            np.testing.assert_array_equal(np.asarray(plen_s[j]),
                                          np.asarray(plen))
            np.testing.assert_array_equal(np.asarray(par_s[j]),
                                          np.asarray(par))

    def test_edgeless_trie_stacks_and_stays_at_root(self):
        m, r = 4, 12
        # huge capacity => every entry fits the root, no splits, no edges
        flat, sigs, grps = _random_forest(3, rows=30, num_groups=2,
                                          m=m, r=r, capacity=1e9)
        deep, dsig, dgrp = _random_forest(4, rows=200, num_groups=3,
                                          m=m, r=r, capacity=40.0)
        t_flat, t_deep = TrieDevice.from_forest(flat), \
            TrieDevice.from_forest(deep)
        assert int(t_flat.edge_key.shape[0]) == 0
        tables = stack_tries([t_flat, t_deep])
        q = 30
        p4 = jnp.stack([jnp.asarray(sigs[:q]), jnp.asarray(dsig[:q])])
        grp = jnp.stack([jnp.asarray(grps[:q]) % 2,
                         jnp.asarray(dgrp[:q])])
        node_s, plen_s, _ = descend_stacked(tables, p4, grp, num_pivots=r)
        # edgeless shard: everyone stays at its group root, pathlen 0
        roots = np.asarray(t_flat.group_root)[np.asarray(grp[0])]
        np.testing.assert_array_equal(np.asarray(node_s[0]), roots)
        assert not np.asarray(plen_s[0]).any()
        # the deep shard is untouched by riding next to an edgeless one
        node, plen, _ = descend(t_deep, p4[1], grp[1])
        np.testing.assert_array_equal(np.asarray(node_s[1]),
                                      np.asarray(node))
        np.testing.assert_array_equal(np.asarray(plen_s[1]),
                                      np.asarray(plen))

    def test_pad_shards_are_inert(self):
        m, r = 4, 12
        f, sigs, grps = _random_forest(5, rows=120, num_groups=3,
                                       m=m, r=r, capacity=50.0)
        trie = TrieDevice.from_forest(f)
        tables = stack_tries([trie] * 3, pad_to=4)   # S=3, S % n_dev != 0
        assert tables.num_slots == 4
        # pad-shard bookkeeping: 1 fallback group, 0 partitions
        np.testing.assert_array_equal(np.asarray(tables.num_groups),
                                      [3, 3, 3, 1])
        np.testing.assert_array_equal(np.asarray(tables.num_partitions),
                                      [f.num_partitions] * 3 + [0])
        q = 40
        p4 = jnp.broadcast_to(jnp.asarray(sigs[:q]), (4, q, m))
        grp = jnp.broadcast_to(jnp.asarray(grps[:q]), (4, q))
        node_s, plen_s, _ = descend_stacked(tables, p4, grp, num_pivots=r)
        # pad row: every signature lands on the inert node and matches no
        # edge; the inert node has no partitions and size 0
        inert = int(tables.has_children.shape[1]) - 1
        np.testing.assert_array_equal(np.asarray(node_s[3]),
                                      np.full(q, inert))
        assert not np.asarray(plen_s[3]).any()
        pad_view = trie_row(tables, 3, num_pivots=r)
        assert not np.asarray(pad_view.has_children[inert])
        assert float(pad_view.node_size[inert]) == 0.0
        assert np.all(np.asarray(pad_view.part_ids_pad[inert]) == -1)

    def test_stack_tries_validation(self):
        m, r = 4, 12
        f, *_ = _random_forest(6, rows=50, num_groups=2, m=m, r=r,
                               capacity=30.0)
        trie = TrieDevice.from_forest(f)
        with pytest.raises(ValueError):
            stack_tries([])
        with pytest.raises(ValueError):
            stack_tries([trie, trie], pad_to=1)
        other = trie._replace(num_pivots=r + 1)
        with pytest.raises(ValueError):
            stack_tries([trie, other])


# ----------------------------------------------------------------------
# fused mesh pass: masked plan rows + epoch-keyed cache
# ----------------------------------------------------------------------
def _small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


@pytest.fixture(scope="module")
def small_fleet():
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1800, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 5))
    fleet = IndexFleet(FleetConfig(shard_cfg=_small_cfg(), fanout=2,
                                   auto_compact=False))
    for i in range(3):
        fleet.add_shard(f"t{i}", data[i * 600: (i + 1) * 600])
    return fleet, data, queries


class TestFusedMeshPass:
    def test_all_masked_plan_rows(self, small_fleet):
        """Unrouted queries/shards: the device plan masks to -1 rows and
        the answer is exactly the host merge over the routed pairs."""
        fleet, data, queries = small_fleet
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            pl = fleet._ensure_placement()
            assert pl.supports_device_planning("adaptive")
            qn = len(queries)
            routed = np.zeros((pl.num_slots, qn), dtype=bool)
            routed[0, 1:] = True        # query 0: routed nowhere at all
            routed[1, 1:] = True        # shard 2: no queries at all
            d, g, sp, lo, hi, pt, sc = pl.query(queries, routed, K,
                                                variant="adaptive")
            # fully-unrouted query: pure PAD row
            assert np.all(d[0] == np.float32(PAD_DIST))
            assert np.all(g[0] == -1)
            # host oracle over the same mask
            bd = np.full((qn, K), PAD_DIST, np.float32)
            bg = np.full((qn, K), -1, np.int32)
            for si in (0, 1):
                qsel = np.nonzero(routed[si])[0]
                dist, gid, qp = knn_query(fleet.shards[si].index,
                                          jnp.asarray(queries[qsel]), K,
                                          variant="adaptive")
                gg = np.where(np.asarray(gid) >= 0,
                              fleet.shards[si].global_ids[
                                  np.maximum(np.asarray(gid), 0)],
                              -1).astype(np.int32)
                md, mg = merge_topk(jnp.asarray(bd[qsel]),
                                    jnp.asarray(bg[qsel]),
                                    jnp.asarray(dist), jnp.asarray(gg), K)
                bd[qsel], bg[qsel] = np.asarray(md), np.asarray(mg)
                # the unmasked metrics rows reproduce the host plan's
                np.testing.assert_array_equal(
                    pt[si][qsel],
                    np.asarray(qp.partitions_touched(), np.int64))
            np.testing.assert_array_equal(d, bd)
            np.testing.assert_array_equal(g, bg)
        finally:
            fleet._placement = None
            fleet.mesh = None

    def test_plan_cache_hits_and_epoch_invalidation(self, small_fleet):
        fleet, data, queries = small_fleet
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            d0, g0, i0 = fleet.query(queries, K, placement="mesh")
            assert i0.plan_cache_misses == len(queries)
            assert i0.plan_cache_hits == 0
            d1, g1, i1 = fleet.query(queries, K, placement="mesh")
            assert i1.plan_cache_hits == len(queries)
            assert i1.plan_cache_misses == 0
            np.testing.assert_array_equal(d0, d1)
            np.testing.assert_array_equal(g0, g1)
            # shard-set change bumps the epoch: stale entries unreachable
            epoch0 = fleet._placement_epoch
            fleet.add_shard("t3", data[:600] * 0.5 + 1.0)
            assert fleet._placement_epoch > epoch0
            d2, g2, i2 = fleet.query(queries, K, placement="mesh")
            assert i2.plan_cache_hits == 0
            assert i2.plan_cache_misses == len(queries)
            dh, gh, _ = fleet.query(queries, K, placement="host")
            np.testing.assert_array_equal(d2, dh)
            np.testing.assert_array_equal(g2, gh)
        finally:
            fleet.shards = [s for s in fleet.shards if s.key != "t3"]
            if fleet.router is not None:
                fleet.router.replace_span(3, 1)
            fleet._invalidate_placement()
            fleet.mesh = None

    def test_fleet_engine_surfaces_cache_stats(self, small_fleet):
        fleet, data, queries = small_fleet
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            engine = FleetEngine(fleet, batch_size=len(queries), k=K,
                                 placement="mesh")
            engine.run(queries)
            assert engine.stats.plan_cache_misses >= len(queries)
            h0 = engine.stats.plan_cache_hits
            engine.run(queries)
            assert engine.stats.plan_cache_hits >= h0 + len(queries)
            assert 0.0 < engine.stats.plan_cache_hit_rate < 1.0
        finally:
            fleet._invalidate_placement()
            fleet.mesh = None
