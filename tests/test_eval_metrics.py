"""Recall-metric correctness: hand-computed fixtures, tie handling at the
distance boundary, pad-sentinel exclusion, and ground-truth cache keying."""
import numpy as np
import pytest

from repro.core.refine import PAD_DIST
from repro.eval.ground_truth import GroundTruthCache
from repro.eval.metrics import (frontier_auc, mean_average_precision,
                                recall_at_k)


class TestRecallAtK:
    def test_hand_computed(self):
        exact = np.array([[1, 2, 3, 4], [10, 11, 12, 13]])
        approx = np.array([[1, 2, 9, 8], [10, 11, 12, 13]])
        # query 0: 2/4 hits; query 1: 4/4 → mean 0.75
        assert recall_at_k(approx, exact) == pytest.approx(0.75)

    def test_k_prefix(self):
        exact = np.array([[1, 2, 3, 4]])
        approx = np.array([[1, 9, 3, 4]])
        # only the first 2 columns: truth {1,2}, got {1,9} → 0.5
        assert recall_at_k(approx, exact, k=2) == pytest.approx(0.5)

    def test_pad_rows_excluded(self):
        """gid=-1 pad slots count neither as hits nor as truth."""
        exact = np.array([[1, 2, -1, -1]])
        approx = np.array([[1, -1, -1, -1]])
        # truth {1,2}, got {1} → 0.5 (pads on both sides ignored)
        assert recall_at_k(approx, exact) == pytest.approx(0.5)

    def test_all_pad_truth_skipped(self):
        exact = np.array([[-1, -1], [1, 2]])
        approx = np.array([[-1, -1], [1, 2]])
        assert recall_at_k(approx, exact) == pytest.approx(1.0)

    def test_tie_at_boundary_counts_as_hit(self):
        """An id outside the oracle set but at the k-th distance is a hit:
        the oracle's pick among equidistant records is arbitrary."""
        exact_ids = np.array([[5, 6]])
        exact_dist = np.array([[1.0, 2.0]])
        approx_ids = np.array([[5, 7]])          # 7 ties the boundary
        approx_dist = np.array([[1.0, 2.0]])
        assert recall_at_k(approx_ids, exact_ids) == pytest.approx(0.5)
        assert recall_at_k(approx_ids, exact_ids,
                           approx_dist=approx_dist,
                           exact_dist=exact_dist) == pytest.approx(1.0)

    def test_beyond_boundary_is_a_miss(self):
        exact_ids = np.array([[5, 6]])
        exact_dist = np.array([[1.0, 2.0]])
        approx_ids = np.array([[5, 7]])
        approx_dist = np.array([[1.0, 2.5]])     # strictly worse: miss
        assert recall_at_k(approx_ids, exact_ids,
                           approx_dist=approx_dist,
                           exact_dist=exact_dist) == pytest.approx(0.5)

    def test_pad_dist_sentinel_rows_excluded_with_ties(self):
        """PAD_DIST-carrying pad slots must not ride the tie rule."""
        exact_ids = np.array([[5, 6]])
        exact_dist = np.array([[1.0, 2.0]])
        approx_ids = np.array([[5, -1]])
        approx_dist = np.array([[1.0, PAD_DIST]])
        assert recall_at_k(approx_ids, exact_ids,
                           approx_dist=approx_dist,
                           exact_dist=exact_dist) == pytest.approx(0.5)

    def test_tie_hits_capped_at_truth_size(self):
        """All-tied answers can't push recall above 1.0."""
        exact_ids = np.array([[5, 6]])
        exact_dist = np.array([[2.0, 2.0]])
        approx_ids = np.array([[7, 8]])          # both tie the boundary
        approx_dist = np.array([[2.0, 2.0]])
        assert recall_at_k(approx_ids, exact_ids,
                           approx_dist=approx_dist,
                           exact_dist=exact_dist) == pytest.approx(1.0)


class TestMeanAveragePrecision:
    def test_perfect_ranking(self):
        exact = np.array([[1, 2, 3]])
        assert mean_average_precision(exact, exact) == pytest.approx(1.0)

    def test_hand_computed(self):
        exact = np.array([[1, 2, 3]])
        approx = np.array([[9, 1, 2]])
        # hits at ranks 2, 3: AP = (1/2 + 2/3) / 3
        expected = (0.5 + 2.0 / 3.0) / 3.0
        assert mean_average_precision(approx, exact) \
            == pytest.approx(expected)

    def test_order_sensitivity(self):
        """Same set, true neighbours ranked later → lower MAP."""
        exact = np.array([[1, 2]])
        early = np.array([[1, 2, 8, 9]])
        late = np.array([[8, 9, 1, 2]])
        assert mean_average_precision(early, exact, k=4) \
            > mean_average_precision(late, exact, k=4)

    def test_pad_slots_do_not_occupy_ranks(self):
        exact = np.array([[1, 2]])
        padded = np.array([[-1, 1, 2]])
        clean = np.array([[1, 2, -1]])
        assert mean_average_precision(padded, exact, k=3) \
            == pytest.approx(mean_average_precision(clean, exact, k=3))


class TestFrontierAuc:
    def test_empty(self):
        assert frontier_auc([]) == 0.0

    def test_single_point_holds_to_one(self):
        assert frontier_auc([(0.5, 0.8)]) == pytest.approx(0.8)

    def test_perfect_cheap_frontier(self):
        assert frontier_auc([(0.1, 1.0), (1.0, 1.0)]) == pytest.approx(1.0)

    def test_higher_curve_higher_auc(self):
        low = [(0.2, 0.4), (0.6, 0.6), (1.0, 0.7)]
        high = [(0.2, 0.6), (0.6, 0.8), (1.0, 0.9)]
        assert frontier_auc(high) > frontier_auc(low)

    def test_dedup_keeps_best_recall(self):
        assert frontier_auc([(0.5, 0.2), (0.5, 0.9)]) == pytest.approx(0.9)


class TestGroundTruthCache:
    def test_roundtrip_and_hit_accounting(self, tmp_path):
        cache = GroundTruthCache(tmp_path)
        rng = np.random.default_rng(0)
        data = rng.standard_normal((50, 16)).astype(np.float32)
        queries = data[:4] + 0.01
        meta = {"name": "unit", "seed": 0}
        d1, i1 = cache.exact(meta, queries, data, 3)
        assert cache.misses == 1 and cache.hits == 0
        d2, i2 = cache.exact(meta, queries, data, 3)
        assert cache.hits == 1
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_allclose(d1, d2)

    def test_seed_change_invalidates(self, tmp_path):
        """A different dataset seed must miss — never serve stale truth."""
        cache = GroundTruthCache(tmp_path)
        rng = np.random.default_rng(0)
        data = rng.standard_normal((50, 16)).astype(np.float32)
        queries = data[:4]
        cache.exact({"name": "unit", "seed": 0}, queries, data, 3)
        cache.exact({"name": "unit", "seed": 1}, queries, data, 3)
        assert cache.misses == 2 and cache.hits == 0
        assert GroundTruthCache.key_for({"name": "unit", "seed": 0}) \
            != GroundTruthCache.key_for({"name": "unit", "seed": 1})

    def test_k_is_part_of_the_key(self, tmp_path):
        cache = GroundTruthCache(tmp_path)
        rng = np.random.default_rng(0)
        data = rng.standard_normal((50, 16)).astype(np.float32)
        queries = data[:4]
        cache.exact({"name": "unit"}, queries, data, 3)
        d, i = cache.exact({"name": "unit"}, queries, data, 5)
        assert cache.misses == 2
        assert i.shape == (4, 5)

    def test_key_is_order_insensitive(self):
        a = GroundTruthCache.key_for({"x": 1, "y": 2})
        b = GroundTruthCache.key_for({"y": 2, "x": 1})
        assert a == b
