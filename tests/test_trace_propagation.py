"""End-to-end trace propagation, flight recorder and admin plane tests.

The contracts from the issue:
  * ONE distributed trace per client call: a localhost query's server
    spans (``net.admit`` → ``serve.tick`` → ``fleet.query`` → per-shard
    stages) all carry the client-minted ``trace_id``, and the client's
    own ``net.rtt`` span carries the same id — across threads and a real
    socket;
  * executor-thread tick spans adopt the *admitting* request's context
    (the cross-thread handoff through the double buffer), and
    compaction-worker spans join the triggering trace;
  * the flight recorder tail-samples full span trees for slow or failed
    requests only, in a bounded ring, exportable as JSONL;
  * the admin plane answers METRICS / HEALTH / TRACES over the same
    socket queries ride.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                # container fallback
    from tests._hypothesis_fallback import given, settings, st

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.obs import (REGISTRY, TRACER, MetricsRegistry, SpanTracer,
                       TraceContext)
from repro.obs.flight import FlightRecorder
from repro.serve import api
from repro.serve.net import ClimberClient, ServerError, codec, schema, \
    serve_in_thread
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


def make_fleet(data: np.ndarray) -> IndexFleet:
    fleet = IndexFleet(FleetConfig(shard_cfg=small_cfg(), fanout=2,
                                   delta_capacity=4096, auto_compact=False))
    for i in range(2):
        fleet.add_shard(f"tenant{i}", data[i * 600:(i + 1) * 600])
    return fleet


@pytest.fixture(scope="module")
def corpus():
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1200, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 8))
    return data, queries


# -- TraceContext / adopt unit + property tests -----------------------------

class TestTraceContext:
    def test_mint_is_nonzero_and_distinct(self):
        ids = {SpanTracer.mint_trace_id() for _ in range(256)}
        assert 0 not in ids
        assert len(ids) == 256           # 63-bit space: collisions ≈ never

    def test_adopt_none_and_zero_are_noops(self):
        tracer = SpanTracer(capacity=16)
        for ctx in (None, 0, TraceContext(0)):
            with tracer.adopt(ctx):
                with tracer.span("w") as sp:
                    pass
                assert sp.trace_id == sp.span_id   # rooted its own trace
                assert sp.parent_id is None

    @settings(max_examples=50)
    @given(st.integers(min_value=1, max_value=2**63 - 1),
           st.integers(min_value=0, max_value=2**31))
    def test_adopted_spans_join_the_remote_trace(self, trace_id, span_id):
        tracer = SpanTracer(capacity=64)
        with tracer.adopt(TraceContext(trace_id, span_id)):
            with tracer.span("outer") as outer:
                with tracer.span("inner") as inner:
                    pass
        assert outer.trace_id == trace_id
        assert inner.trace_id == trace_id
        # span_id=0 means "root of the remote trace": no local parent
        assert outer.parent_id == (span_id or None)
        assert inner.parent_id == outer.span_id

    def test_current_context_exports_innermost(self):
        tracer = SpanTracer(capacity=16)
        assert tracer.current_context() is None
        with tracer.span("a") as a:
            with tracer.span("b") as b:
                ctx = tracer.current_context()
        assert ctx == TraceContext(a.span_id, b.span_id)

    def test_context_survives_the_exporting_span(self):
        # the handoff token is by-value: the admitting span may close
        # before the executor thread adopts it
        tracer = SpanTracer(capacity=16)
        with tracer.span("admit") as admit:
            ctx = tracer.current_context()
        done = {}

        def _worker():
            with tracer.adopt(ctx):
                with tracer.span("tick") as sp:
                    pass
            done["span"] = sp

        t = threading.Thread(target=_worker)
        t.start()
        t.join()
        assert done["span"].trace_id == admit.trace_id
        assert done["span"].parent_id == admit.span_id

    def test_set_capacity_counts_drops(self):
        reg = MetricsRegistry()
        tracer = SpanTracer(capacity=4, registry=reg)
        for _ in range(10):
            with tracer.span("w"):
                pass
        assert len(tracer.spans()) == 4
        assert reg.counter("obs.spans_dropped").value == 6
        tracer.set_capacity(8)           # resize keeps the newest spans
        assert tracer.capacity == 8
        assert len(tracer.spans()) == 4
        with pytest.raises(ValueError):
            tracer.set_capacity(0)


# -- cross-thread handoff through the engine's double buffer ----------------

class TestCrossThread:
    def test_executor_tick_joins_admitting_trace(self, corpus):
        data, queries = corpus
        engine = FleetEngine(make_fleet(data), batch_size=4,
                             routing="exhaustive")
        with TRACER.span("test.admitting") as admitting:
            tickets = [engine.make_ticket(api.QueryRequest(
                series=q, k=K, request_id=i))
                for i, q in enumerate(queries[:2])]
        qbatch = engine.prepare_batch(tickets)
        thread = threading.Thread(
            target=engine.execute_prepared, args=(qbatch, tickets))
        thread.start()
        thread.join()
        trace = TRACER.trace(admitting.trace_id)
        names = {s.name for s in trace}
        assert {"serve.tick", "fleet.query"} <= names
        tick = next(s for s in trace if s.name == "serve.tick")
        assert tick.thread != admitting.thread
        assert tick.attrs["traces"] == 1
        for t in tickets:
            assert t.result.trace_id == admitting.trace_id
            assert t.result.parent_span_id == tick.span_id

    def test_wire_context_beats_local_context(self, corpus):
        data, queries = corpus
        engine = FleetEngine(make_fleet(data), batch_size=4,
                             routing="exhaustive")
        remote = TRACER.mint_trace_id()
        with TRACER.span("test.local"):
            ticket = engine.make_ticket(api.QueryRequest(
                series=queries[0], k=K, trace_id=remote,
                parent_span_id=77))
        assert ticket.trace == TraceContext(remote, 77)


# -- the acceptance test: one trace across a real localhost socket ----------

class TestOneTraceAcrossSocket:
    def test_client_query_produces_one_trace(self, corpus):
        data, queries = corpus
        engine = FleetEngine(make_fleet(data), batch_size=4,
                             routing="signature")
        server, stop = serve_in_thread(engine)
        try:
            with ClimberClient("127.0.0.1", server.port) as client:
                results = client.query_batch(list(queries[:4]), k=K)
        finally:
            stop()
        # every request of the batch rode the same client-minted trace
        tids = {r.trace_id for r in results}
        assert len(tids) == 1
        tid = tids.pop()
        assert tid != 0
        spans = TRACER.trace(tid)
        names = {s.name for s in spans}
        assert {"net.rtt", "net.admit", "serve.tick",
                "fleet.query"} <= names
        # the client RTT span is part of the same trace (in-process test:
        # same ring) and parents the server's admission spans
        rtt = next(s for s in spans if s.name == "net.rtt")
        assert rtt.trace_id == tid
        for admit in (s for s in spans if s.name == "net.admit"):
            assert admit.parent_id == rtt.span_id
        # the tick ran on the executor thread, in the same trace
        tick = next(s for s in spans if s.name == "serve.tick")
        assert "exec" in tick.thread
        # results echo the tick that answered them
        assert all(r.parent_span_id for r in results)
        # the tree anchors on the client span even though the trace root
        # (the minted id) has no local span
        tree = TRACER.tree(tid)
        assert tree is not None and tree["name"] == "net.rtt"


# -- compaction worker joins the triggering trace ---------------------------

class TestCompactionTrace:
    def test_compactor_spans_join_trigger_trace(self, corpus):
        data, _ = corpus
        fleet = IndexFleet(FleetConfig(shard_cfg=small_cfg(), fanout=2,
                                       delta_capacity=4096,
                                       auto_compact=False))
        fleet.insert(data[:200])
        with TRACER.span("test.trigger") as trigger:
            ticket = fleet.compact_async()
        ticket.wait(timeout=60)
        spans = TRACER.trace(trigger.trace_id)
        names = {s.name for s in spans}
        assert {"compact.seal", "compact.build", "compact.swap"} <= names
        seal = next(s for s in spans if s.name == "compact.seal")
        assert seal.thread == "fleet-compactor"
        assert seal.parent_id == trigger.span_id

    def test_explicit_compaction_still_roots_its_own_trace(self, corpus):
        data, _ = corpus
        fleet = IndexFleet(FleetConfig(shard_cfg=small_cfg(), fanout=2,
                                       delta_capacity=4096,
                                       auto_compact=False))
        fleet.insert(data[200:400])
        ticket = fleet.compact_async()   # no span open: adopt is a no-op
        ticket.wait(timeout=60)
        seal = next(s for s in reversed(TRACER.spans())
                    if s.name == "compact.seal")
        assert seal.parent_id is None
        assert seal.trace_id == seal.span_id


# -- flight recorder --------------------------------------------------------

def _request(tracer, flight, *, ms_name="serve.tick", error=None):
    """One synthetic request trace: admit + trigger span."""
    tid = tracer.mint_trace_id()
    with tracer.adopt(tid):
        with tracer.span("net.admit"):
            if error is not None:
                flight.note_error(tid, error)
        if error is None:
            with tracer.span(ms_name):
                pass
    return tid


class TestFlightRecorder:
    def test_threshold_retains_only_slow_ticks(self):
        tracer = SpanTracer(capacity=256)
        flight = FlightRecorder(tracer, threshold_ms=1e6, registry=None)
        for _ in range(5):
            _request(tracer, flight)
        assert flight.records() == []    # nothing is slower than 1000 s
        flight.threshold_ms = 0.0        # now everything is "slow"
        tid = _request(tracer, flight)
        recs = flight.records()
        assert len(recs) == 1
        assert recs[0]["trace_id"] == tid
        assert recs[0]["reason"] == "latency>0ms"
        assert {s["name"] for s in recs[0]["spans"]} == \
            {"net.admit", "serve.tick"}
        flight.close()

    def test_quantile_gate_waits_for_warmup(self):
        tracer = SpanTracer(capacity=256)
        flight = FlightRecorder(tracer, quantile=0.99, min_samples=32,
                                registry=None)
        for _ in range(10):
            _request(tracer, flight)
        assert flight.records() == []    # below min_samples: gate unarmed
        flight.close()

    def test_error_retains_without_a_tick(self):
        # a refused request never reaches serve.tick; the noted error
        # retains on the admission span instead
        tracer = SpanTracer(capacity=256)
        flight = FlightRecorder(tracer, threshold_ms=1e6, registry=None)
        tid = _request(tracer, flight, error="RETRY_LATER")
        recs = flight.records()
        assert len(recs) == 1
        assert recs[0]["trace_id"] == tid
        assert recs[0]["reason"] == "error:RETRY_LATER"
        assert recs[0]["trigger"] == "net.admit"
        flight.close()

    def test_ring_and_open_buffers_are_bounded(self):
        tracer = SpanTracer(capacity=1024)
        flight = FlightRecorder(tracer, threshold_ms=0.0, capacity=8,
                                max_open_traces=4, registry=None)
        for _ in range(32):
            _request(tracer, flight)
        assert len(flight.records()) == 8
        # traces that never hit a trigger can't grow without bound
        for _ in range(32):
            _request(tracer, flight, ms_name="not.a.trigger")
        assert len(flight._open) <= 4
        flight.close()

    def test_jsonl_roundtrips(self):
        import json
        tracer = SpanTracer(capacity=256)
        flight = FlightRecorder(tracer, threshold_ms=0.0, registry=None)
        for _ in range(3):
            _request(tracer, flight)
        lines = flight.jsonl(limit=2).strip().splitlines()
        assert len(lines) == 2
        for line in lines:
            rec = json.loads(line)
            assert {"trace_id", "reason", "spans"} <= rec.keys()
        flight.close()

    def test_counters(self):
        reg = MetricsRegistry()
        tracer = SpanTracer(capacity=256)
        flight = FlightRecorder(tracer, threshold_ms=1e6, registry=reg)
        _request(tracer, flight)                      # dropped (fast)
        _request(tracer, flight, error="INTERNAL")    # retained (error)
        assert reg.counter("flight.dropped").value == 1
        assert reg.counter("flight.retained").value == 1
        flight.close()


# -- admin plane ------------------------------------------------------------

class TestAdminPlane:
    def roundtrip(self, mtype, msg):
        frame = schema.encode_message(mtype, msg)
        got_type, length, _ = codec.decode_header(frame)
        assert length == len(frame) - codec.HEADER_LEN
        return schema.decode_message(got_type, frame[codec.HEADER_LEN:])

    def test_schema_roundtrips(self):
        mtype, got = self.roundtrip(schema.MsgType.METRICS,
                                    {"page": "# HELP x\nx 1\n"})
        assert mtype == schema.MsgType.METRICS
        assert got["page"].startswith("# HELP")
        health = {k: i for i, k in enumerate(schema._HEALTH_FIELDS)}
        mtype, got = self.roundtrip(schema.MsgType.HEALTH, health)
        assert mtype == schema.MsgType.HEALTH and got == health
        mtype, got = self.roundtrip(
            schema.MsgType.TRACES,
            {"limit": 3, "count": 1, "traces_jsonl": '{"a": 1}\n'})
        assert got == {"limit": 3, "count": 1, "traces_jsonl": '{"a": 1}\n'}

    def test_admin_requests_decode_with_defaults(self):
        # a client's admin request is an empty dict: every field defaults
        for mtype in (schema.MsgType.METRICS, schema.MsgType.HEALTH,
                      schema.MsgType.TRACES):
            _, got = self.roundtrip(mtype, {})
            assert isinstance(got, dict)

    def test_admin_plane_over_live_socket(self, corpus):
        data, queries = corpus
        engine = FleetEngine(make_fleet(data), batch_size=4,
                             routing="signature", sentinel_rate=1.0)
        server, stop = serve_in_thread(engine)
        try:
            with ClimberClient("127.0.0.1", server.port) as client:
                client.query_batch(list(queries[:4]), k=K)
                engine.sentinel.drain()
                # METRICS: the Prometheus page over the query socket
                page = client.metrics()
                assert "repro_net_queries_total" in page
                assert "repro_fleet_online_recall" in page
                assert "repro_obs_spans_dropped_total" in page
                # HEALTH: readiness card
                health = client.health()
                assert health["ready"] == 1 and health["draining"] == 0
                assert health["shards"] == 2
                assert health["compaction_in_flight"] == 0
                # TRACES: force a refusal, then read the retained trace
                with pytest.raises(ServerError) as err:
                    client.query(np.zeros(13, np.float32), k=K)
                assert err.value.code == "BAD_REQUEST"
                traces = client.traces()
                assert any(t["reason"] == "error:BAD_REQUEST"
                           for t in traces)
                bad = next(t for t in traces
                           if t["reason"] == "error:BAD_REQUEST")
                assert any(s["name"] == "net.admit"
                           for s in bad["spans"])
        finally:
            stop()
