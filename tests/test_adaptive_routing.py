"""Adaptive-routing and recall-target parity acceptance tests.

The accuracy story of the eval program rests on two degradation proofs:

* ``routing="adaptive"`` at ``threshold=1`` is **bit-identical** to
  ``routing="exhaustive"`` (distances *and* gids), on the host oracle and
  on the mesh placement — widening the fan-out all the way recovers the
  lossless answer, so any recall gap at lower thresholds is purely the
  routing mask's doing;
* the ``recall_target`` planner at ``spend_factor=1`` is bit-identical to
  the stock ``adaptive`` planner — spending more is the *only* thing the
  variant does.

Plus the cheap end: ``threshold=0`` degrades to top-1 signature routing.
"""
import numpy as np
import pytest

from repro.core.query import register_recall_target
from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, IndexFleet
from repro.launch.mesh import make_mesh
from repro.utils.config import ClimberConfig

import jax
import jax.numpy as jnp

K = 8


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                        prefix_len=5, capacity=128, sample_frac=0.3,
                        max_centroids=12, k=K, candidate_groups=4,
                        # factor 1: the partition cap binds, so boosting
                        # spend measurably widens plans (spend-two test)
                        adaptive_factor=1)
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1200, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 6))
    # plan_cache_size=0: the cache keys on the variant *name*, and these
    # tests re-register "recall_target" with different spend factors
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   auto_compact=False, plan_cache_size=0))
    for i in range(3):
        fleet.add_shard(f"t{i}", data[i * 400:(i + 1) * 400])
    return fleet, queries


class TestThresholdOneIsExhaustive:
    def test_host_bit_identical(self, fleet_setup):
        fleet, queries = fleet_setup
        de, ge, ie = fleet.query(queries, K, routing="exhaustive",
                                 placement="host")
        da, ga, ia = fleet.query(queries, K, routing="adaptive",
                                 threshold=1.0, placement="host")
        np.testing.assert_array_equal(ge, ga)
        np.testing.assert_array_equal(de, da)
        assert ia.routed_mask.all()
        np.testing.assert_array_equal(ie.candidates_scanned,
                                      ia.candidates_scanned)

    def test_mesh_bit_identical(self, fleet_setup):
        fleet, queries = fleet_setup
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            de, ge, _ = fleet.query(queries, K, routing="exhaustive",
                                    placement="mesh")
            da, ga, ia = fleet.query(queries, K, routing="adaptive",
                                     threshold=1.0, placement="mesh")
            np.testing.assert_array_equal(ge, ga)
            np.testing.assert_array_equal(de, da)
            assert ia.routed_mask.all()
        finally:
            fleet.mesh = None
            fleet._placement = None

    def test_learned_threshold_of_one_also_exhaustive(self, fleet_setup):
        """router.threshold=1 (no per-call override) takes the same path."""
        fleet, queries = fleet_setup
        fleet.router.threshold = 1.0
        try:
            de, ge, _ = fleet.query(queries, K, routing="exhaustive")
            da, ga, _ = fleet.query(queries, K, routing="adaptive")
            np.testing.assert_array_equal(ge, ga)
            np.testing.assert_array_equal(de, da)
        finally:
            fleet.router.threshold = None


class TestThresholdZeroIsTopOne:
    def test_mask_degrades_to_top1(self, fleet_setup):
        fleet, queries = fleet_setup
        _, _, ia = fleet.query(queries, K, routing="adaptive",
                               threshold=0.0)
        _, _, i1 = fleet.query(queries, K, routing="signature", fanout=1)
        assert (ia.routed_mask.sum(axis=1) == 1).all()
        scores = fleet.router.score(queries)
        unique = (scores == scores.max(axis=1, keepdims=True)) \
            .sum(axis=1) == 1
        np.testing.assert_array_equal(ia.routed_mask[unique],
                                      i1.routed_mask[unique])

    def test_results_match_top1(self, fleet_setup):
        fleet, queries = fleet_setup
        da, ga, _ = fleet.query(queries, K, routing="adaptive",
                                threshold=0.0)
        d1, g1, _ = fleet.query(queries, K, routing="signature", fanout=1)
        scores = np.asarray(fleet.router.score(queries))
        unique = (scores == scores.max(axis=1, keepdims=True)) \
            .sum(axis=1) == 1
        np.testing.assert_array_equal(ga[unique], g1[unique])
        np.testing.assert_array_equal(da[unique], d1[unique])


class TestRecallTargetParity:
    def test_spend_one_is_stock_adaptive(self, fleet_setup):
        fleet, queries = fleet_setup
        register_recall_target(1.0)
        da, ga, ia = fleet.query(queries, K, routing="exhaustive",
                                 variant="adaptive")
        dr, gr, ir = fleet.query(queries, K, routing="exhaustive",
                                 variant="recall_target")
        np.testing.assert_array_equal(ga, gr)
        np.testing.assert_array_equal(da, dr)
        np.testing.assert_array_equal(ia.candidates_scanned,
                                      ir.candidates_scanned)

    def test_spend_two_scans_at_least_as_much(self, fleet_setup):
        fleet, queries = fleet_setup
        register_recall_target(2.0)
        _, _, ia = fleet.query(queries, K, routing="exhaustive",
                               variant="adaptive")
        _, _, ir = fleet.query(queries, K, routing="exhaustive",
                               variant="recall_target")
        assert (ir.candidates_scanned >= ia.candidates_scanned).all()
        assert ir.candidates_scanned.sum() > ia.candidates_scanned.sum()

    def test_mesh_matches_host(self, fleet_setup):
        """The recall_target variant is registered for both planner
        registries, so mesh execution stays bit-identical to the oracle."""
        fleet, queries = fleet_setup
        register_recall_target(2.0)
        dh, gh, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="recall_target", placement="host")
        fleet.attach_mesh(make_mesh((1,), ("data",)))
        try:
            dm, gm, _ = fleet.query(queries, K, routing="exhaustive",
                                    variant="recall_target",
                                    placement="mesh")
            np.testing.assert_array_equal(gh, gm)
            np.testing.assert_array_equal(dh, dm)
        finally:
            fleet.mesh = None
            fleet._placement = None


class TestCalibrationFlow:
    def test_audit_record_and_calibrate(self, fleet_setup):
        fleet, queries = fleet_setup
        fleet.routing_traces.clear()
        fleet.audit_routing(queries, K, record=True)
        assert len(fleet.routing_traces) == len(queries)
        th = fleet.calibrate_routing(target_recall=0.9)
        assert 0.0 <= th <= 1.0
        assert fleet.router.threshold == th
        d, g, info = fleet.query(queries, K, routing="adaptive")
        assert d.shape == (len(queries), K)
        assert (info.routed_mask.sum(axis=1) >= 1).all()

    def test_calibrate_without_traces_raises(self, fleet_setup):
        fleet, _ = fleet_setup
        fleet.routing_traces.clear()
        fleet.router.threshold = None
        with pytest.raises(RuntimeError):
            fleet.calibrate_routing()
