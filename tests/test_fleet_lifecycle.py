"""Fleet lifecycle plane — durability, background compaction, merge/retire.

The acceptance contracts from the issue:
  * **restart invariant**: ``IndexFleet.open(save_dir)`` after a simulated
    crash (WAL tail unreplayed, delta lost) returns bit-identical
    ``(dist, gid)`` to the never-crashed fleet, for routed and exhaustive
    variants;
  * **kill points**: crashes injected between WAL append → delta scatter →
    compact swap → WAL truncate all replay to the uninterrupted answers;
  * **background compaction**: ``compact()`` runs the rebuild off-thread
    while a concurrent query thread keeps getting the pre-compact answers,
    and the existing post-compact bit-identity holds.

A "crash" is simulated by discarding the fleet object (the delta and all
host state are process-lifetime) and re-opening the storage directory —
the WAL/snapshot files are exactly what a killed process would leave.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet, MergePolicy
from repro.fleet.fleet import DeltaShard
from repro.fleet.lifecycle import WriteAheadLog
from repro.fleet.lifecycle.merge import shard_records
from repro.fleet.lifecycle.snapshot import load_shard, save_shard
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


def mkdata(seed: int, n: int) -> np.ndarray:
    return np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(seed),
                                   n, 64))


def mkfleet(storage_dir=None, **kw) -> IndexFleet:
    fc = dict(shard_cfg=small_cfg(), fanout=1, delta_capacity=4096,
              auto_compact=False)
    fc.update(kw)
    return IndexFleet(FleetConfig(**fc), storage_dir=storage_dir)


def seeded_fleet(storage_dir, **kw) -> IndexFleet:
    fleet = mkfleet(storage_dir, **kw)
    data = mkdata(0, 1600)
    fleet.add_shard("t0", data[:800])
    fleet.add_shard("t1", data[800:])
    return fleet


def answers(fleet, queries):
    """(dist, gid) for both contract modes: routed and exhaustive.

    Restart bit-identity covers both: the restored fleet has the same
    shard topology, so even routed answers must match.  (Across a
    *compaction* only the exhaustive answers are invariant — sealing moves
    always-queried delta records under the router's fanout — so
    compaction tests use :func:`exhaustive_answers`.)
    """
    de, ge, _ = fleet.query(queries, K, routing="exhaustive",
                            variant="exhaustive")
    dr, gr, _ = fleet.query(queries, K, routing="signature",
                            variant="adaptive")
    return de, ge, dr, gr


def exhaustive_answers(fleet, queries):
    d, g, _ = fleet.query(queries, K, routing="exhaustive",
                          variant="exhaustive")
    return d, g


def assert_same_answers(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@pytest.fixture()
def queries():
    return np.asarray(make_queries(jax.random.PRNGKey(2),
                                   jnp.asarray(mkdata(0, 1600)), 5))


class TestWal:
    def test_append_roll_replay(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        g1, b1 = np.arange(3, dtype=np.int32), mkdata(1, 3)
        g2, b2 = np.arange(3, 7, dtype=np.int32), mkdata(2, 4)
        wal.append(g1, b1)
        frozen = wal.roll()
        wal.append(g2, b2)
        frames = wal.replay()
        assert [f[0] for f in frames] == [frozen, frozen + 1]
        np.testing.assert_array_equal(frames[0][1], g1)
        np.testing.assert_array_equal(frames[1][2], b2)
        wal.drop([frozen])
        assert [f[0] for f in wal.replay()] == [frozen + 1]
        with pytest.raises(ValueError, match="active segment"):
            wal.drop([wal.active_segment])
        wal.close()

    def test_torn_tail_dropped(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        wal.append(np.arange(2, dtype=np.int32), mkdata(1, 2))
        wal.append(np.arange(2, 4, dtype=np.int32), mkdata(2, 2))
        wal.close()
        seg = tmp_path / "wal" / "seg_00000001.wal"
        raw = seg.read_bytes()
        seg.write_bytes(raw[:-7])          # crash mid-append: torn frame
        frames = WriteAheadLog(tmp_path / "wal").replay()
        assert len(frames) == 1            # only the complete frame survives
        np.testing.assert_array_equal(frames[0][1], [0, 1])

    def test_reopen_appends_to_active_segment(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        wal.append(np.arange(2, dtype=np.int32), mkdata(1, 2))
        wal.close()
        wal2 = WriteAheadLog(tmp_path / "wal")
        wal2.append(np.arange(2, 4, dtype=np.int32), mkdata(2, 2))
        assert len(wal2.replay()) == 2
        assert wal2.segments() == [1]
        wal2.close()


class TestShardSnapshot:
    def test_roundtrip_bit_identical(self, tmp_path, queries):
        from repro.core.query import knn_query
        fleet = seeded_fleet(None)
        handle = fleet.shards[0]
        save_shard(tmp_path / "snap", handle)
        loaded = load_shard(tmp_path / "snap")
        assert loaded.key == handle.key
        np.testing.assert_array_equal(loaded.global_ids, handle.global_ids)
        for variant in ("exhaustive", "adaptive"):
            d0, g0, _ = knn_query(handle.index, jnp.asarray(queries), K,
                                  variant=variant)
            d1, g1, _ = knn_query(loaded.index, jnp.asarray(queries), K,
                                  variant=variant)
            np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
            np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    def test_records_invert_store_scatter(self):
        fleet = seeded_fleet(None)
        data, gids = shard_records(fleet.shards[0])
        np.testing.assert_array_equal(data, mkdata(0, 1600)[:800])
        np.testing.assert_array_equal(gids, np.arange(800))


class TestRestartInvariant:
    """Acceptance: crash (delta lost) + open() == the never-crashed fleet."""

    def test_restart_bit_identical(self, tmp_path, queries):
        fleet = seeded_fleet(tmp_path / "fleet")
        for i in range(3):
            fleet.insert(mkdata(10 + i, 40))
        fleet.save()
        live = answers(fleet, queries)
        del fleet                              # crash: delta state lost
        restored = IndexFleet.open(tmp_path / "fleet")
        assert restored.delta.occupancy == 120  # WAL tail replayed
        assert_same_answers(answers(restored, queries), live)

    def test_unsaved_tail_is_replayed(self, tmp_path, queries):
        """Inserts after the last save() are WAL-durable on their own."""
        fleet = seeded_fleet(tmp_path / "fleet")
        fleet.save()
        gids = fleet.insert(mkdata(20, 50))    # after the save
        live = answers(fleet, queries)
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert restored.delta.occupancy == 50
        assert restored._next_gid == int(gids.max()) + 1
        assert_same_answers(answers(restored, queries), live)
        # and the restored fleet keeps ingesting with fresh gids
        more = restored.insert(mkdata(21, 5))
        assert more.min() == int(gids.max()) + 1

    def test_double_restart(self, tmp_path, queries):
        fleet = seeded_fleet(tmp_path / "fleet")
        fleet.insert(mkdata(22, 60))
        live = answers(fleet, queries)
        del fleet
        once = IndexFleet.open(tmp_path / "fleet")
        assert_same_answers(answers(once, queries), live)
        del once
        twice = IndexFleet.open(tmp_path / "fleet")
        assert_same_answers(answers(twice, queries), live)


class TestKillPoints:
    """Injected crashes at every step of the append → seal → truncate
    pipeline replay to the uninterrupted answers."""

    def test_kill_between_wal_append_and_scatter(self, tmp_path, queries,
                                                 monkeypatch):
        fleet = seeded_fleet(tmp_path / "fleet")
        batch = mkdata(30, 40)
        # uninterrupted twin for the reference answers
        twin = seeded_fleet(tmp_path / "twin")
        twin.insert(batch)
        ref = answers(twin, queries)

        monkeypatch.setattr(DeltaShard, "insert",
                            lambda *a, **k: (_ for _ in ()).throw(
                                RuntimeError("killed before scatter")))
        with pytest.raises(RuntimeError, match="killed before scatter"):
            fleet.insert(batch)                 # WAL append already durable
        monkeypatch.undo()
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert restored.delta.occupancy == 40   # the acknowledged-to-WAL batch
        assert_same_answers(answers(restored, queries), ref)

    def test_kill_mid_compaction_build(self, tmp_path, queries,
                                       monkeypatch):
        """Crash while the rebuild runs: no snapshot, WAL intact → replay
        restores the pre-compaction fleet bit-for-bit."""
        fleet = seeded_fleet(tmp_path / "fleet")
        fleet.insert(mkdata(31, 60))
        fleet.insert(mkdata(32, 30))
        ref = answers(fleet, queries)
        monkeypatch.setattr(
            IndexFleet, "_build_shard_index",
            lambda *a, **k: (_ for _ in ()).throw(
                RuntimeError("killed mid-build")))
        ticket = fleet.compact_async()
        with pytest.raises(RuntimeError, match="killed mid-build"):
            ticket.wait()
        monkeypatch.undo()
        # the abort path lost nothing in the live fleet...
        assert fleet.delta.occupancy == 90
        assert_same_answers(answers(fleet, queries), ref)
        # ...and neither does a crash + replay (both WAL segments survive)
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert restored.delta.occupancy == 90
        assert_same_answers(answers(restored, queries), ref)

    def test_kill_between_swap_and_truncate(self, tmp_path, queries,
                                            monkeypatch):
        """Sealed shard durable but WAL not truncated: replay must skip the
        already-sealed frames (gid dedupe), not double-ingest them."""
        fleet = seeded_fleet(tmp_path / "fleet")
        fleet.insert(mkdata(33, 70))
        monkeypatch.setattr(WriteAheadLog, "drop",
                            lambda *a, **k: (_ for _ in ()).throw(
                                RuntimeError("killed before truncate")))
        ticket = fleet.compact_async()
        with pytest.raises(RuntimeError, match="killed before truncate"):
            ticket.wait()
        monkeypatch.undo()
        # swap completed: the fleet itself is consistent (shard sealed)
        assert any(s.key.startswith("sealed:") for s in fleet.shards)
        assert fleet.delta.occupancy == 0
        ref = answers(fleet, queries)
        # the stale WAL segment is still on disk
        stale = WriteAheadLog(tmp_path / "fleet" / "wal")
        assert len(stale.replay()) == 1
        stale.close()
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert restored.delta.occupancy == 0    # frame skipped, not re-ingested
        assert restored.total_records == 1670
        assert_same_answers(answers(restored, queries), ref)

    def test_completed_seal_restarts_clean(self, tmp_path, queries):
        fleet = seeded_fleet(tmp_path / "fleet")
        fleet.insert(mkdata(34, 80))
        fleet.compact()
        ref = answers(fleet, queries)
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert [s.key for s in restored.shards] == ["t0", "t1", "sealed:1"]
        assert_same_answers(answers(restored, queries), ref)


class TestBackgroundCompaction:
    def test_sync_contract_unchanged(self, tmp_path, queries):
        """compact() still blocks, seals everything, and preserves answers
        — now via the worker thread."""
        fleet = seeded_fleet(None)
        fleet.insert(mkdata(40, 90))
        before = exhaustive_answers(fleet, queries)
        handle = fleet.compact()
        assert handle is not None and handle.sealed
        assert fleet.delta.occupancy == 0
        assert fleet.stats.compactions == 1
        assert fleet.stats.compaction_ms > 0
        assert_same_answers(exhaustive_answers(fleet, queries), before)
        assert fleet.compact() is None          # empty delta: no-op

    def test_queries_during_background_compaction(self, queries):
        """Acceptance: the post-compact bit-identity holds under a
        concurrent query thread — every answer observed while the rebuild
        runs equals the pre-compact answer."""
        fleet = seeded_fleet(None)
        fleet.insert(mkdata(41, 100))
        ref = exhaustive_answers(fleet, queries)
        results, errors = [], []
        stop = threading.Event()

        def hammer():
            try:
                while not stop.is_set():
                    results.append(exhaustive_answers(fleet, queries))
            except BaseException as exc:        # noqa: BLE001
                errors.append(exc)

        t = threading.Thread(target=hammer)
        t.start()
        try:
            ticket = fleet.compact_async()
            assert ticket is not None
            handle = ticket.wait(timeout=300)
        finally:
            stop.set()
            t.join()
        assert not errors
        assert handle.key == "sealed:1"
        assert results                           # the thread really ran
        for snap in results:
            assert_same_answers(snap, ref)
        assert_same_answers(exhaustive_answers(fleet, queries), ref)

    def test_inserts_during_background_compaction(self, queries):
        """Records inserted while a seal is in flight land in the fresh
        delta and stay visible through the swap."""
        fleet = seeded_fleet(None)
        fleet.insert(mkdata(42, 80))
        ticket = fleet.compact_async()
        fresh = mkdata(43, 3)
        gids = fleet.insert(fresh)               # goes to the new delta
        ticket.wait(timeout=300)
        assert fleet.delta.occupancy == 3
        assert fleet.total_records == 1600 + 80 + 3
        _, g, _ = fleet.query(fresh[:1], K, routing="exhaustive",
                              variant="exhaustive")
        assert gids[0] in g[0]

    def test_min_build_refusal_is_synchronous(self):
        fleet = mkfleet()
        fleet.insert(mkdata(44, 3))
        with pytest.raises(ValueError, match="cannot compact"):
            fleet.compact()
        assert fleet.delta.occupancy == 3        # refusal lost nothing

    def test_background_auto_compact(self):
        fleet = mkfleet(delta_capacity=64, auto_compact=True,
                        background_compaction=True)
        fleet.add_shard("t0", mkdata(0, 800))
        fleet.insert(mkdata(45, 80))             # crosses capacity
        ticket = fleet._seal_ticket
        if ticket is not None:
            ticket.wait(timeout=300)
        assert fleet.stats.compactions == 1
        assert fleet.delta.occupancy == 0


class TestMergeAndRetire:
    def seeded(self, tmp_path=None, n_shards=4, per=120):
        fleet = mkfleet(tmp_path)
        for i in range(n_shards):
            fleet.add_shard(f"t{i}", mkdata(50 + i, per))
        return fleet

    def test_merge_preserves_exact_answers(self, queries):
        fleet = self.seeded()
        de, ge, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        report = fleet.maintenance(MergePolicy(small_shard_records=150,
                                               max_merged_records=300,
                                               merges_per_tick=10))
        assert report["merged"]
        assert len(fleet.shards) == 2            # 4 small shards → 2 merged
        assert fleet.stats.merges == 2
        de2, ge2, _ = fleet.query(queries, K, routing="exhaustive",
                                  variant="exhaustive")
        np.testing.assert_array_equal(ge, ge2)   # gids preserved
        np.testing.assert_array_equal(de, de2)

    def test_merge_respects_size_caps(self):
        fleet = self.seeded()
        report = fleet.maintenance(MergePolicy(small_shard_records=100,
                                               merges_per_tick=10))
        assert report["merged"] == []            # nothing small enough
        report = fleet.maintenance(MergePolicy(small_shard_records=150,
                                               max_merged_records=200,
                                               merges_per_tick=10))
        assert report["merged"] == []            # pairwise sum over the cap

    def test_retire_past_horizon(self, queries):
        fleet = self.seeded()
        t0 = fleet.shards[0].created_at
        # age the first two shards far past the horizon
        fleet.shards[0].created_at = t0 - 1000
        fleet.shards[1].created_at = t0 - 900
        report = fleet.maintenance(MergePolicy(small_shard_records=0,
                                               retire_after=500),
                                   now=t0)
        assert report["retired"] == ["t0", "t1"]
        assert [s.key for s in fleet.shards] == ["t2", "t3"]
        assert fleet.stats.retired_shards == 2
        # retired records are gone; the survivors still answer exactly
        _, g, _ = fleet.query(queries, K, routing="exhaustive",
                              variant="exhaustive")
        live = set(np.concatenate([s.global_ids for s in fleet.shards])
                   .tolist())
        assert all(int(x) in live for x in g.ravel() if x >= 0)

    def test_router_stays_parallel_after_maintenance(self, queries):
        """Routed queries keep working (mask width == shard count) after
        merges and retirements resize the shard list."""
        fleet = self.seeded()
        t0 = fleet.shards[0].created_at
        fleet.shards[0].created_at = t0 - 1000
        fleet.maintenance(MergePolicy(small_shard_records=150,
                                      max_merged_records=300,
                                      merges_per_tick=10, retire_after=500),
                          now=t0)
        assert fleet.router.keys == [s.key for s in fleet.shards]
        _, _, info = fleet.query(queries, K, routing="signature")
        assert info.routed_mask.shape == (len(queries), len(fleet.shards))

    def test_routed_queries_during_concurrent_merge(self, queries):
        """The routing mask is computed under the fleet lock, so a merge
        shrinking the router mid-query can never produce a mask narrower
        than the captured shard list."""
        fleet = self.seeded()
        errors = []
        stop = threading.Event()

        def hammer():
            try:
                while not stop.is_set():
                    _, _, info = fleet.query(queries, K,
                                             routing="signature")
                    assert info.routed_mask.shape[0] == len(queries)
            except BaseException as exc:        # noqa: BLE001
                errors.append(exc)

        t = threading.Thread(target=hammer)
        t.start()
        try:
            fleet.maintenance(MergePolicy(small_shard_records=150,
                                          max_merged_records=300,
                                          merges_per_tick=10))
        finally:
            stop.set()
            t.join()
        assert not errors
        assert len(fleet.shards) == 2

    def test_crash_between_merge_manifest_and_cleanup(self, tmp_path,
                                                      queries, monkeypatch):
        """Kill point inside the merge's storage update: the manifest is
        rewritten before the source snapshot dirs are deleted, so a crash
        in between leaves an openable directory (orphan dirs, no dangling
        references)."""
        fleet = self.seeded(tmp_path / "fleet")
        ref = exhaustive_answers(fleet, queries)
        monkeypatch.setattr("shutil.rmtree",
                            lambda *a, **k: (_ for _ in ()).throw(
                                RuntimeError("killed before cleanup")))
        with pytest.raises(RuntimeError, match="killed before cleanup"):
            fleet.maintenance(MergePolicy(small_shard_records=150,
                                          max_merged_records=300))
        monkeypatch.undo()
        keys = [s.key for s in fleet.shards]    # splice already happened
        assert "merged:1" in keys
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert [s.key for s in restored.shards] == keys
        assert_same_answers(exhaustive_answers(restored, queries), ref)

    def test_maintenance_persists(self, tmp_path, queries):
        fleet = self.seeded(tmp_path / "fleet")
        fleet.maintenance(MergePolicy(small_shard_records=150,
                                      max_merged_records=300,
                                      merges_per_tick=10))
        ref = answers(fleet, queries)
        keys = [s.key for s in fleet.shards]
        del fleet
        restored = IndexFleet.open(tmp_path / "fleet")
        assert [s.key for s in restored.shards] == keys
        assert_same_answers(answers(restored, queries), ref)


class TestEngineMaintenance:
    def test_engine_ticks_drive_maintenance(self, queries):
        from repro.serve import QueryRequest
        fleet = mkfleet(delta_capacity=4096)
        for i in range(4):
            fleet.add_shard(f"t{i}", mkdata(60 + i, 120))
        eng = FleetEngine(fleet, batch_size=2, k=K, maintenance_every=1,
                          merge_policy=MergePolicy(small_shard_records=150,
                                                   max_merged_records=300,
                                                   merges_per_tick=10))
        for i in range(len(queries)):
            eng.submit(QueryRequest(rid=i, series=queries[i], k=K))
        eng.run_until_drained()
        assert fleet.stats.merges == 2           # ticks drove both merges
        assert len(fleet.shards) == 2

    def test_engine_maintenance_compacts_in_background(self):
        fleet = mkfleet(delta_capacity=64, auto_compact=False)
        fleet.add_shard("t0", mkdata(0, 800))
        fleet.insert(mkdata(61, 80))             # over capacity, not sealed
        # flip auto_compact on so the engine's maintenance tick triggers
        # the (background) seal the insert path deliberately skipped
        fleet.cfg = FleetConfig(shard_cfg=small_cfg(), fanout=1,
                                delta_capacity=64, auto_compact=True)
        eng = FleetEngine(fleet, batch_size=2, k=K, maintenance_every=1)
        eng.maintenance()
        ticket = fleet._seal_ticket
        if ticket is not None:
            ticket.wait(timeout=300)
        assert fleet.stats.compactions == 1
        assert fleet.delta.occupancy == 0


class TestStatsSurface:
    def test_snapshot_has_lifecycle_counters(self, queries):
        fleet = seeded_fleet(None)
        fleet.insert(mkdata(70, 40))
        snap = fleet.stats.snapshot()
        for key in ("compaction_ms", "wal_bytes", "merges",
                    "retired_shards"):
            assert key in snap
        assert snap["wal_bytes"] > 0             # pending (mem) frames
        _, _, info = fleet.query(queries, K)
        assert info.lifecycle["wal_bytes"] == snap["wal_bytes"]
        fleet.compact()
        assert fleet.stats.wal_bytes == 0        # frames sealed away
        assert fleet.stats.snapshot()["compaction_ms"] > 0
