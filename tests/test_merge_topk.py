"""merge_topk edge cases: under-filled inputs, duplicate global ids across
inputs, and sentinel-distance padding propagation through merges."""
import jax.numpy as jnp
import numpy as np

from repro.core import PAD_DIST, merge_topk

PAD = np.float32(PAD_DIST)


def _merge(da, ga, db, gb, k, **kw):
    d, g = merge_topk(jnp.asarray(da, jnp.float32), jnp.asarray(ga, jnp.int32),
                      jnp.asarray(db, jnp.float32), jnp.asarray(gb, jnp.int32),
                      k, **kw)
    return np.asarray(d), np.asarray(g)


class TestKLargerThanAvailable:
    def test_fewer_real_candidates_than_k(self):
        """3 + 2 real candidates, k=10: all five survive in order, the tail
        carries the pad sentinel."""
        d, g = _merge([[1.0, 3.0, PAD]], [[5, 7, -1]],
                      [[2.0, 4.0, PAD]], [[8, 9, -1]], 10)
        np.testing.assert_array_equal(g[0, :4], [5, 8, 7, 9])
        np.testing.assert_array_equal(d[0, :4], [1.0, 2.0, 3.0, 4.0])
        np.testing.assert_array_equal(g[0, 4:], -1)
        np.testing.assert_array_equal(d[0, 4:], PAD)

    def test_k_exceeds_combined_width(self):
        """k wider than both input lists together: inputs are padded out."""
        d, g = _merge([[1.0]], [[3]], [[2.0]], [[4]], 6)
        assert d.shape == (1, 6) and g.shape == (1, 6)
        np.testing.assert_array_equal(g[0], [3, 4, -1, -1, -1, -1])
        np.testing.assert_array_equal(d[0, 2:], PAD)


class TestDuplicateGlobalIds:
    def test_default_keeps_duplicates(self):
        """Without dedupe the inputs are assumed disjoint; a violated
        assumption surfaces as a repeated gid (documented behaviour)."""
        d, g = _merge([[1.0, 3.0]], [[7, 5]], [[2.0, PAD]], [[7, -1]], 4)
        assert list(g[0]).count(7) == 2

    def test_dedupe_keeps_best_copy(self):
        d, g = _merge([[1.0, 3.0]], [[7, 5]], [[2.0, PAD]], [[7, -1]], 4,
                      dedupe=True)
        np.testing.assert_array_equal(g[0], [7, 5, -1, -1])
        np.testing.assert_array_equal(d[0, :2], [1.0, 3.0])
        np.testing.assert_array_equal(d[0, 2:], PAD)

    def test_dedupe_tie_breaks_toward_first_input(self):
        """Equal distances: the earlier slot survives, exactly one copy."""
        d, g = _merge([[2.0]], [[9]], [[2.0]], [[9]], 3, dedupe=True)
        np.testing.assert_array_equal(g[0], [9, -1, -1])
        assert d[0, 0] == 2.0

    def test_dedupe_never_drops_distinct_gids(self):
        rng = np.random.default_rng(0)
        da = np.sort(rng.random((2, 5)).astype(np.float32), axis=-1)
        db = np.sort(rng.random((2, 5)).astype(np.float32), axis=-1)
        ga = np.arange(10, dtype=np.int32).reshape(2, 5)
        gb = ga + 100
        d1, g1 = _merge(da, ga, db, gb, 8)
        d2, g2 = _merge(da, ga, db, gb, 8, dedupe=True)
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(d1, d2)


class TestSentinelPropagation:
    def test_all_pad_inputs_stay_pad(self):
        d, g = _merge(np.full((2, 3), PAD), np.full((2, 3), -1),
                      np.full((2, 3), PAD), np.full((2, 3), -1), 3)
        np.testing.assert_array_equal(g, -1)
        np.testing.assert_array_equal(d, PAD)

    def test_pads_always_lose_to_real_candidates(self):
        """A pad from one input never displaces a real candidate from the
        other, for any distance below the sentinel (real EDs are sqrt of a
        float32 and therefore always below sqrt(3.4e38) = PAD)."""
        d, g = _merge([[PAD, PAD]], [[-1, -1]],
                      [[1e18, PAD]], [[3, -1]], 2)
        np.testing.assert_array_equal(g[0], [3, -1])
        assert d[0, 0] == np.float32(1e18)

    def test_merge_is_ascending(self):
        rng = np.random.default_rng(1)
        da = np.sort(rng.random((3, 6)).astype(np.float32), axis=-1)
        db = np.sort(rng.random((3, 6)).astype(np.float32), axis=-1)
        ga = rng.integers(0, 100, (3, 6)).astype(np.int32)
        gb = rng.integers(100, 200, (3, 6)).astype(np.int32)
        d, g = _merge(da, ga, db, gb, 6)
        assert (np.diff(d, axis=-1) >= 0).all()
        ref = np.sort(np.concatenate([da, db], axis=-1), axis=-1)[:, :6]
        np.testing.assert_array_equal(d, ref)
