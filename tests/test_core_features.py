"""Unit tests for CLIMBER-FX: PAA, P4 signatures, distance metrics.

Includes exact reproductions of the paper's worked examples (Def. 7 example,
Example 1 of §IV-C).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (assign_groups, decay_weights, euclidean,
                        overlap_distance, paa, pivot_distances,
                        rank_signature, set_onehot, set_signature,
                        squared_l2_pairwise, total_weight, weight_distance,
                        weighted_onehot, znormalize)


class TestPAA:
    def test_matches_manual_means(self):
        x = jnp.arange(12.0)
        out = paa(x, 4)
        np.testing.assert_allclose(out, [1.0, 4.0, 7.0, 10.0])

    def test_batched(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 7, 64))
        out = paa(x, 8)
        assert out.shape == (5, 7, 8)
        ref = np.asarray(x).reshape(5, 7, 8, 8).mean(-1)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            paa(jnp.zeros(10), 4)

    def test_znormalize(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 100)) * 5 + 2
        z = znormalize(x)
        np.testing.assert_allclose(np.asarray(z.mean(-1)), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(z.std(-1)), 1.0, atol=1e-3)


class TestSignatures:
    def test_rank_signature_matches_argsort(self):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (32, 8))
        pivots = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
        p4 = np.asarray(rank_signature(x, pivots, 5))
        d = np.asarray(pivot_distances(x, pivots))
        ref = np.argsort(d, axis=-1, kind="stable")[:, :5]
        np.testing.assert_array_equal(p4, ref)

    def test_set_signature_sorted(self):
        p4r = jnp.array([[3, 1, 2], [7, 0, 5]])
        np.testing.assert_array_equal(np.asarray(set_signature(p4r)),
                                      [[1, 2, 3], [0, 5, 7]])

    def test_set_onehot(self):
        oh = np.asarray(set_onehot(jnp.array([[1, 3]]), 5))
        np.testing.assert_array_equal(oh, [[0, 1, 0, 1, 0]])

    def test_decay_weights_exp(self):
        w = np.asarray(decay_weights(4, "exp", 0.5))
        np.testing.assert_allclose(w, [1.0, 0.5, 0.25, 0.125])

    def test_decay_weights_linear(self):
        w = np.asarray(decay_weights(4, "linear"))
        np.testing.assert_allclose(w, [1.0, 0.75, 0.5, 0.25])

    def test_decay_monotone(self):
        for kind in ("exp", "linear"):
            w = np.asarray(decay_weights(10, kind, 0.7))
            assert np.all(np.diff(w) < 0), "Def. 9 requires strict decay"

    def test_weighted_onehot(self):
        w = decay_weights(3, "exp", 0.5)
        woh = np.asarray(weighted_onehot(jnp.array([[4, 1, 2]]), 6, w))
        np.testing.assert_allclose(woh, [[0, 0.5, 0.25, 0, 1.0, 0]])


class TestDistances:
    def test_overlap_distance_paper_example(self):
        # Paper, below Def. 7: X=<1,3,6,8>, Y=<2,3,4,6> => OD = 4-2 = 2
        r, m = 10, 4
        x = set_onehot(jnp.array([[1, 3, 6, 8]]), r)
        y = set_onehot(jnp.array([[2, 3, 4, 6]]), r)
        od = np.asarray(overlap_distance(x, y, m))
        assert od[0, 0] == 2

    def test_od_range_and_identity(self):
        r, m = 16, 5
        key = jax.random.PRNGKey(4)
        sig = jax.random.choice(key, r, shape=(20, m), replace=False, axis=0) \
            if False else jnp.stack([
                jax.random.permutation(jax.random.PRNGKey(i), r)[:m]
                for i in range(20)])
        oh = set_onehot(sig, r)
        od = np.asarray(overlap_distance(oh, oh, m))
        assert np.all(od >= 0) and np.all(od <= m)
        np.testing.assert_allclose(np.diag(od), 0.0)     # identity
        np.testing.assert_allclose(od, od.T)             # symmetry

    def test_euclidean(self):
        x = jnp.array([0.0, 3.0])
        y = jnp.array([4.0, 0.0])
        assert float(euclidean(x, y)) == 5.0

    def test_pairwise_matches_direct(self):
        q = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
        d = jax.random.normal(jax.random.PRNGKey(6), (9, 32))
        got = np.asarray(squared_l2_pairwise(q, d))
        ref = ((np.asarray(q)[:, None] - np.asarray(d)[None]) ** 2).sum(-1)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-4)


class TestPaperExample1:
    """Example 1 (§IV-C): exact group-assignment reproduction."""

    def setup_method(self):
        # centroids o1=<1,2,3>, o2=<2,4,5>; fall-back row 0
        self.r, self.m = 8, 3
        c = np.zeros((3, self.r), dtype=np.float32)
        c[1, [1, 2, 3]] = 1.0
        c[2, [2, 4, 5]] = 1.0
        self.c = jnp.asarray(c)

    def test_assignments(self):
        p4r = jnp.array([
            [3, 4, 1],   # X -> G1 (unique smallest OD)
            [4, 2, 1],   # Y -> G2 (WD tie-break: 0.25 < 1.0)
            [6, 2, 7],   # Z -> WD tie again -> deterministic lowest = G1
        ])
        grp = np.asarray(assign_groups(p4r, self.c, self.r,
                                       decay="exp", decay_lambda=0.5))
        assert grp[0] == 1
        assert grp[1] == 2
        assert grp[2] == 1   # paper: random among {G1, G2}; we take lowest

    def test_wd_values_match_paper(self):
        w = decay_weights(self.m, "exp", 0.5)
        tw = total_weight(w)
        assert float(tw) == pytest.approx(1.75)
        y_w = weighted_onehot(jnp.array([[4, 2, 1]]), self.r, w)
        wd = np.asarray(weight_distance(y_w, self.c, tw))[0]
        assert wd[1] == pytest.approx(1.0)    # WD(Y, G1.o1) = 1
        assert wd[2] == pytest.approx(0.25)   # WD(Y, G2.o2) = 0.25
        z_w = weighted_onehot(jnp.array([[6, 2, 7]]), self.r, w)
        wdz = np.asarray(weight_distance(z_w, self.c, tw))[0]
        assert wdz[1] == pytest.approx(1.25) and wdz[2] == pytest.approx(1.25)

    def test_no_overlap_goes_to_fallback(self):
        p4r = jnp.array([[6, 7, 0]])   # zero overlap with o1 and o2
        grp = np.asarray(assign_groups(p4r, self.c, self.r))
        assert grp[0] == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 12), st.integers(16, 64), st.integers(0, 2**31 - 1))
def test_property_od_equals_set_formula(m, r, seed):
    """Property: OD == m − |intersection| for random prefix signatures."""
    rng = np.random.default_rng(seed)
    a = rng.choice(r, size=m, replace=False)
    b = rng.choice(r, size=m, replace=False)
    oh_a = set_onehot(jnp.asarray(a)[None], r)
    oh_b = set_onehot(jnp.asarray(b)[None], r)
    od = float(np.asarray(overlap_distance(oh_a, oh_b, m))[0, 0])
    assert od == m - len(set(a) & set(b))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(2, 64))
def test_property_rank_signature_is_prefix_of_ranking(m, seed):
    """Property: P4→ is always the m nearest pivots in ascending distance."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    pv = jnp.asarray(rng.normal(size=(m + 8, 8)).astype(np.float32))
    p4 = np.asarray(rank_signature(x, pv, m))
    d = np.asarray(pivot_distances(x, pv))
    for i in range(3):
        dd = d[i][p4[i]]
        assert np.all(np.diff(dd) >= -1e-6)              # ascending
        worst = dd[-1]
        others = np.delete(d[i], p4[i])
        assert np.all(others >= worst - 1e-6)            # truly the m nearest
