"""benchmarks/compare.py — bench-trend diffing contract.

The CI bench-trend step must never silently drop a suite: a fresh
``BENCH_*.json`` with no counterpart in the previous artifact set gets an
explicit "new suite, no baseline" row, new cells inside a shared suite get
"new cell, no baseline" rows, and suites not in the historical defaults
are auto-discovered from the fresh run's directory.
"""
import json

import pytest

from benchmarks.compare import (DEFAULT_FILES, compare_file, discover_files,
                                load_cells)


def write_bench(path, cells, bench="engine"):
    path.write_text(json.dumps({"bench": bench, "cells": cells}))


CELL_A = {"batch": 8, "variant": "adaptive", "queries_per_sec": 100.0,
          "recall": 0.95}
CELL_B = {"batch": 16, "variant": "adaptive", "queries_per_sec": 150.0,
          "recall": 0.97}


class TestCompareFile:
    def test_new_suite_emits_explicit_baseline_row(self, tmp_path):
        """A suite absent from the previous artifact set is reported, not
        skipped."""
        new = tmp_path / "BENCH_new_suite.json"
        write_bench(new, [CELL_A, CELL_B])
        lines = compare_file(tmp_path / "prev" / "BENCH_new_suite.json",
                             new, warn_pct=15.0)
        text = "\n".join(lines)
        assert "new suite, no baseline" in text
        assert "2 cell(s) recorded" in text

    def test_missing_fresh_file_reports_skip(self, tmp_path):
        lines = compare_file(tmp_path / "old.json", tmp_path / "gone.json",
                             warn_pct=15.0)
        assert any("skipped" in ln for ln in lines)

    def test_shared_cells_get_deltas_and_flags(self, tmp_path):
        old, new = tmp_path / "old.json", tmp_path / "new.json"
        write_bench(old, [CELL_A])
        worse = dict(CELL_A, queries_per_sec=50.0)      # -50% regression
        write_bench(new, [worse])
        text = "\n".join(compare_file(old, new, warn_pct=15.0))
        assert "-50.0%" in text and "⚠" in text

    def test_new_cell_in_shared_suite_reported(self, tmp_path):
        """A cell keyed by a new identity-column value (e.g. a new
        placement sweep column) gets its own explicit row."""
        old, new = tmp_path / "old.json", tmp_path / "new.json"
        write_bench(old, [CELL_A])
        mesh_cell = dict(CELL_A, placement="mesh")      # new identity key
        write_bench(new, [CELL_A, mesh_cell])
        text = "\n".join(compare_file(old, new, warn_pct=15.0))
        assert "new cell, no baseline" in text
        assert "placement=mesh" in text
        assert "+0.0%" in text or "| 100 | 100 |" in text  # shared compared

    def test_dropped_cells_counted(self, tmp_path):
        old, new = tmp_path / "old.json", tmp_path / "new.json"
        write_bench(old, [CELL_A, CELL_B])
        write_bench(new, [CELL_A])
        text = "\n".join(compare_file(old, new, warn_pct=15.0))
        assert "1 cell(s) no longer produced" in text


class TestDiscovery:
    def test_discovers_non_default_suites(self, tmp_path):
        write_bench(tmp_path / "BENCH_custom.json", [CELL_A])
        files = discover_files(tmp_path)
        assert "BENCH_custom.json" in files
        for name in DEFAULT_FILES:          # defaults always present
            assert name in files

    def test_suite_that_stopped_producing_still_listed(self, tmp_path):
        """A non-default suite present only in the *previous* run must not
        vanish — it gets compare_file's explicit skip line."""
        old_dir = tmp_path / "prev"
        old_dir.mkdir()
        write_bench(old_dir / "BENCH_retired.json", [CELL_A])
        files = discover_files(tmp_path, old_dir)
        assert "BENCH_retired.json" in files
        lines = compare_file(old_dir / "BENCH_retired.json",
                             tmp_path / "BENCH_retired.json", warn_pct=15.0)
        assert any("skipped" in ln for ln in lines)

    def test_zero_prev_metric_has_no_inf(self, tmp_path):
        old, new = tmp_path / "old.json", tmp_path / "new.json"
        write_bench(old, [dict(CELL_A, queries_per_sec=0.0)])
        write_bench(new, [CELL_A])
        text = "\n".join(compare_file(old, new, warn_pct=15.0))
        assert "n/a (prev 0)" in text and "inf" not in text


class TestLoadCells:
    def test_cells_keyed_by_identity_columns(self, tmp_path):
        p = tmp_path / "b.json"
        write_bench(p, [CELL_A, CELL_B])
        cells = load_cells(p)
        assert len(cells) == 2              # batch differs → distinct keys
        # metric-only changes map to the same key (so runs stay comparable)
        write_bench(p, [dict(CELL_A, queries_per_sec=1.0)])
        (key,) = load_cells(p)
        assert key in cells
