"""Documentation integrity — the docs-check CI contract.

Relative markdown links in the operator-facing docs must resolve to real
files, so refactors that move code break the build instead of silently
rotting the documentation plane.  (Doctests on the public API modules are
the other half of the contract; CI runs them via ``pytest
--doctest-modules`` in the docs-check job.)
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

DOC_FILES = sorted(
    [REPO / "README.md", REPO / "benchmarks" / "README.md"]
    + list((REPO / "docs").glob("*.md")))

# [text](target) — markdown inline links, excluding images
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def relative_links(path: Path):
    for m in LINK_RE.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


def test_doc_files_exist():
    """The documentation plane this repo promises actually exists."""
    for p in DOC_FILES:
        assert p.exists(), f"missing doc file {p}"
    names = {p.name for p in DOC_FILES}
    assert {"README.md", "ARCHITECTURE.md", "SERVING.md"} <= names


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_markdown_links_resolve(doc):
    broken = []
    for target in relative_links(doc):
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            broken.append(target)
    assert not broken, f"{doc.relative_to(REPO)} has broken links: {broken}"


def test_architecture_covers_every_package():
    """The which-file-owns-what table must keep naming every repro
    package — including nested subpackages like ``fleet/lifecycle`` — so
    new subsystems get documented when they land."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    root = REPO / "src" / "repro"
    needles = []
    for init in sorted(root.rglob("__init__.py")):
        rel = init.parent.relative_to(root)
        if str(rel) == ".":
            continue
        # top-level packages by name; subpackages by their slash path
        needles.append(str(rel) if len(rel.parts) > 1 else rel.name)
    missing = [pkg for pkg in needles if pkg not in text]
    assert not missing, f"ARCHITECTURE.md does not mention: {missing}"


def test_architecture_covers_every_serve_module():
    """The serving plane now spans an API contract plus a network package;
    every ``serve/**/*.py`` module must hold an owns-table row so the wire
    schema and admission machinery stay documented as they grow."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    root = REPO / "src" / "repro" / "serve"
    missing = []
    for mod in sorted(root.rglob("*.py")):
        if mod.name.startswith("_"):
            continue
        rel = mod.relative_to(root.parent)          # e.g. serve/net/codec.py
        if str(rel) not in text:
            missing.append(str(rel))
    assert not missing, f"ARCHITECTURE.md owns-table misses: {missing}"


def test_architecture_covers_every_eval_module():
    """The recall program is methodology: a new ``eval/*.py`` module means
    a new measurement surface, and it must land with an owns-table row so
    EVALUATION.md's claims stay traceable to code."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    root = REPO / "src" / "repro" / "eval"
    missing = []
    for mod in sorted(root.rglob("*.py")):
        if mod.name.startswith("_"):
            continue
        rel = mod.relative_to(root.parent)          # e.g. eval/metrics.py
        if str(rel) not in text:
            missing.append(str(rel))
    assert not missing, f"ARCHITECTURE.md owns-table misses: {missing}"


def test_architecture_covers_every_fleet_module():
    """The fleet is the subsystem that grows module-by-module (placement,
    device planning, lifecycle…), so the owns-table must name every one of
    its modules individually — a new ``fleet/*.py`` lands with a table row
    or this fails."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    root = REPO / "src" / "repro" / "fleet"
    missing = []
    for mod in sorted(root.rglob("*.py")):
        if mod.name.startswith("_"):
            continue
        rel = mod.relative_to(root.parent)          # e.g. fleet/device_plan.py
        if str(rel) not in text:
            missing.append(str(rel))
    assert not missing, f"ARCHITECTURE.md owns-table misses: {missing}"
