"""Eval-harness structure tests: corpora, hardness splits, the frontier
runner's output document, and recall-target calibration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.data import make_dataset, seismic_like
from repro.eval import (FrontierSpec, RecallCalibration, hardness_split,
                        install_recall_target, perturbed_queries,
                        run_frontier, tenant_corpus)
from repro.eval.frontier import build_eval_fleet

SMOKE = FrontierSpec(
    datasets=("randomwalk",), shard_counts=(2,), shard_size=250,
    series_len=64, num_queries=10, num_calibration=6, k=4,
    fanouts=(1,), thresholds=(0.5,), spend_factors=(1.0,),
    slot_budgets=(1,))


class TestSeismicGenerator:
    def test_shape_dtype_normalization(self):
        x = np.asarray(seismic_like(jax.random.PRNGKey(0), 8, 96))
        assert x.shape == (8, 96) and x.dtype == np.float32
        np.testing.assert_allclose(x.mean(axis=-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(x.std(axis=-1), 1.0, atol=1e-2)

    def test_deterministic_in_key(self):
        a = np.asarray(seismic_like(jax.random.PRNGKey(7), 4, 64))
        b = np.asarray(seismic_like(jax.random.PRNGKey(7), 4, 64))
        c = np.asarray(seismic_like(jax.random.PRNGKey(8), 4, 64))
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_registered(self):
        x = make_dataset("seismic", jax.random.PRNGKey(0), 4, 64)
        assert x.shape == (4, 64)


class TestTenantCorpus:
    def test_shapes_and_meta(self):
        c = tenant_corpus("randomwalk", num_shards=3, shard_size=100,
                          series_len=64, seed=1, affinity=0.5)
        assert len(c.shards) == 3
        assert c.union.shape == (300, 64)
        meta = c.meta()
        assert meta["seed"] == 1 and meta["shard_sizes"] == [100] * 3

    def test_shards_differ_and_are_deterministic(self):
        a = tenant_corpus("randomwalk", num_shards=2, shard_size=50,
                          series_len=64, seed=0)
        b = tenant_corpus("randomwalk", num_shards=2, shard_size=50,
                          series_len=64, seed=0)
        np.testing.assert_array_equal(a.union, b.union)
        assert not np.array_equal(a.shards[0], a.shards[1])

    def test_affinity_concentrates_neighbours(self):
        """With a strong tenant motif, a shard's rows are mutually closer
        than rows across shards — the signal routing depends on."""
        c = tenant_corpus("randomwalk", num_shards=2, shard_size=60,
                          series_len=64, seed=0, affinity=0.8)
        a, b = c.shards
        within = np.linalg.norm(a[:20, None] - a[None, 20:40], axis=-1)
        across = np.linalg.norm(a[:20, None] - b[None, :20], axis=-1)
        assert within.mean() < across.mean()

    def test_perturbed_queries_shape(self):
        c = tenant_corpus("randomwalk", num_shards=2, shard_size=50,
                          series_len=64)
        q = perturbed_queries(c, 7, noise=0.1, seed=3)
        assert q.shape == (7, 64) and q.dtype == np.float32


class TestHardnessSplit:
    def test_disjoint_cover_deterministic(self):
        rng = np.random.default_rng(0)
        dist = np.sort(rng.uniform(1, 10, size=(21, 8)), axis=-1)
        hard, easy = hardness_split(dist, k=4)
        again = hardness_split(dist, k=4)
        assert set(hard) | set(easy) <= set(range(21))
        assert len(set(hard) & set(easy)) == 0
        assert len(hard) == 10 and len(easy) == 11
        np.testing.assert_array_equal(hard, again[0])

    def test_low_contrast_is_hard(self):
        # query 0: d_k=1, d_2k=1.01 (near-tie => hard)
        # query 1: d_k=1, d_2k=9    (contrasted => easy)
        dist = np.array([[0.5, 1.0, 1.005, 1.01],
                         [0.5, 1.0, 5.0, 9.0]])
        hard, easy = hardness_split(dist, k=2)
        assert list(hard) == [0] and list(easy) == [1]

    def test_needs_2k_columns(self):
        with pytest.raises(ValueError):
            hardness_split(np.ones((4, 3)), k=2)


class TestFrontierRunner:
    @pytest.fixture(scope="class")
    def doc(self):
        return run_frontier(SMOKE)

    def test_cells_cover_the_sweep(self, doc):
        cells = doc["cells"]
        routings = {c.get("routing") for c in cells if "routing" in c}
        assert routings == {"exhaustive", "signature", "adaptive"}
        splits = {c["split"] for c in cells if "split" in c}
        assert splits == {"all", "hard", "easy"}
        variants = {c.get("variant") for c in cells if "variant" in c}
        assert "recall_target" in variants
        budgets = {c["slot_budget"] for c in cells if "slot_budget" in c}
        assert budgets == {0, 1}

    def test_metric_ranges(self, doc):
        for c in doc["cells"]:
            if "recall" in c:
                assert 0.0 <= c["recall"] <= 1.0
                assert 0.0 <= c["map"] <= 1.0
                assert c["mean_candidates_scanned"] >= 0

    def test_frontiers_and_gap_sections(self, doc):
        fr = doc["frontiers"]
        assert {f["split"] for f in fr} == {"all", "hard", "easy"}
        for f in fr:
            assert 0.0 <= f["fixed_auc"] <= 1.0
            assert 0.0 <= f["adaptive_auc"] <= 1.0
            assert all(0 <= x <= 1 for x, _ in f["fixed"])
        gap = doc["routed_gap"]
        assert gap, "adaptive cells must produce matched-cost rows"
        for g in gap:
            assert g["improvement"] == pytest.approx(
                g["adaptive_recall"] - g["fixed_recall_at_cost"])

    def test_exhaustive_routing_is_the_scan_ceiling(self, doc):
        """Exhaustive fan-out (same planner/budget) touches at least as
        much data as any routed cell, and no cell exceeds the corpus."""
        total = SMOKE.shard_counts[0] * SMOKE.shard_size
        cells = [c for c in doc["cells"] if c.get("split") == "all"
                 and c.get("slot_budget") == 0
                 and c.get("variant") == "adaptive"]
        exh = [c for c in cells if c["routing"] == "exhaustive"][0]
        for c in cells:
            assert c["mean_candidates_scanned"] \
                <= exh["mean_candidates_scanned"]
            assert c["mean_candidates_scanned"] <= total

    def test_slot_budget_caps_partitions(self, doc):
        """``query_max_slots=b`` compacts each shard's plan to at most
        ``b`` partitions, so a query touches at most ``b * shards`` and
        never scans more than the unbudgeted cell.  (Strict reduction
        requires plans wider than the budget — the full-scale artifact
        shows it; smoke plans are already ~1 slot per shard, so here the
        budget must merely never hurt.)"""
        full = [c for c in doc["cells"]
                if c.get("routing") == "exhaustive"
                and c["split"] == "all" and c["slot_budget"] == 0
                and c["variant"] == "adaptive"][0]
        tight = [c for c in doc["cells"]
                 if c.get("slot_budget") == 1 and c["split"] == "all"][0]
        budget, shards = SMOKE.slot_budgets[0], SMOKE.shard_counts[0]
        assert tight["mean_partitions_touched"] <= budget * shards
        assert tight["mean_candidates_scanned"] \
            <= full["mean_candidates_scanned"]


class TestRecallCalibration:
    CELLS = [{"mean_partitions_touched": 2.0, "recall": 0.5},
             {"mean_partitions_touched": 4.0, "recall": 0.8},
             {"mean_partitions_touched": 8.0, "recall": 0.95}]

    def test_monotone_envelope(self):
        noisy = self.CELLS + [{"mean_partitions_touched": 6.0,
                               "recall": 0.6}]       # dips below envelope
        cal = RecallCalibration.from_cells(noisy)
        assert list(cal.recalls) == sorted(cal.recalls)
        assert cal.predict(3.0) == pytest.approx(0.65)
        assert cal.predict(100.0) == pytest.approx(0.95)

    def test_partitions_for_target(self):
        cal = RecallCalibration.from_cells(self.CELLS)
        assert cal.partitions_for(0.8) == 4.0
        assert cal.partitions_for(0.99) == 8.0   # best available

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            RecallCalibration.from_cells([{"recall": 1.0}])

    def test_install_on_live_fleet(self):
        """install_recall_target sizes the spend from the fleet's live
        partitions-touched histogram and registers the variant."""
        corpus = tenant_corpus("randomwalk", num_shards=2, shard_size=200,
                               series_len=64, seed=0)
        fleet = build_eval_fleet(corpus, SMOKE)
        q = perturbed_queries(corpus, 6, seed=1)
        fleet.query(q, 4)                      # populate touched_hist
        cal = RecallCalibration.from_cells(self.CELLS)
        spend = install_recall_target(fleet, 0.95, cal, max_spend=8.0)
        assert 1.0 <= spend <= 8.0
        d, g, info = fleet.query(q, 4, variant="recall_target")
        assert d.shape == (6, 4)
