"""IndexFleet acceptance tests.

The two hard contracts from the issue:
  * exhaustive-routing + exhaustive-variant fleet results are bit-identical
    to a single-index ``knn_query`` over the concatenated data;
  * ``compact()`` does not change query results on the same fleet contents.
Plus: signature routing, streaming ingest through the assignment path,
global-id stability, and the FleetEngine serving loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import exact_knn
from repro.core import build_index, knn_query
from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.serve import QueryRequest
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = small_cfg()
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   2400, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 7))
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   delta_capacity=4096, auto_compact=False))
    for i in range(3):
        fleet.add_shard(f"tenant{i}", data[i * 800:(i + 1) * 800])
    return fleet, data, queries


class TestExhaustiveEquivalence:
    def test_bit_identical_to_union_index(self, fleet_setup):
        """Acceptance: exhaustive fan-out + exhaustive per-shard variant ==
        single-index knn_query over the concatenated data, bit for bit."""
        fleet, data, queries = fleet_setup
        union = build_index(jax.random.PRNGKey(1), jnp.asarray(data),
                            fleet.cfg.shard_cfg)
        du, gu, _ = knn_query(union, jnp.asarray(queries), K,
                              variant="exhaustive")
        df, gf, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        np.testing.assert_array_equal(np.asarray(gu), gf)
        np.testing.assert_array_equal(np.asarray(du), df)

    def test_equals_brute_force(self, fleet_setup):
        fleet, data, queries = fleet_setup
        _, exact_ids = exact_knn(jnp.asarray(queries), jnp.asarray(data), K)
        _, gf, _ = fleet.query(queries, K, routing="exhaustive",
                               variant="exhaustive")
        for i in range(len(queries)):
            assert set(gf[i].tolist()) == set(np.asarray(exact_ids)[i].tolist())

    def test_scan_exact_matches_per_shard_fanout(self, fleet_setup):
        """The fused-store fallback (one refine over concat_stores) equals
        the per-shard scatter/gather + merge_topk path."""
        fleet, _, queries = fleet_setup
        df, gf, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        ds, gs = fleet.scan_exact(queries, K)
        np.testing.assert_array_equal(gs, gf)
        np.testing.assert_array_equal(ds, df)

    def test_empty_fleet_returns_pads(self):
        fleet = IndexFleet(FleetConfig(shard_cfg=small_cfg()))
        q = np.zeros((2, 64), np.float32)
        d, g, info = fleet.query(q, K)
        assert (g == -1).all()
        d2, g2 = fleet.scan_exact(q, K)
        assert (g2 == -1).all()


class TestSignatureRouting:
    def test_routes_subset_and_tracks_stats(self, fleet_setup):
        fleet, _, queries = fleet_setup
        before = fleet.stats.routed_pairs
        _, _, info = fleet.query(queries, K, routing="signature")
        assert info.routed_mask.shape == (len(queries), len(fleet.shards))
        np.testing.assert_array_equal(info.routed_mask.sum(axis=1),
                                      np.full(len(queries), 2))
        assert fleet.stats.routed_pairs - before == int(info.routed_mask.sum())
        assert fleet.stats.fanout_savings > 0

    def test_full_fanout_equals_exhaustive_routing(self, fleet_setup):
        """fanout >= #shards must reproduce exhaustive routing exactly."""
        fleet, _, queries = fleet_setup
        d1, g1, _ = fleet.query(queries, K, routing="signature",
                                fanout=len(fleet.shards))
        d2, g2, _ = fleet.query(queries, K, routing="exhaustive")
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(d1, d2)

    def test_audit_precision_bounds(self, fleet_setup):
        fleet, _, queries = fleet_setup
        p = fleet.audit_routing(queries, K)
        assert 0.0 <= p <= 1.0
        assert fleet.stats.routing_audits >= 1
        assert fleet.stats.routing_precision == pytest.approx(
            fleet.stats.routing_overlap / fleet.stats.routing_audits)

    def test_unknown_routing_mode(self, fleet_setup):
        fleet, _, queries = fleet_setup
        with pytest.raises(ValueError, match="routing"):
            fleet.query(queries, K, routing="nope")


class TestStreamingIngest:
    def make_fleet(self, **kw):
        cfg = small_cfg()
        data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(3),
                                       1600, 64))
        fc = dict(shard_cfg=cfg, fanout=2, delta_capacity=4096,
                  auto_compact=False)
        fc.update(kw)
        fleet = IndexFleet(FleetConfig(**fc))
        fleet.add_shard("t0", data[:800])
        fleet.add_shard("t1", data[800:])
        return fleet, data

    def test_insert_assigns_contiguous_global_ids(self):
        fleet, data = self.make_fleet()
        batch = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(4),
                                        50, 64))
        gids = fleet.insert(batch)
        np.testing.assert_array_equal(gids, np.arange(1600, 1650))
        assert fleet.total_records == 1650
        assert fleet.delta.occupancy == 50
        assert fleet.stats.delta_occupancy == 50

    def test_inserted_record_immediately_visible(self):
        fleet, data = self.make_fleet()
        dup = data[7:8]
        gid = fleet.insert(dup)[0]
        d, g, _ = fleet.query(dup, K, routing="exhaustive",
                              variant="exhaustive")
        assert 7 in g[0] and gid in g[0]
        # self-distance through the float32 norm trick is only zero up to
        # cancellation noise
        assert d[0, 0] < 1e-2

    def test_delta_absorbs_through_assignment_path(self):
        """Once the delta index exists, further batches scatter into free
        partition slots without a rebuild."""
        fleet, _ = self.make_fleet()
        big = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(5),
                                      100, 64))
        fleet.insert(big)                       # crosses min_build → rebuild
        assert fleet.delta.index is not None
        rebuilds = fleet.delta.rebuilds
        fleet.insert(big[:30] * 1.1)            # small batch → in-place
        assert fleet.delta.rebuilds == rebuilds
        assert fleet.delta.occupancy == 130
        # the scattered records are served through the delta's planner
        d, g, _ = fleet.query(big[:2] * 1.1, K, routing="exhaustive",
                              variant="exhaustive")
        assert d[0, 0] < 1e-2 and d[1, 0] < 1e-2

    def test_compact_preserves_results(self):
        """Acceptance: post-compact results equal pre-compact results."""
        fleet, _ = self.make_fleet()
        batch = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(6),
                                        120, 64))
        fleet.insert(batch)
        queries = np.asarray(make_queries(
            jax.random.PRNGKey(7), jnp.asarray(batch), 5))
        d1, g1, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        handle = fleet.compact()
        assert handle is not None and handle.sealed
        assert fleet.delta.occupancy == 0
        assert fleet.stats.compactions == 1
        d2, g2, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(d1, d2)
        # compacting an empty delta is a no-op
        assert fleet.compact() is None

    def test_auto_compact_seals_at_capacity(self):
        fleet, _ = self.make_fleet(delta_capacity=100, auto_compact=True)
        for i in range(3):
            fleet.insert(np.asarray(make_dataset(
                "randomwalk", jax.random.PRNGKey(10 + i), 60, 64)))
        assert fleet.stats.compactions >= 1
        assert fleet.delta.occupancy < 100
        assert any(s.key.startswith("sealed:") for s in fleet.shards)

    def test_small_first_insert_into_empty_fleet(self):
        """Streaming-first fleet: batches smaller than num_pivots must not
        crash router construction, and a too-small compact() must refuse
        without losing the buffered records."""
        cfg = small_cfg()                    # num_pivots=32
        fleet = IndexFleet(FleetConfig(shard_cfg=cfg, auto_compact=False))
        small = np.asarray(make_dataset("randomwalk",
                                        jax.random.PRNGKey(30), 3, 64))
        fleet.insert(small)
        assert fleet.router is None          # deferred until enough rows
        d, g, _ = fleet.query(small[:1], K)  # exhaustive fallback serves it
        assert g[0, 0] == 0
        with pytest.raises(ValueError, match="cannot compact"):
            fleet.compact()
        assert fleet.delta.occupancy == 3    # refusal lost nothing
        fleet.insert(np.asarray(make_dataset(
            "randomwalk", jax.random.PRNGKey(31), 60, 64)))
        assert fleet.router is not None      # built from accumulated delta
        handle = fleet.compact()
        assert handle is not None
        assert fleet.delta.occupancy == 0
        assert fleet.total_records == 63

    def test_insert_rejects_bad_shape(self):
        fleet, _ = self.make_fleet()
        with pytest.raises(ValueError, match="insert batch"):
            fleet.insert(np.zeros((3, 7), np.float32))
        with pytest.raises(ValueError, match="duplicate shard key"):
            fleet.add_shard("t0", np.zeros((300, 64), np.float32))


class TestFleetEngine:
    def test_run_matches_fleet_query(self, fleet_setup):
        fleet, _, queries = fleet_setup
        eng = FleetEngine(fleet, batch_size=4, k=K, routing="exhaustive",
                          variant="exhaustive")
        dist, gid, metrics = eng.run(queries)
        df, gf, _ = fleet.query(queries, K, routing="exhaustive",
                                variant="exhaustive")
        np.testing.assert_array_equal(gid, gf)
        np.testing.assert_array_equal(dist, df)
        assert len(metrics) == len(queries)
        assert all(m.partitions_touched >= 1 for m in metrics)

    def test_queue_mode(self, fleet_setup):
        fleet, _, queries = fleet_setup
        eng = FleetEngine(fleet, batch_size=4, k=K)
        reqs = [QueryRequest(rid=i, series=queries[i], k=5)
                for i in range(len(queries))]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert eng.stats.queries == len(queries)
        assert eng.stats.queries_per_sec > 0

    def test_rejects_bad_requests(self, fleet_setup):
        fleet, _, queries = fleet_setup
        eng = FleetEngine(fleet, batch_size=4, k=K)
        with pytest.raises(ValueError, match="series shape"):
            eng.submit(QueryRequest(rid=0, series=queries[0][:5]))
        with pytest.raises(ValueError, match="routing"):
            FleetEngine(fleet, routing="nope")


class TestGlobalIdRemapping:
    def test_custom_global_ids(self):
        """Shard-local ids remap through caller-provided global id maps."""
        cfg = small_cfg()
        data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(8),
                                       600, 64))
        fleet = IndexFleet(FleetConfig(shard_cfg=cfg))
        custom = np.arange(600, dtype=np.int32) * 7 + 3
        fleet.add_shard("t0", data, global_ids=custom)
        q = data[11:12]
        _, g, _ = fleet.query(q, K, routing="exhaustive",
                              variant="exhaustive")
        assert g[0, 0] == custom[11]
        # next auto-assigned ids start above the custom range
        gids = fleet.insert(data[:3])
        assert gids.min() > custom.max()
