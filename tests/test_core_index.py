"""Tests for CLIMBER-INX: Algorithm 2, trie/packing, routing, store, queries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # not in the container; vendored fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (ClimberIndex, TrieDevice, assign_groups, build_forest,
                        build_index, compute_centroids, descend, ffd_pack,
                        knn_query, route_records, squared_l2_pairwise)
from repro.data import make_dataset, make_queries
from repro.utils.config import ClimberConfig


# ----------------------------------------------------------------------
# Algorithm 2 — centroid computation
# ----------------------------------------------------------------------
class TestCentroids:
    def test_highest_freq_first_and_spread(self):
        # 3 signature patterns; the most frequent must be centroid #1
        sigs = np.array([[0, 1, 2]] * 50 + [[0, 1, 3]] * 30 + [[5, 6, 7]] * 20,
                        dtype=np.int32)
        cs = compute_centroids(sigs, 10, sample_frac=1.0, capacity=5, min_od=2)
        # row 0 fallback; row 1 must be the most frequent signature
        np.testing.assert_array_equal(cs.sigs[1], [0, 1, 2])
        # [0,1,3] has OD=1 from [0,1,2] < eps=2 -> skipped; [5,6,7] admitted
        assert any((cs.sigs[i] == [5, 6, 7]).all() for i in range(1, cs.num_groups))
        assert not any((cs.sigs[i] == [0, 1, 3]).all()
                       for i in range(1, cs.num_groups))

    def test_tiny_group_stop(self):
        sigs = np.array([[0, 1, 2]] * 100 + [[4, 5, 6]] * 1, dtype=np.int32)
        cs = compute_centroids(sigs, 10, sample_frac=1.0, capacity=50, min_od=2)
        # the singleton signature estimate (1 + remaining/2) << 50 -> stop
        assert cs.num_groups == 2  # fallback + 1

    def test_max_centroids_cap(self):
        rng = np.random.default_rng(0)
        sigs = np.stack([rng.choice(64, 4, replace=False) for _ in range(500)])
        sigs = np.sort(sigs.astype(np.int32), axis=-1)
        cs = compute_centroids(sigs, 64, sample_frac=1.0, capacity=1,
                               min_od=1, max_centroids=5)
        assert cs.num_groups <= 6

    def test_fallback_row_zero_is_empty(self):
        sigs = np.array([[0, 1, 2]] * 10, dtype=np.int32)
        cs = compute_centroids(sigs, 10, sample_frac=1.0, capacity=1)
        assert cs.onehot[0].sum() == 0


# ----------------------------------------------------------------------
# FFD packing (Def. 13)
# ----------------------------------------------------------------------
class TestPacking:
    def test_simple(self):
        assign, nbins = ffd_pack([3, 3, 2, 2], 5)
        assert nbins == 2
        loads = np.bincount(assign, weights=[3, 3, 2, 2])
        assert np.all(loads <= 5)

    def test_oversize_gets_own_bin(self):
        assign, nbins = ffd_pack([10, 1], 5)
        assert nbins == 2

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(0.1, 10.0), min_size=1, max_size=40),
           st.floats(1.0, 20.0))
    def test_property_capacity_and_bound(self, sizes, cap):
        assign, nbins = ffd_pack(sizes, cap)
        assert np.all(np.asarray(assign) >= 0)
        loads = np.zeros(nbins)
        for s, b in zip(sizes, assign):
            loads[b] += s
        for b in range(nbins):
            members = [s for s, a in zip(sizes, assign) if a == b]
            # capacity holds unless the bin is a single oversized item
            assert loads[b] <= cap + 1e-9 or len(members) == 1
        # FFD guarantee: nbins <= 1.5 * OPT + 1 <= 1.5 * (lower bound) + 1
        # where a valid lower bound is ceil(sum(fitting items)/cap) + #oversized
        oversized = sum(1 for s in sizes if s > cap)
        fitting = sum(s for s in sizes if s <= cap)
        lb = oversized + int(np.ceil(fitting / cap))
        assert nbins <= max(1.5 * lb + 1, lb)


# ----------------------------------------------------------------------
# Trie construction + vectorised descent
# ----------------------------------------------------------------------
def _small_forest():
    rng = np.random.default_rng(7)
    m, r = 4, 12
    sigs = np.stack([rng.choice(r, m, replace=False) for _ in range(200)]).astype(np.int32)
    freqs = rng.integers(1, 20, size=200)
    groups = rng.integers(0, 3, size=200)
    forest = build_forest(sigs, freqs, groups, 3, r, capacity=100.0,
                          sample_frac=1.0)
    return forest, sigs, freqs, groups, m, r


class TestTrie:
    def test_leaf_capacity_or_depth(self):
        forest, sigs, freqs, groups, m, r = _small_forest()
        is_leaf = np.diff(forest.child_start) == 0
        for nid in np.nonzero(is_leaf)[0]:
            assert (forest.node_size[nid] <= 100.0
                    or forest.node_depth[nid] == m)

    def test_dfs_intervals_nested(self):
        forest, *_ = _small_forest()
        for e in range(len(forest.edge_child)):
            child = forest.edge_child[e]
            # find parent by scanning child_start ranges
            parent = np.searchsorted(forest.child_start, e, side="right") - 1
            assert forest.dfs_in[parent] <= forest.dfs_in[child]
            assert forest.dfs_out[child] <= forest.dfs_out[parent]

    def test_descend_matches_python_walk(self):
        forest, sigs, freqs, groups, m, r = _small_forest()
        trie = TrieDevice.from_forest(forest)
        node, pathlen, parent = descend(trie, jnp.asarray(sigs),
                                        jnp.asarray(groups))
        node, pathlen = np.asarray(node), np.asarray(pathlen)

        # python reference walk over the CSR structure
        for i in range(len(sigs)):
            cur = forest.group_root[groups[i]]
            depth = 0
            for d in range(m):
                lo, hi = forest.child_start[cur], forest.child_start[cur + 1]
                edges = dict(zip(forest.edge_pivot[lo:hi],
                                 forest.edge_child[lo:hi]))
                nxt = edges.get(sigs[i][d])
                if nxt is None:
                    break
                cur = nxt
                depth += 1
            assert node[i] == cur, f"row {i}"
            assert pathlen[i] == depth

    def test_route_records_leaf_vs_default(self):
        forest, sigs, freqs, groups, m, r = _small_forest()
        trie = TrieDevice.from_forest(forest)
        part, rec_dfs = route_records(trie, jnp.asarray(sigs),
                                      jnp.asarray(groups))
        part = np.asarray(part)
        assert np.all(part >= 0) and np.all(part < forest.num_partitions)
        # every group's partitions must be disjoint across groups
        # (partition ids are allocated per group, monotonically)
        for g in range(3):
            mask = groups == g
            gparts = set(part[mask])
            for g2 in range(g + 1, 3):
                assert gparts.isdisjoint(set(part[groups == g2]))


# ----------------------------------------------------------------------
# End-to-end index + query
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_index():
    cfg = ClimberConfig(series_len=128, paa_segments=16, num_pivots=48,
                        prefix_len=6, capacity=256, sample_frac=0.2,
                        max_centroids=24, k=20, candidate_groups=4,
                        adaptive_factor=4)
    data = make_dataset("randomwalk", jax.random.PRNGKey(0), 6000, 128)
    index = build_index(jax.random.PRNGKey(1), data, cfg)
    return index, data


class TestIndexQuery:
    def test_store_holds_every_record_once(self, small_index):
        index, data = small_index
        gids = np.asarray(index.store.rec_gid).ravel()
        live = np.sort(gids[gids >= 0])
        np.testing.assert_array_equal(live, np.arange(data.shape[0]))

    def test_partition_counts(self, small_index):
        index, _ = small_index
        counts = np.asarray(index.store.count)
        per_gid = (np.asarray(index.store.rec_gid) >= 0).sum(axis=1)
        np.testing.assert_array_equal(counts, per_gid)

    def test_self_query_finds_itself(self, small_index):
        index, data = small_index
        q = data[:8]
        dist, gid, _ = knn_query(index, q, 5, variant="adaptive")
        gid = np.asarray(gid)
        dist = np.asarray(dist)
        for i in range(8):
            assert i in gid[i], "a dataset member must retrieve itself"
            pos = list(gid[i]).index(i)
            # float32 |a|^2-2ab+|b|^2 cancellation => O(1e-2) absolute floor
            assert dist[i][pos] == pytest.approx(0.0, abs=5e-2)

    def test_recall_ladder(self, small_index):
        """adaptive >= knn and od_smallest >= adaptive (more data scanned)."""
        index, data = small_index
        q = make_queries(jax.random.PRNGKey(3), data, 24)
        gt = np.argsort(np.asarray(squared_l2_pairwise(q, data)), axis=1)[:, :20]
        recalls = {}
        touched = {}
        for v in ("knn", "adaptive", "od_smallest"):
            _, gid, plan = knn_query(index, q, 20, variant=v)
            gid = np.asarray(gid)
            recalls[v] = np.mean([
                len(set(gid[i][gid[i] >= 0]) & set(gt[i])) / 20
                for i in range(len(q))])
            touched[v] = float(np.asarray(plan.partitions_touched()).mean())
        assert recalls["adaptive"] >= recalls["knn"] - 1e-9
        assert recalls["od_smallest"] >= recalls["adaptive"] - 0.05
        assert recalls["adaptive"] > 0.25, recalls
        # OD-smallest must touch at least as many partitions
        assert touched["od_smallest"] >= touched["adaptive"] - 1e-9

    def test_results_sorted_and_valid(self, small_index):
        index, data = small_index
        q = make_queries(jax.random.PRNGKey(5), data, 10)
        dist, gid, _ = knn_query(index, q, 20)
        dist, gid = np.asarray(dist), np.asarray(gid)
        for i in range(10):
            live = gid[i] >= 0
            d = dist[i][live]
            assert np.all(np.diff(d) >= -1e-5), "ascending ED required"
            ids = gid[i][live]
            assert len(set(ids)) == len(ids), "no duplicate answers"

    def test_exact_distances(self, small_index):
        """Refine must return true ED, not an approximation."""
        index, data = small_index
        q = make_queries(jax.random.PRNGKey(7), data, 4)
        dist, gid, _ = knn_query(index, q, 10)
        dist, gid = np.asarray(dist), np.asarray(gid)
        data_np = np.asarray(data)
        qn = np.asarray(q)
        for i in range(4):
            for j in range(10):
                if gid[i, j] >= 0:
                    true = np.linalg.norm(qn[i] - data_np[gid[i, j]])
                    # float32 norm-trick cancellation => absolute floor ~1e-2
                    assert dist[i, j] == pytest.approx(true, rel=5e-3, abs=2e-2)
