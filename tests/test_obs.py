"""Observability plane — registry, histograms, tracer, exporters.

The acceptance contracts from the issue:
  * **histogram quantiles** track ``numpy.percentile`` within the bucket
    quantization bound (growth 1.05 → ≤2.5% relative at the geometric
    midpoint), with exact extremes;
  * **span nesting** stays correct per-thread: a serving thread and the
    background compaction worker interleave spans in the ring without
    corrupting either tree, and one fleet query yields a complete
    admission → plan → refine → merge tree;
  * **exporters** emit the golden Prometheus / JSONL / snapshot formats;
  * **back-compat**: ``FleetStats.snapshot()`` / ``EngineStats.snapshot()``
    keep the exact key sets benchmark artifacts already depend on.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.fleet.fleet import FleetStats
from repro.obs import (REGISTRY, TRACER, MetricsRegistry, SpanTracer,
                       snapshot, spans_jsonl, to_prometheus)
from repro.obs.export import prom_name
from repro.obs.registry import Counter, Gauge, Histogram
from repro.serve import EngineStats, QueryRequest
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


def mkdata(seed: int, n: int) -> np.ndarray:
    return np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(seed),
                                   n, 64))


def mkfleet(**kw) -> IndexFleet:
    fc = dict(shard_cfg=small_cfg(), fanout=1, delta_capacity=4096,
              auto_compact=False)
    fc.update(kw)
    fleet = IndexFleet(FleetConfig(**fc))
    data = mkdata(0, 1600)
    fleet.add_shard("t0", data[:800])
    fleet.add_shard("t1", data[800:])
    return fleet


def span_names(tree: dict) -> set:
    out = {tree["name"]}
    for kid in tree["children"]:
        out |= span_names(kid)
    return out


# ---------------------------------------------------------------------------
# histogram: quantile accuracy, bucket edges, lifecycle
# ---------------------------------------------------------------------------

class TestHistogram:
    @pytest.mark.parametrize("dist", ["lognormal", "uniform", "exp"])
    def test_quantiles_track_numpy(self, dist):
        rng = np.random.default_rng(7)
        vals = {"lognormal": lambda: np.exp(rng.normal(2.0, 1.0, 5000)),
                "uniform": lambda: rng.uniform(0.5, 500.0, 5000),
                "exp": lambda: rng.exponential(30.0, 5000)}[dist]()
        h = Histogram()
        for v in vals:
            h.observe(v)
        # same rank convention as numpy's 'lower' method (rank q·(n−1),
        # no interpolation), so only the bucket quantization differs —
        # at most half a bucket width ≈ growth**0.5 − 1 ≈ 2.47% relative
        for q in (0.10, 0.50, 0.90, 0.95, 0.99):
            exact = float(np.percentile(vals, q * 100, method="lower"))
            assert abs(h.quantile(q) - exact) / exact < 0.026, \
                f"{dist} q={q}: hist {h.quantile(q)} vs numpy {exact}"

    def test_extremes_are_exact(self):
        h = Histogram()
        for v in (3.7, 1.23, 900.5, 42.0):
            h.observe(v)
        assert h.quantile(0.0) == h.min == 1.23
        assert h.quantile(1.0) == h.max == 900.5
        assert h.count == 4 and h.sum == pytest.approx(947.43)

    def test_underflow_overflow_clamp_to_observed(self):
        h = Histogram(lo=1.0, hi=100.0)
        h.observe(0.001)        # below lo → underflow bucket
        h.observe(5000.0)       # above hi → overflow bucket
        assert h.count == 2
        assert h.quantile(0.0) == 0.001 and h.quantile(1.0) == 5000.0

    def test_nan_rejected_empty_zero(self):
        h = Histogram()
        assert h.quantile(0.5) == 0.0 and h.count == 0
        h.observe(float("nan"))
        assert h.count == 0
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_reset(self):
        h = Histogram()
        h.observe(10.0)
        h.reset()
        assert h.count == 0 and h.sum == 0.0 and h.quantile(0.5) == 0.0

    def test_percentiles_trio(self):
        h = Histogram()
        for v in range(1, 101):
            h.observe(float(v))
        p = h.percentiles()
        assert sorted(p) == ["p50", "p95", "p99"]
        assert p["p50"] <= p["p95"] <= p["p99"]


# ---------------------------------------------------------------------------
# registry: get-or-create, kind safety, collectors
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_get_or_create_same_object(self):
        reg = MetricsRegistry()
        c = reg.counter("x.q", loop="a")
        c.inc(3)
        assert reg.counter("x.q", loop="a") is c
        assert isinstance(reg.gauge("x.depth"), Gauge)
        assert isinstance(reg.histogram("x.lat"), Histogram)
        # different labels → different series
        assert reg.counter("x.q", loop="b") is not c
        assert reg.counter("x.q", loop="b").value == 0

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("x.q")
        with pytest.raises(TypeError, match="already registered as Counter"):
            reg.gauge("x.q")
        with pytest.raises(TypeError, match="not Histogram"):
            reg.histogram("x.q")

    def test_counter_monotonic(self):
        c = Counter()
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_collector_scraped_at_read_time(self):
        reg = MetricsRegistry()
        state = {"depth": 1.0}
        reg.add_collector(lambda: {"pool.depth": state["depth"]}, pool="p0")
        assert list(reg.collected()) == [("pool.depth", {"pool": "p0"}, 1.0)]
        state["depth"] = 7.0        # pull-based: next scrape sees the update
        assert list(reg.collected())[0][2] == 7.0

    def test_dead_collector_pruned(self):
        import weakref

        class Owner:
            def vals(self):
                return {"owner.alive": 1.0}

        reg = MetricsRegistry()
        o = Owner()
        ref = weakref.ref(o)
        reg.add_collector(lambda: (lambda s: s.vals() if s else None)(ref()))
        assert len(list(reg.collected())) == 1
        del o
        assert list(reg.collected()) == []       # None → dropped
        assert list(reg.collected()) == []       # and unregistered
        assert len(reg._collectors) == 0

    def test_snapshot_slots(self):
        reg = MetricsRegistry()
        reg.counter("a.n").inc(2)
        reg.gauge("a.g", loop="e0").set(1.5)
        reg.histogram("a.h").observe(4.0)
        snap = reg.snapshot()
        assert snap["counters"] == {"a.n": 2}
        assert snap["gauges"] == {"a.g{loop=e0}": 1.5}
        assert snap["histograms"]["a.h"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer: nesting, ring bound, cross-thread interleaving
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nesting_and_tree(self):
        tr = SpanTracer()
        with tr.span("root", tick=1):
            with tr.span("child.a"):
                with tr.span("leaf"):
                    pass
            with tr.span("child.b"):
                pass
        roots = tr.roots()
        assert [r.name for r in roots] == ["root"]
        tree = tr.tree(roots[0].trace_id)
        assert tree["name"] == "root" and tree["attrs"] == {"tick": 1}
        assert [k["name"] for k in tree["children"]] == ["child.a", "child.b"]
        assert tree["children"][0]["children"][0]["name"] == "leaf"
        # durations nest: parent covers its children
        spans = {s.name: s for s in tr.spans()}
        assert spans["root"].duration_ms >= spans["child.a"].duration_ms

    def test_span_yields_live_measurement(self):
        tr = SpanTracer()
        with tr.span("work") as sp:
            pass
        assert sp.duration_ms >= 0.0
        assert sp.to_dict()["name"] == "work"

    def test_ring_is_bounded(self):
        tr = SpanTracer(capacity=8)
        for i in range(20):
            with tr.span("tick", i=i):
                pass
        spans = tr.spans()
        assert len(spans) == 8
        assert [s.attrs["i"] for s in spans] == list(range(12, 20))

    def test_registry_gets_span_histograms(self):
        reg = MetricsRegistry()
        tr = SpanTracer(registry=reg)
        with tr.span("stage"):
            pass
        h = reg.histogram("span.stage")
        assert h.count == 1

    def test_threads_do_not_corrupt_each_other(self):
        tr = SpanTracer(capacity=100_000)
        barrier = threading.Barrier(4)

        def worker(tag):
            barrier.wait()
            for i in range(200):
                with tr.span(f"outer.{tag}"):
                    with tr.span(f"inner.{tag}"):
                        pass

        threads = [threading.Thread(target=worker, args=(t,), name=f"w{t}")
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        spans = tr.spans()
        assert len(spans) == 4 * 200 * 2
        by_id = {s.span_id: s for s in spans}
        for s in spans:
            if s.name.startswith("inner."):
                parent = by_id[s.parent_id]
                tag = s.name.split(".")[1]
                # every inner span hangs off ITS thread's outer span
                assert parent.name == f"outer.{tag}"
                assert parent.thread == s.thread
                assert s.trace_id == parent.span_id
            else:
                assert s.parent_id is None and s.trace_id == s.span_id

    def test_last_trace_filters_by_root_name(self):
        tr = SpanTracer()
        with tr.span("a"):
            pass
        with tr.span("b"):
            pass
        assert tr.last_trace()["name"] == "b"
        assert tr.last_trace("a")["name"] == "a"
        assert tr.last_trace("nope") is None

    def test_jsonl_event_log(self, tmp_path):
        tr = SpanTracer()
        path = tmp_path / "spans.jsonl"
        tr.attach_jsonl(path)
        with tr.span("outer", rows=3):
            with tr.span("inner"):
                pass
        tr.detach_jsonl()
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert [l["name"] for l in lines] == ["inner", "outer"]  # end order
        assert lines[1]["attrs"] == {"rows": 3}
        assert lines[0]["parent_id"] == lines[1]["span_id"]


# ---------------------------------------------------------------------------
# exporters: golden formats
# ---------------------------------------------------------------------------

class TestExporters:
    def test_prometheus_golden_page(self):
        reg = MetricsRegistry()
        reg.counter("serve.queries", loop="e0").inc(12)
        reg.gauge("serve.queue_depth", loop="e0").set(3)
        h = reg.histogram("serve.latency_ms", loop="e0")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        reg.add_collector(lambda: {"fleet.shards": 2.0}, fleet="f0")
        page = to_prometheus(reg)
        assert page == (
            '# TYPE repro_serve_latency_ms summary\n'
            'repro_serve_latency_ms{loop="e0",quantile="0.5"} '
            + repr(h.quantile(0.5)) + '\n'
            'repro_serve_latency_ms{loop="e0",quantile="0.95"} '
            + repr(h.quantile(0.95)) + '\n'
            'repro_serve_latency_ms{loop="e0",quantile="0.99"} '
            + repr(h.quantile(0.99)) + '\n'
            'repro_serve_latency_ms_count{loop="e0"} 4\n'
            'repro_serve_latency_ms_sum{loop="e0"} 10\n'
            '# TYPE repro_serve_queries_total counter\n'
            'repro_serve_queries_total{loop="e0"} 12\n'
            '# TYPE repro_serve_queue_depth gauge\n'
            'repro_serve_queue_depth{loop="e0"} 3\n'
            '# TYPE repro_fleet_shards gauge\n'
            'repro_fleet_shards{fleet="f0"} 2\n')

    def test_prom_name_sanitizes(self):
        assert prom_name("fleet.query_latency_ms") == \
            "repro_fleet_query_latency_ms"
        assert prom_name("span.compact.seal") == "repro_span_compact_seal"

    def test_spans_jsonl_roundtrip(self):
        tr = SpanTracer()
        with tr.span("q", n=2):
            pass
        doc = spans_jsonl(tr.spans())
        (line,) = doc.strip().splitlines()
        rec = json.loads(line)
        assert rec["name"] == "q" and rec["attrs"] == {"n": 2}
        assert list(rec) == sorted(rec)          # sorted keys: stable diffs

    def test_snapshot_stable_and_prom_named(self):
        reg = MetricsRegistry()
        reg.counter("a.n").inc(1)
        reg.histogram("a.h").observe(2.0)
        s1, s2 = snapshot(reg), snapshot(reg)
        assert s1 == s2
        assert json.dumps(s1, sort_keys=True) == json.dumps(s2,
                                                            sort_keys=True)
        assert "repro_a_n_total" in s1["counters"]
        assert sorted(s1["histograms"]["repro_a_h"]) == \
            ["count", "max", "min", "p50", "p95", "p99", "sum"]

    def test_snapshot_includes_traces(self):
        reg = MetricsRegistry()
        tr = SpanTracer(registry=reg)
        with tr.span("root"):
            with tr.span("leaf"):
                pass
        s = snapshot(reg, tracer=tr)
        assert s["traces"][0]["name"] == "root"
        assert s["traces"][0]["children"][0]["name"] == "leaf"


# ---------------------------------------------------------------------------
# snapshot() back-compat: the dict contracts benchmarks already consume
# ---------------------------------------------------------------------------

class TestSnapshotBackCompat:
    FLEET_KEYS = {
        "queries", "inserts", "compactions", "delta_rebuilds",
        "delta_occupancy", "routed_pairs", "exhaustive_pairs",
        "routing_audits", "routing_overlap", "compaction_ms", "wal_bytes",
        "merges", "retired_shards", "per_shard_queries",
        "per_shard_partitions", "routing_precision", "fanout_savings"}
    ENGINE_KEYS = {
        "queries", "ticks", "total_s", "partitions_touched",
        "candidates_scanned", "plan_cache_hits", "plan_cache_misses",
        "queries_per_sec", "mean_partitions_touched",
        "mean_candidates_scanned", "plan_cache_hit_rate"}

    def test_fleet_stats_keys_unchanged(self):
        assert set(FleetStats().snapshot()) == self.FLEET_KEYS
        assert set(FleetStats().lifecycle_snapshot()) == {
            "compaction_ms", "wal_bytes", "merges", "retired_shards"}

    def test_engine_stats_keys_unchanged(self):
        assert set(EngineStats().snapshot()) == self.ENGINE_KEYS


# ---------------------------------------------------------------------------
# integration: the query path's span tree + metrics, live fleet
# ---------------------------------------------------------------------------

class TestFleetIntegration:
    def test_fleet_query_span_tree_complete(self):
        fleet = mkfleet()
        queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                          mkdata(0, 1600), 4))
        engine = FleetEngine(fleet, batch_size=4, k=K, routing="exhaustive")
        TRACER.clear()
        engine.run(queries)
        tree = TRACER.last_trace("serve.tick")
        assert tree is not None
        names = span_names(tree)
        # the full admission → plan → refine → merge path, one tree
        assert {"serve.tick", "fleet.query", "fleet.plan", "fleet.refine",
                "fleet.merge"} <= names
        fq = [c for c in tree["children"] if c["name"] == "fleet.query"]
        assert len(fq) == 1 and fq[0]["attrs"]["placement"] == "host"
        # per-query latency histogram observed one row per live request
        assert engine.latency_hist.count == 4
        assert fleet.query_hist.count == 1

    def test_engine_reset_metrics_clears_fleet_and_histograms(self):
        fleet = mkfleet()
        queries = np.asarray(make_queries(jax.random.PRNGKey(3),
                                          mkdata(0, 1600), 2))
        engine = FleetEngine(fleet, batch_size=2, k=K)
        engine.run(queries)
        assert engine.stats.queries == 2 and fleet.stats.queries >= 1
        engine.reset_metrics()
        assert engine.stats.queries == 0 and fleet.stats.queries == 0
        assert engine.latency_hist.count == 0
        assert fleet.query_hist.count == 0

    def test_ingest_spans(self):
        fleet = mkfleet()
        TRACER.clear()
        fleet.insert(mkdata(5, 32))
        tree = TRACER.last_trace("fleet.insert")
        assert tree is not None
        assert {"delta.scatter"} <= span_names(tree)

    def test_host_plan_cache_hits(self):
        fleet = mkfleet(plan_cache_size=64)
        queries = np.asarray(make_queries(jax.random.PRNGKey(4),
                                          mkdata(0, 1600), 4))
        d1, g1, i1 = fleet.query(queries, K, routing="exhaustive",
                                 placement="host")
        assert i1.plan_cache_hits == 0 and i1.plan_cache_misses > 0
        d2, g2, i2 = fleet.query(queries, K, routing="exhaustive",
                                 placement="host")
        assert i2.plan_cache_misses == 0
        assert i2.plan_cache_hits == i1.plan_cache_misses
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(d1, d2)

    def test_spans_interleave_with_concurrent_compaction(self):
        """The compaction hammer: a query thread serves while the worker
        seals the delta — both span trees come out intact."""
        fleet = mkfleet()
        fleet.insert(mkdata(6, 256))
        queries = np.asarray(make_queries(jax.random.PRNGKey(7),
                                          mkdata(0, 1600), 2))
        fleet.query(queries, K, routing="exhaustive")       # warm the jits
        TRACER.clear()
        stop = threading.Event()
        errors = []

        def hammer():
            try:
                while not stop.is_set():
                    fleet.query(queries, K, routing="exhaustive")
            except Exception as e:                # pragma: no cover
                errors.append(e)

        t = threading.Thread(target=hammer, name="query-hammer")
        t.start()
        try:
            ticket = fleet.compact_async()
            assert ticket.wait(timeout=300)
        finally:
            stop.set()
            t.join()
        assert not errors
        # the compactor's tree: seal → build → swap, on its own thread
        seal = TRACER.last_trace("compact.seal")
        assert seal is not None
        assert {"compact.build", "compact.swap"} <= span_names(seal)
        assert fleet.compaction_hist.count == 1
        # every query tree recorded during the hammer is complete
        spans = TRACER.spans()
        trees = [TRACER.tree(s.trace_id) for s in spans
                 if s.parent_id is None and s.name == "fleet.query"]
        assert trees, "hammer produced no fleet.query roots"
        for tree in trees:
            assert {"fleet.plan", "fleet.refine", "fleet.merge"} <= \
                span_names(tree)
        # no span ever claims a parent on a different thread
        by_id = {s.span_id: s for s in spans}
        for s in spans:
            if s.parent_id is not None and s.parent_id in by_id:
                assert by_id[s.parent_id].thread == s.thread

    def test_prometheus_page_has_fleet_series(self):
        fleet = mkfleet()
        queries = np.asarray(make_queries(jax.random.PRNGKey(8),
                                          mkdata(0, 1600), 2))
        fleet.query(queries, K, routing="exhaustive")
        page = to_prometheus(REGISTRY)
        assert "repro_fleet_query_latency_ms" in page
        assert "repro_span_fleet_query" in page
        assert f'fleet="{fleet.obs_label}"' in page
