"""Network serving plane acceptance tests.

The contracts from the issue:
  * codec round-trips every message type; truncated/corrupt/misversioned
    bytes raise typed errors (and the server answers them typed);
  * localhost client → server → fleet answers are bit-identical (dist +
    gid) to direct ``IndexFleet.query`` on routed AND exhaustive modes;
  * double-buffered admission demonstrably overlaps — batch N+1 is
    admitted while tick N executes;
  * backpressure (``RETRY_LATER``) and per-tenant quotas
    (``QUOTA_EXCEEDED``) refuse typed instead of queueing unboundedly;
  * graceful shutdown answers every admitted request before closing;
  * the legacy mutable-QueryRequest path still works, deprecated once.
"""
import socket
import threading
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                # container fallback
    from tests._hypothesis_fallback import given, settings, st

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.obs import REGISTRY, to_prometheus
from repro.serve import ClimberEngine, api
from repro.serve import knn_engine as knn_engine_mod
from repro.serve.net import (ClimberClient, FrameError, RetryLater,
                             ServerError, codec, schema, serve_in_thread)
from repro.serve.net.server import ClimberServer
from repro.utils.config import ClimberConfig

K = 10


def small_cfg() -> ClimberConfig:
    return ClimberConfig(series_len=64, paa_segments=8, num_pivots=32,
                         prefix_len=5, capacity=128, sample_frac=0.3,
                         max_centroids=12, k=K, candidate_groups=4,
                         adaptive_factor=4)


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = small_cfg()
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   1200, 64))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2),
                                      jnp.asarray(data), 6))
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   delta_capacity=4096, auto_compact=False))
    for i in range(2):
        fleet.add_shard(f"tenant{i}", data[i * 600:(i + 1) * 600])
    return fleet, data, queries


def roundtrip(mtype, msg):
    frame = schema.encode_message(mtype, msg)
    got_type, length, _ = codec.decode_header(frame)
    assert length == len(frame) - codec.HEADER_LEN
    return schema.decode_message(got_type, frame[codec.HEADER_LEN:])


# -- codec / schema ---------------------------------------------------------

class TestCodec:
    @settings(max_examples=25)
    @given(st.integers(min_value=1, max_value=256),
           st.integers(min_value=0, max_value=64),
           st.integers(min_value=0, max_value=2**31),
           st.sampled_from(["", "tenant0", "αβγ-tenant"]))
    def test_query_roundtrip(self, series_len, k, rid, tenant):
        rng = np.random.default_rng(series_len * 31 + k)
        req = api.QueryRequest(
            series=rng.standard_normal(series_len).astype(np.float32),
            k=k, tenant=tenant, request_id=rid)
        mtype, got = roundtrip(schema.MsgType.QUERY, req)
        assert mtype == schema.MsgType.QUERY
        assert (got.k, got.tenant, got.request_id) == (k, tenant, rid)
        np.testing.assert_array_equal(got.series, req.series)
        assert got.series.dtype == np.float32

    @settings(max_examples=25)
    @given(st.integers(min_value=1, max_value=64),
           st.floats(min_value=0.0, max_value=1e6))
    def test_result_roundtrip(self, k, latency_ms):
        rng = np.random.default_rng(k)
        res = api.QueryResult(
            request_id=7, dist=rng.random(k).astype(np.float32),
            gid=rng.integers(0, 1000, k).astype(np.int32),
            partitions_touched=3, candidates_scanned=128,
            latency_ms=latency_ms, batch_fill=0.5)
        mtype, got = roundtrip(schema.MsgType.RESULT, res)
        assert mtype == schema.MsgType.RESULT
        np.testing.assert_array_equal(got.dist, res.dist)
        np.testing.assert_array_equal(got.gid, res.gid)
        assert got.candidates_scanned == 128
        assert got.latency_ms == pytest.approx(latency_ms)

    @settings(max_examples=10)
    @given(st.sampled_from(api.ERROR_CODES))
    def test_error_roundtrip(self, code):
        err = api.ErrorReply(request_id=3, code=code, message="m",
                             retry_after_ms=2.5)
        mtype, got = roundtrip(schema.MsgType.ERROR, err)
        assert mtype == schema.MsgType.ERROR
        assert (got.code, got.message, got.retry_after_ms) == (code, "m", 2.5)

    def test_info_and_handshake_roundtrip(self):
        info = api.ServerInfo(series_len=64, k_max=10, batch_size=8,
                              engine="fleet", variant="adaptive",
                              routing="signature", shards=3,
                              max_pending=64, tenant_quota=4)
        _, got = roundtrip(schema.MsgType.SERVER_INFO, info)
        assert got == info
        _, hello = roundtrip(schema.MsgType.HELLO, {"client": "t"})
        assert hello == {"wire_version": api.WIRE_VERSION, "client": "t"}
        mtype, _ = roundtrip(schema.MsgType.BYE, {})
        assert mtype == schema.MsgType.BYE

    def test_truncated_header(self):
        with pytest.raises(FrameError) as ei:
            codec.decode_header(b"\x00" * 4)
        assert ei.value.code == "TRUNCATED"

    def test_bad_magic(self):
        frame = bytearray(schema.encode_message(schema.MsgType.BYE, {}))
        frame[0] ^= 0xFF
        with pytest.raises(FrameError) as ei:
            codec.decode_header(bytes(frame))
        assert ei.value.code == "BAD_MAGIC"

    def test_version_mismatch_rejected(self):
        frame = codec.encode_frame(int(schema.MsgType.BYE), b"",
                                   version=api.WIRE_VERSION + 1)
        with pytest.raises(FrameError) as ei:
            codec.decode_header(frame)
        assert ei.value.code == "VERSION_MISMATCH"
        assert ei.value.peer_version == api.WIRE_VERSION + 1

    @settings(max_examples=25)
    @given(st.integers(min_value=0, max_value=200))
    def test_corrupt_payload_byte_fails_crc(self, offset):
        """Any flipped payload bit is caught by the crc before np.load."""
        req = api.QueryRequest(series=np.zeros(32, np.float32))
        frame = bytearray(schema.encode_message(schema.MsgType.QUERY, req))
        offset = codec.HEADER_LEN + offset % (len(frame) - codec.HEADER_LEN)
        frame[offset] ^= 0x01
        a, b = socket.socketpair()
        try:
            a.sendall(bytes(frame))
            with pytest.raises(FrameError) as ei:
                codec.read_frame_sync(b)
            assert ei.value.code == "BAD_CRC"
        finally:
            a.close(); b.close()

    def test_valid_crc_garbage_payload(self):
        frame = codec.encode_frame(int(schema.MsgType.QUERY),
                                   b"not an npz archive")
        msg_type, _, _ = codec.decode_header(frame)
        with pytest.raises(FrameError) as ei:
            schema.decode_message(msg_type, frame[codec.HEADER_LEN:])
        assert ei.value.code == "BAD_PAYLOAD"

    def test_missing_field_is_typed(self):
        payload = codec.encode_payload({"k": np.asarray(3)})
        with pytest.raises(FrameError) as ei:
            schema.decode_message(int(schema.MsgType.QUERY), payload)
        assert ei.value.code == "BAD_PAYLOAD"

    def test_no_pickle_either_way(self):
        with pytest.raises(TypeError):
            codec.encode_payload({"evil": object()})

    def test_oversized_length_prefix_refused(self):
        header = codec.HEADER.pack(codec.MAGIC, api.WIRE_VERSION, 1, 0,
                                   codec.MAX_PAYLOAD + 1, 0)
        with pytest.raises(FrameError) as ei:
            codec.decode_header(header)
        assert ei.value.code == "TOO_LARGE"


# -- api dataclasses / ServingConfig ---------------------------------------

class TestApi:
    def test_error_reply_validates_code(self):
        with pytest.raises(ValueError):
            api.ErrorReply(request_id=0, code="NOT_A_CODE")

    def test_config_and_kwargs_exclusive(self, fleet_setup):
        fleet, _, _ = fleet_setup
        with pytest.raises(TypeError):
            FleetEngine(fleet, config=api.ServingConfig(), batch_size=4)

    def test_unknown_kwarg_rejected(self, fleet_setup):
        fleet, _, _ = fleet_setup
        with pytest.raises(TypeError):
            FleetEngine(fleet, not_a_knob=1)

    def test_engines_share_one_config(self, fleet_setup):
        fleet, data, _ = fleet_setup
        cfg = api.ServingConfig(batch_size=4, k=K, variant="adaptive",
                                routing="signature")
        fe = FleetEngine(fleet, config=cfg)
        assert fe.config is cfg and fe.batch_size == 4
        assert fe.routing == "signature"

    def test_kwargs_fold_into_config(self, fleet_setup):
        fleet, _, _ = fleet_setup
        fe = FleetEngine(fleet, batch_size=2, maintenance_every=3)
        assert isinstance(fe.config, api.ServingConfig)
        assert fe.config.batch_size == 2
        assert fe.config.maintenance_every == 3

    def test_tenant_load(self, fleet_setup):
        fleet, _, queries = fleet_setup
        engine = FleetEngine(fleet, batch_size=4, k=K)
        fleet.reset_metrics()
        assert engine.tenant_load("tenant0") == 0.0     # unqueried
        fleet.query(queries, K, routing="exhaustive")
        load = engine.tenant_load("tenant0")
        assert 0.0 < load <= 1.0
        assert engine.tenant_load("no-such-tenant") == 0.0

    def test_legacy_submit_warns_once(self, fleet_setup, monkeypatch):
        from repro.serve import QueryRequest as LegacyRequest
        fleet, _, queries = fleet_setup
        engine = FleetEngine(fleet, batch_size=2, k=K)
        monkeypatch.setattr(knn_engine_mod, "_LEGACY_SUBMIT_WARNED", False)
        with pytest.warns(DeprecationWarning):
            engine.submit(LegacyRequest(rid=0, series=queries[0], k=K))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            engine.submit(LegacyRequest(rid=1, series=queries[1], k=K))
        assert not [w for w in caught
                    if issubclass(w.category, DeprecationWarning)]
        engine.step()
        assert not engine.queue


# -- live server ------------------------------------------------------------

@pytest.fixture(scope="module")
def net_setup(fleet_setup):
    fleet, data, queries = fleet_setup
    engine = FleetEngine(fleet, config=api.ServingConfig(
        batch_size=4, k=K, variant="adaptive", routing="signature"))
    server, stop = serve_in_thread(engine)
    yield fleet, engine, server, queries
    stop()


class TestServer:
    def test_handshake_card(self, net_setup):
        fleet, engine, server, queries = net_setup
        with ClimberClient("127.0.0.1", server.port) as c:
            assert c.info.series_len == 64
            assert c.info.k_max == K
            assert c.info.engine == "fleet"
            assert c.info.shards == len(fleet.shards)
            assert c.info.wire_version == api.WIRE_VERSION

    def test_bit_identity_routed(self, net_setup):
        """Acceptance: the socket adds zero numeric difference."""
        fleet, engine, server, queries = net_setup
        with ClimberClient("127.0.0.1", server.port) as c:
            got = c.query_batch(list(queries), k=K)
        dist, gid, _ = fleet.query(queries, K, routing="signature",
                                   variant="adaptive")
        np.testing.assert_array_equal(
            np.stack([r.gid for r in got]), gid)
        np.testing.assert_array_equal(
            np.stack([r.dist for r in got]), dist.astype(np.float32))

    def test_bit_identity_exhaustive(self, fleet_setup):
        fleet, data, queries = fleet_setup
        engine = FleetEngine(fleet, config=api.ServingConfig(
            batch_size=4, k=K, variant="exhaustive", routing="exhaustive"))
        server, stop = serve_in_thread(engine)
        try:
            with ClimberClient("127.0.0.1", server.port) as c:
                got = c.query_batch(list(queries), k=K)
        finally:
            stop()
        dist, gid, _ = fleet.query(queries, K, routing="exhaustive",
                                   variant="exhaustive")
        np.testing.assert_array_equal(np.stack([r.gid for r in got]), gid)
        np.testing.assert_array_equal(np.stack([r.dist for r in got]),
                                      dist.astype(np.float32))

    def test_result_metrics_ride_along(self, net_setup):
        _, _, server, queries = net_setup
        with ClimberClient("127.0.0.1", server.port) as c:
            res = c.query(queries[0], k=K)
        assert res.latency_ms > 0.0
        assert res.candidates_scanned > 0
        assert 0.0 < res.batch_fill <= 1.0

    def test_bad_request_is_typed(self, net_setup):
        _, _, server, queries = net_setup
        with ClimberClient("127.0.0.1", server.port) as c:
            with pytest.raises(ServerError) as ei:
                c.query(np.zeros(13, np.float32))       # wrong series_len
            assert ei.value.code == "BAD_REQUEST"
            with pytest.raises(ServerError) as ei:
                c.query(queries[0], k=K + 1)            # k > k_max
            assert ei.value.code == "BAD_REQUEST"
            # the connection survives typed rejections
            res = c.query(queries[0], k=K)
            assert res.gid.shape == (K,)

    def test_wire_version_mismatch_over_socket(self, net_setup):
        _, _, server, _ = net_setup
        sock = socket.create_connection(("127.0.0.1", server.port),
                                        timeout=10)
        try:
            hello = codec.encode_frame(
                int(schema.MsgType.HELLO),
                codec.encode_payload({"wire_version": np.asarray(99)}),
                version=api.WIRE_VERSION + 1)
            sock.sendall(hello)
            msg_type, payload = codec.read_frame_sync(sock)
            mtype, reply = schema.decode_message(msg_type, payload)
            assert mtype == schema.MsgType.ERROR
            assert reply.code == "VERSION_MISMATCH"
        finally:
            sock.close()

    def test_corrupt_frame_gets_typed_reply(self, net_setup):
        _, _, server, queries = net_setup
        sock = socket.create_connection(("127.0.0.1", server.port),
                                        timeout=10)
        try:
            sock.sendall(schema.encode_message(schema.MsgType.HELLO,
                                               {"client": "t"}))
            codec.read_frame_sync(sock)                  # SERVER_INFO
            frame = bytearray(schema.encode_message(
                schema.MsgType.QUERY,
                api.QueryRequest(series=queries[0])))
            frame[-1] ^= 0x01                            # flip payload bit
            sock.sendall(bytes(frame))
            msg_type, payload = codec.read_frame_sync(sock)
            mtype, reply = schema.decode_message(msg_type, payload)
            assert mtype == schema.MsgType.ERROR
            assert reply.code == "BAD_FRAME"
        finally:
            sock.close()

    def test_net_metrics_exported(self, net_setup):
        _, _, server, queries = net_setup
        with ClimberClient("127.0.0.1", server.port) as c:
            c.query(queries[0], k=K)
        page = to_prometheus(REGISTRY)
        assert "repro_net_rtt_ms" in page
        assert "repro_net_connections" in page
        assert "repro_net_frames_in" in page
        assert "repro_net_queries" in page


def _slowed(engine, seconds):
    """Wrap engine._execute so every tick holds the device plane."""
    orig = engine._execute

    def slow(qbatch, nlive):
        time.sleep(seconds)
        return orig(qbatch, nlive)

    engine._execute = slow
    return engine


class TestAdmission:
    def test_double_buffer_overlap(self, fleet_setup):
        """Acceptance: batch N+1 is admitted while tick N executes.

        Three concurrent clients each stream 4 queries (retrying typed
        backpressure), so sends keep landing while 50ms ticks run — the
        admissions the double buffer takes during a tick are counted in
        ``server.overlap_admissions``.  Load on the host only makes
        ticks longer and overlap likelier, so the assert is stable under
        a full parallel test run.
        """
        fleet, _, queries = fleet_setup
        engine = _slowed(FleetEngine(fleet, config=api.ServingConfig(
            batch_size=2, k=K, admission_depth=2)), 0.05)
        server, stop = serve_in_thread(engine)
        results = []

        def worker(widx):
            with ClimberClient("127.0.0.1", server.port) as c:
                for i in range(4):
                    while True:
                        try:
                            results.append(
                                c.query(queries[(widx + i) % len(queries)],
                                        k=K))
                            break
                        except RetryLater as exc:
                            time.sleep(max(exc.retry_after_ms, 1.0) / 1e3)

        try:
            threads = [threading.Thread(target=worker, args=(w,))
                       for w in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=60)
            assert len(results) == 12
            assert all(isinstance(r, api.QueryResult) for r in results)
            assert server.overlap_admissions > 0
        finally:
            stop()

    def test_backpressure_retry_later(self, fleet_setup):
        fleet, _, queries = fleet_setup
        engine = _slowed(FleetEngine(fleet, config=api.ServingConfig(
            batch_size=2, k=K, admission_depth=1, max_pending=2)), 0.25)
        server, stop = serve_in_thread(engine)
        try:
            series = [queries[i % len(queries)] for i in range(6)]
            with ClimberClient("127.0.0.1", server.port) as c:
                with pytest.raises(RetryLater) as ei:
                    c.query_batch(series, k=K)
            assert ei.value.code == "RETRY_LATER"
            assert ei.value.retry_after_ms >= 1.0
        finally:
            stop()

    def test_tenant_quota(self, fleet_setup):
        fleet, _, queries = fleet_setup
        engine = _slowed(FleetEngine(fleet, config=api.ServingConfig(
            batch_size=4, k=K, tenant_quota=1)), 0.25)
        server, stop = serve_in_thread(engine)
        try:
            with ClimberClient("127.0.0.1", server.port,
                               tenant="hog") as c:
                with pytest.raises(RetryLater) as ei:
                    c.query_batch([queries[0], queries[1], queries[2]], k=K)
            assert ei.value.code == "QUOTA_EXCEEDED"
        finally:
            stop()
        assert engine.tenant_inflight("hog") == 0        # quota released

    def test_hot_tenant_share_halves_quota(self, fleet_setup):
        fleet, _, _ = fleet_setup
        engine = FleetEngine(fleet, config=api.ServingConfig(
            batch_size=2, k=K, tenant_quota=4, hot_tenant_share=0.5))
        server = ClimberServer(engine)
        engine.tenant_load = lambda tenant: 0.9          # hog the fleet
        assert server._effective_quota("hog") == 2
        engine.tenant_load = lambda tenant: 0.1
        assert server._effective_quota("cold") == 4

    def test_graceful_shutdown_drains_in_flight(self, fleet_setup):
        """stop() answers every admitted request before closing."""
        fleet, _, queries = fleet_setup
        engine = _slowed(FleetEngine(fleet, config=api.ServingConfig(
            batch_size=2, k=K, admission_depth=2)), 0.05)
        server, stop = serve_in_thread(engine)
        series = [queries[i % len(queries)] for i in range(6)]
        box = {}

        def client_run():
            with ClimberClient("127.0.0.1", server.port) as c:
                box["results"] = c.query_batch(series, k=K)

        t = threading.Thread(target=client_run)
        t.start()
        time.sleep(0.08)           # let requests admit; ticks in flight
        stop()                     # drain while executing
        t.join(timeout=30)
        assert not t.is_alive()
        assert len(box["results"]) == 6
        assert all(isinstance(r, api.QueryResult) for r in box["results"])

    def test_rejects_after_shutdown(self, fleet_setup):
        fleet, _, queries = fleet_setup
        engine = FleetEngine(fleet, config=api.ServingConfig(
            batch_size=2, k=K))
        server = ClimberServer(engine)
        server._draining = True

        class FakeConn:
            posted = []
            alive = True

            def post(self, mtype, msg):
                FakeConn.posted.append((mtype, msg))

        server._admit(api.QueryRequest(series=queries[0], k=K), FakeConn())
        (mtype, reply), = FakeConn.posted
        assert mtype == schema.MsgType.ERROR
        assert reply.code == "SHUTTING_DOWN"


class TestClimberEngineConfig:
    def test_single_index_engine_takes_config(self):
        from repro.core import build_index
        cfg = small_cfg()
        data = make_dataset("randomwalk", jax.random.PRNGKey(5), 400, 64)
        index = build_index(jax.random.PRNGKey(6), jnp.asarray(data), cfg)
        engine = ClimberEngine(index, config=api.ServingConfig(
            batch_size=2, k=K, variant="adaptive"))
        assert engine.batch_size == 2
        with pytest.raises(TypeError):
            ClimberEngine(index, config=api.ServingConfig(), batch_size=2)
