"""§Roofline table generator — reads the dry-run artifacts and prints the
three-term analysis per (arch × shape) on the single-pod mesh."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run() -> None:
    if not ART.exists():
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    rows = []
    for f in sorted(ART.glob("*_16x16.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "skipped":
            emit(f"roofline/{d['arch']}/{d['shape']}", 0.0,
                 "status=skipped(long-context-rule)")
            continue
        if d.get("status") != "ok":
            emit(f"roofline/{d['arch']}/{d['shape']}", 0.0, "status=error")
            continue
        emit(f"roofline/{d['arch']}/{d['shape']}",
             d["bound_s"] * 1e6 if "bound_s" in d else
             max(d["compute_s"], d["memory_s"], d["collective_s"]) * 1e6,
             f"compute_s={d['compute_s']:.4f};memory_s={d['memory_s']:.4f};"
             f"collective_s={d['collective_s']:.4f};"
             f"bottleneck={d['bottleneck']};"
             f"useful={d['useful_flops_ratio']:.2f};"
             f"roofline_frac={d['roofline_fraction']:.3f}")
