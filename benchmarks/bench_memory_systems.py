"""Table I analogue — CLIMBER vs in-memory exact search across sizes.

Odyssey / ParlayANN themselves are not reproducible here (different
codebases); the exact-scan jitted path plays the "in-memory exact" role the
table uses them for: I.C.T (index construction), Q.R.T (query response),
R.R (recall).  The qualitative claim under test is the paper's: CLIMBER
trades a bounded recall loss for index-backed queries that touch a tiny
fraction of the data, while exact in-memory search pays full scans.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import default_cfg, emit, timed
from repro.baselines import exact_knn, recall
from repro.core import build_index, knn_query
from repro.data import make_dataset, make_queries

K = 50


def run() -> None:
    for n in (8_000, 16_000, 32_000, 64_000):
        data = make_dataset("randomwalk", jax.random.PRNGKey(0), n, 128)
        queries = make_queries(jax.random.PRNGKey(1), data, 20)
        _, exact_ids = exact_knn(queries, data, K)

        # exact in-memory scan ("Odyssey role"): no index, full scan
        (_, _), t_scan = timed(lambda: exact_knn(queries, data, K))
        emit(f"table1/n{n}/exact-inmem", t_scan * 1e6, "recall=1.000;ict_us=0")

        cfg = default_cfg(k=K)
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(2), data, cfg)
        ict = time.perf_counter() - t0
        (_, gid, plan), t_q = timed(
            lambda: knn_query(index, queries, K, variant="adaptive"))
        r = recall(np.asarray(gid), np.asarray(exact_ids))
        frac = (float(np.asarray(plan.partitions_touched()).mean())
                * index.store.capacity / n)
        emit(f"table1/n{n}/climber", t_q * 1e6,
             f"recall={r:.3f};ict_us={ict*1e6:.0f};data_frac={frac:.3f}")
