"""Recall-frontier sweep — the Hydra-style accuracy measurement plane.

Drives :func:`repro.eval.frontier.run_frontier` over tenant-sharded
corpora (≥2 datasets × hard/easy query splits) and writes
``artifacts/BENCH_recall_frontier.json``: per-cell recall@k / MAP /
data-touched metrics across (shards × routing mode/fanout/threshold ×
planner variant/spend × slot budget), the fixed-vs-adaptive frontier
curves with AUC, and the ``routed_gap`` section — adaptive routing's
recall against the fixed-fanout baseline *at matched candidates-scanned
cost* (the apples-to-apples number the ROADMAP's recall program is judged
on).

Exact ground truth is cached under ``artifacts/gt_cache/`` keyed by the
generating parameters, so repeat runs skip the brute-force scans.

``--smoke`` (or ``RECALL_FRONTIER_SMOKE=1``, for the CI ``recall`` job)
shrinks the sweep to one dataset, 2 shards, and 2 fanout points — a
structural check, not a measurement — and skips the artifact write so a
smoke run can never clobber the committed frontier.
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

from benchmarks.common import emit
from repro.eval import FrontierSpec, run_frontier

ART = Path(__file__).resolve().parents[1] / "artifacts"

FULL_SPEC = FrontierSpec()
SMOKE_SPEC = FrontierSpec(
    datasets=("randomwalk",), shard_counts=(2,), shard_size=300,
    series_len=64, num_queries=12, num_calibration=8, k=5,
    fanouts=(1, 2), thresholds=(0.5, 0.95), spend_factors=(1.0, 2.0),
    slot_budgets=(4,))


def run(smoke: bool = False) -> dict:
    smoke = smoke or bool(os.environ.get("RECALL_FRONTIER_SMOKE"))
    spec = SMOKE_SPEC if smoke else FULL_SPEC
    doc = run_frontier(spec, cache_dir=None if smoke else ART / "gt_cache",
                       progress=lambda msg: print(f"# {msg}"))
    for c in doc["cells"]:
        if "recall" not in c or c["split"] != "all":
            continue
        tag = (f"recall_frontier/{c['dataset']}/s{c['shards']}"
               f"/{c['routing']}/{c['param']}/{c['variant']}")
        emit(tag, 0.0,
             f"recall={c['recall']:.3f};map={c['map']:.3f};"
             f"scanned={c['mean_candidates_scanned']:.0f}")
    for g in doc["routed_gap"]:
        if g["split"] == "all":
            emit(f"recall_frontier/gap/{g['dataset']}/s{g['shards']}"
                 f"/{g['param']}", 0.0,
                 f"adaptive={g['adaptive_recall']:.3f};"
                 f"fixed_at_cost={g['fixed_recall_at_cost']:.3f};"
                 f"improvement={g['improvement']:+.3f}")
    if not smoke:
        ART.mkdir(exist_ok=True)
        out = ART / "BENCH_recall_frontier.json"
        out.write_text(json.dumps(dict(doc, bench="recall_frontier"),
                                  indent=2))
        print(f"# wrote {out}")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny structural sweep (no artifact write)")
    run(smoke=ap.parse_args().smoke)
