"""Fig. 11 — CLIMBER variations: adaptive gain when K exceeds node capacity
(11a) and the OD-Smallest data-touched/recall trade-off (11b)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import default_cfg, emit, standard_setup, timed
from repro.baselines import exact_knn, recall
from repro.core import build_index, knn_query


def run() -> None:
    data, queries, _ = standard_setup("randomwalk", 16_000, k=50)

    # 11a: stress K beyond the landing node's capacity
    for k in (50, 200, 400):
        _, exact_ids = exact_knn(queries, data, k)
        base_cfg = default_cfg(k=k, adaptive_factor=1)
        index = build_index(jax.random.PRNGKey(11), data, base_cfg)
        (_, gid_b, plan_b), t_b = timed(
            lambda: knn_query(index, queries, k, variant="knn"))
        r_base = recall(np.asarray(gid_b), np.asarray(exact_ids))
        for factor in (2, 4):
            cfg = default_cfg(k=k, adaptive_factor=factor)
            idx2 = build_index(jax.random.PRNGKey(11), data, cfg)
            (_, gid_a, plan_a), t_a = timed(
                lambda: knn_query(idx2, queries, k, variant="adaptive"))
            r_a = recall(np.asarray(gid_a), np.asarray(exact_ids))
            gain = (r_a - r_base) / max(r_base, 1e-9) * 100
            emit(f"fig11a/k{k}/adaptive{factor}x", t_a * 1e6,
                 f"recall={r_a:.3f};base={r_base:.3f};gain_pct={gain:.1f}")

    # 11b: OD-Smallest vs the three variants — relative data accessed
    k = 100
    _, exact_ids = exact_knn(queries, data, k)
    results = {}
    for variant, factor in (("knn", 1), ("adaptive", 2), ("adaptive", 4),
                            ("od_smallest", 4)):
        cfg = default_cfg(k=k, adaptive_factor=factor)
        index = build_index(jax.random.PRNGKey(12), data, cfg)
        tag = variant if variant != "adaptive" else f"adaptive{factor}x"
        (_, gid, plan), secs = timed(
            lambda: knn_query(index, queries, k, variant=variant))
        r = recall(np.asarray(gid), np.asarray(exact_ids))
        touched = float(np.asarray(plan.partitions_touched()).mean())
        results[tag] = (r, touched)
        emit(f"fig11b/{tag}", secs * 1e6,
             f"recall={r:.3f};parts={touched:.2f}")
    od_r, od_t = results["od_smallest"]
    for tag in ("knn", "adaptive2x", "adaptive4x"):
        r, t = results[tag]
        emit(f"fig11b/ratio/{tag}", 0.0,
             f"od_recall_ratio={od_r/max(r,1e-9):.2f};"
             f"od_data_ratio={od_t/max(t,1e-9):.2f}")
