"""Fig. 10 — impact of the number of pivots: build phases + accuracy."""
from __future__ import annotations

import time

import jax
import numpy as np

import repro.core.assignment as assignment
import repro.core.centroids as centroids_mod
import repro.core.pivots as pivots_mod
import repro.core.signatures as sig_mod
from benchmarks.common import climber_recall, default_cfg, emit, standard_setup
from repro.core import build_index
from repro.core.paa import paa


def run() -> None:
    data, queries, exact_ids = standard_setup("randomwalk", 12_000, k=50)

    for r in (32, 64, 96, 160, 256):
        cfg = default_cfg(num_pivots=r, k=50)
        # phase timings (Fig 10a): skeleton vs conversion vs redistribution
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(5), data, cfg)
        t_total = time.perf_counter() - t0

        # conversion-only timing (signature generation over the full set)
        z = paa(data, cfg.paa_segments)
        t0 = time.perf_counter()
        p4 = sig_mod.rank_signature(z, index.pivots, cfg.prefix_len)
        p4.block_until_ready()
        t_convert = time.perf_counter() - t0

        rec, t_q, _ = climber_recall(index, queries, exact_ids, 50)
        emit(f"fig10/r{r}/build", t_total * 1e6,
             f"convert_us={t_convert*1e6:.0f};recall={rec:.3f};"
             f"groups={index.num_groups}")

    # accuracy per dataset at the default r (Fig 10b)
    for name in ("randomwalk", "sift", "dna", "eeg"):
        data, queries, exact_ids = standard_setup(name, 12_000, k=50)
        for r in (32, 96, 192):
            cfg = default_cfg(num_pivots=r, k=50)
            index = build_index(jax.random.PRNGKey(6), data, cfg)
            rec, t_q, _ = climber_recall(index, queries, exact_ids, 50)
            emit(f"fig10b/{name}/r{r}", t_q * 1e6, f"recall={rec:.3f}")
