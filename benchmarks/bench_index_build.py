"""Fig. 8 — index construction time + global-index (skeleton) size."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import default_cfg, emit
from repro.baselines import build_dpisax, build_tardis
from repro.core import build_index
from repro.data import make_dataset


def _skeleton_bytes(index) -> int:
    f = index.forest
    parts = [index.pivots, index.centroid_onehot]
    arrays = [np.asarray(p) for p in parts] + [
        f.child_start, f.edge_pivot, f.edge_child, f.edge_key, f.node_size,
        f.node_depth, f.dfs_in, f.dfs_out, f.part_start, f.part_ids,
        f.group_root, f.group_default_part]
    return int(sum(a.nbytes for a in arrays))


def run() -> None:
    cfg = default_cfg()
    for name in ("randomwalk", "sift", "dna", "eeg"):
        data = make_dataset(name, jax.random.PRNGKey(0), 12_000, 128)
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(1), data, cfg)
        t_climber = time.perf_counter() - t0
        emit(f"fig8/{name}/climber", t_climber * 1e6,
             f"skeleton_bytes={_skeleton_bytes(index)};"
             f"partitions={index.forest.num_partitions}")

        t0 = time.perf_counter()
        dp = build_dpisax(data, capacity=cfg.capacity)
        emit(f"fig8/{name}/dpisax", (time.perf_counter() - t0) * 1e6,
             f"partitions={dp.num_partitions}")

        t0 = time.perf_counter()
        td = build_tardis(jax.random.PRNGKey(2), data, capacity=cfg.capacity,
                          sample_frac=cfg.sample_frac)
        tb = sum(a.nbytes for a in (td.forest.child_start, td.forest.edge_pivot,
                                    td.forest.edge_child, td.forest.edge_key))
        emit(f"fig8/{name}/tardis", (time.perf_counter() - t0) * 1e6,
             f"skeleton_bytes={tb};partitions={td.forest.num_partitions}")

    # size sweep (Fig 8c/d)
    for n in (4_000, 8_000, 16_000, 32_000):
        data = make_dataset("randomwalk", jax.random.PRNGKey(3), n, 128)
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(4), data, cfg)
        emit(f"fig8/size{n}/climber", (time.perf_counter() - t0) * 1e6,
             f"skeleton_bytes={_skeleton_bytes(index)}")
