"""ClimberEngine throughput — batch size × planner variant × kernel on/off.

The first queries/sec number for the repo: drives the batched serving
engine over a synthetic RandomWalk index and sweeps the three levers the
engine exposes — admission batch size {1, 8, 64}, planner variant
(knn / adaptive), and the Pallas distance kernel.  Each cell reports
throughput, mean partitions touched and mean candidates scanned; recall is
reported once per variant (it is batch-invariant — the engine is
bit-identical to per-query ``knn_query``).

Besides the CSV rows, writes ``artifacts/BENCH_query_engine.json`` so the
perf trajectory across PRs starts here.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import default_cfg, emit, standard_setup
from repro.baselines import recall
from repro.core import build_index
from repro.serve import ClimberEngine, EngineStats

ART = Path(__file__).resolve().parents[1] / "artifacts"

K = 20
NUM_QUERIES = 64
BATCH_SIZES = (1, 8, 64)
VARIANTS = ("knn", "adaptive")
# kernel interpret mode on CPU is orders of magnitude slower than jnp; sweep
# it at a reduced query count so the suite stays minutes, not hours.
KERNEL_QUERIES = 8
KERNEL_BATCH_SIZES = (1, 8)


def _measure(engine: ClimberEngine, queries: np.ndarray):
    """(queries/sec, mean parts touched, mean candidates, gid) post-warmup."""
    engine.run(queries[: engine.batch_size])       # compile, excluded
    engine.stats = EngineStats()
    _, gid, _ = engine.run(queries)
    s = engine.stats
    return (s.queries_per_sec, s.mean_partitions_touched,
            s.mean_candidates_scanned, gid)


def run() -> None:
    data, queries, exact_ids = standard_setup(
        "randomwalk", n=8_000, num_queries=NUM_QUERIES, k=K)
    cfg = default_cfg(k=K)
    index = build_index(jax.random.PRNGKey(7), data, cfg)
    queries = np.asarray(queries)

    cells = []
    for variant in VARIANTS:
        for use_kernel in (False, True):
            q_sweep = queries if not use_kernel else queries[:KERNEL_QUERIES]
            batches = BATCH_SIZES if not use_kernel else KERNEL_BATCH_SIZES
            for bs in batches:
                engine = ClimberEngine(index, batch_size=bs, variant=variant,
                                       k=K, use_kernel=use_kernel)
                qps, parts, cands, gid = _measure(engine, q_sweep)
                r = recall(np.asarray(gid),
                           np.asarray(exact_ids)[: len(q_sweep)])
                tag = f"engine/{variant}/kernel{int(use_kernel)}/bs{bs}"
                emit(tag, 1e6 / qps if qps else 0.0,
                     f"qps={qps:.1f};parts={parts:.2f};recall={r:.3f}")
                cells.append({
                    "variant": variant, "use_kernel": use_kernel,
                    "batch_size": bs, "queries_per_sec": round(qps, 2),
                    "mean_partitions_touched": round(parts, 3),
                    "mean_candidates_scanned": round(cands, 1),
                    "recall": round(float(r), 4),
                    "num_queries": int(len(q_sweep)), "k": K,
                })

    ART.mkdir(exist_ok=True)
    out = ART / "BENCH_query_engine.json"
    out.write_text(json.dumps({
        "bench": "query_engine",
        "dataset": {"name": "randomwalk", "n": 8_000,
                    "series_len": cfg.series_len},
        "cells": cells,
    }, indent=2))
    print(f"# wrote {out}")


if __name__ == "__main__":
    run()
