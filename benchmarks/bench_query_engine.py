"""ClimberEngine throughput — batch size × planner variant × kernel on/off.

The first queries/sec number for the repo: drives the batched serving
engine over a synthetic RandomWalk index and sweeps the three levers the
engine exposes — admission batch size {1, 8, 64}, planner variant
(knn / adaptive), and the streaming fused refine kernel.  Each cell
reports throughput, mean partitions touched and mean candidates scanned;
recall is reported once per variant (it is batch-invariant — the engine is
bit-identical to per-query ``knn_query``).

The kernel-vs-dense column is backed by a **materialization audit**: the
jaxprs of both refine paths are scanned and the bench asserts the fused
kernel path materializes no intermediate of [Q, slots, cap] elements or
more (the dense path materializes both that distance tensor and the
[Q, slots, cap, n] gathered rows).  On CPU the kernel cells run in Pallas
interpret mode — the throughput number is meaningless there, but the audit
and the parity are exactly the TPU code path.

Besides the CSV rows, writes ``artifacts/BENCH_query_engine.json`` so the
perf trajectory across PRs accumulates (see benchmarks/compare.py).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import default_cfg, emit, standard_setup
from repro.baselines import recall
from repro.core import build_index
from repro.core.query import plan as plan_queries
from repro.core.refine import refine
from repro.serve import ClimberEngine

ART = Path(__file__).resolve().parents[1] / "artifacts"

K = 20
NUM_QUERIES = 64
BATCH_SIZES = (1, 8, 64)
VARIANTS = ("knn", "adaptive")
# kernel interpret mode on CPU is orders of magnitude slower than jnp; sweep
# it at a reduced query count so the suite stays minutes, not hours.
KERNEL_QUERIES = 8
KERNEL_BATCH_SIZES = (1, 8)


def _iter_subjaxprs(val):
    if hasattr(val, "jaxpr"):                       # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):                      # Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _iter_subjaxprs(v)


def _peak_intermediate_elems(fn, *args) -> int:
    """Largest XLA-materialized intermediate of ``fn``, in elements.

    Walks every equation output of the traced jaxpr (recursing into pjit
    and friends) but does **not** descend into pallas_call kernel bodies:
    their block-shaped values live in VMEM by construction, while this
    audit is about HBM tensors the compiler must materialize.
    """
    peak = 0

    def visit(jaxpr):
        nonlocal peak
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                shape = getattr(v.aval, "shape", ())
                peak = max(peak, int(np.prod(shape)) if len(shape) else 1)
            if eqn.primitive.name == "pallas_call":
                continue
            for val in eqn.params.values():
                for sub in _iter_subjaxprs(val):
                    visit(sub)

    visit(jax.make_jaxpr(fn)(*args).jaxpr)
    return peak


def materialization_audit(index, queries: np.ndarray, k: int) -> dict:
    """Prove the fused path never materializes the [Q, slots, cap] tensor.

    Traces both refine backends on a representative engine batch and
    compares their peak intermediate against the dense distance-tensor
    size.  Asserts (hard — this is the acceptance criterion, not a warn)
    that the dense path materializes ≥ Q·slots·cap elements and the fused
    kernel path stays strictly below it.
    """
    q = jnp.asarray(queries[:8])
    p4r, _ = index.featurize(q)
    qp = plan_queries(index, p4r)
    store = index.store
    qn, slots = int(q.shape[0]), int(qp.sel_part.shape[-1])
    cap = int(store.capacity)
    dense_tensor = qn * slots * cap

    peaks = {
        use_kernel: _peak_intermediate_elems(
            lambda qq, sp, lo, hi: refine(store, qq, sp, lo, hi, k,
                                          use_kernel=use_kernel),
            q, qp.sel_part, qp.sel_lo, qp.sel_hi)
        for use_kernel in (False, True)}
    assert peaks[False] >= dense_tensor, \
        f"dense path should materialize the distance tensor: " \
        f"{peaks[False]} < {dense_tensor}"
    assert peaks[True] < dense_tensor, \
        f"fused path materialized a [Q, slots, cap]-sized tensor: " \
        f"{peaks[True]} >= {dense_tensor}"
    emit("engine/refine_materialization", 0.0,
         f"q_slots_cap={dense_tensor};dense_peak={peaks[False]};"
         f"fused_peak={peaks[True]}")
    return {
        "q": qn, "slots": slots, "cap": cap,
        "q_slots_cap_elems": dense_tensor,
        "dense_peak_elems": peaks[False],
        "fused_peak_elems": peaks[True],
        "fused_materializes_q_slots_cap": bool(peaks[True] >= dense_tensor),
    }


def _measure(engine: ClimberEngine, queries: np.ndarray):
    """(queries/sec, mean parts, mean candidates, p50, p99, gid) after an
    untimed warmup (reset_metrics drops the compile tick from the stats
    AND the per-row latency histogram the quantiles read from)."""
    engine.run(queries[: engine.batch_size])       # compile, excluded
    engine.reset_metrics()
    _, gid, _ = engine.run(queries)
    s = engine.stats
    return (s.queries_per_sec, s.mean_partitions_touched,
            s.mean_candidates_scanned, engine.latency_hist.quantile(0.5),
            engine.latency_hist.quantile(0.99), gid)


def run() -> None:
    data, queries, exact_ids = standard_setup(
        "randomwalk", n=8_000, num_queries=NUM_QUERIES, k=K)
    cfg = default_cfg(k=K)
    index = build_index(jax.random.PRNGKey(7), data, cfg)
    queries = np.asarray(queries)

    cells = []
    for variant in VARIANTS:
        for use_kernel in (False, True):
            q_sweep = queries if not use_kernel else queries[:KERNEL_QUERIES]
            batches = BATCH_SIZES if not use_kernel else KERNEL_BATCH_SIZES
            for bs in batches:
                engine = ClimberEngine(index, batch_size=bs, variant=variant,
                                       k=K, use_kernel=use_kernel)
                qps, parts, cands, p50, p99, gid = _measure(engine, q_sweep)
                r = recall(np.asarray(gid),
                           np.asarray(exact_ids)[: len(q_sweep)])
                tag = f"engine/{variant}/kernel{int(use_kernel)}/bs{bs}"
                emit(tag, 1e6 / qps if qps else 0.0,
                     f"qps={qps:.1f};parts={parts:.2f};recall={r:.3f};"
                     f"p50={p50:.1f};p99={p99:.1f}")
                cells.append({
                    "variant": variant, "use_kernel": use_kernel,
                    "batch_size": bs, "queries_per_sec": round(qps, 2),
                    "latency_p50_ms": round(p50, 3),
                    "latency_p99_ms": round(p99, 3),
                    "mean_partitions_touched": round(parts, 3),
                    "mean_candidates_scanned": round(cands, 1),
                    "recall": round(float(r), 4),
                    "num_queries": int(len(q_sweep)), "k": K,
                })

    audit = materialization_audit(index, queries, K)

    ART.mkdir(exist_ok=True)
    out = ART / "BENCH_query_engine.json"
    out.write_text(json.dumps({
        "bench": "query_engine",
        "dataset": {"name": "randomwalk", "n": 8_000,
                    "series_len": cfg.series_len},
        "refine_materialization": audit,
        "cells": cells,
    }, indent=2))
    print(f"# wrote {out}")


if __name__ == "__main__":
    run()
