"""Fig. 9 — recall + time under varying K for the three CLIMBER variants
plus the iSAX baselines."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import default_cfg, emit, standard_setup, timed
from repro.baselines import (build_dpisax, build_tardis, dpisax_knn,
                             exact_knn, recall, tardis_knn)
from repro.core import build_index, knn_query


def run() -> None:
    data, queries, _ = standard_setup("randomwalk", 16_000, k=50)
    dp = build_dpisax(data, capacity=256)
    td = build_tardis(jax.random.PRNGKey(1), data, capacity=256,
                      sample_frac=0.15)

    for k in (10, 50, 100, 250, 500):
        _, exact_ids = exact_knn(queries, data, k)
        for factor, tag in ((1, "knn"), (2, "adaptive2x"), (4, "adaptive4x")):
            cfg = default_cfg(k=k, adaptive_factor=factor)
            index = build_index(jax.random.PRNGKey(2), data, cfg)
            variant = "knn" if factor == 1 else "adaptive"
            (_, gid, plan), secs = timed(
                lambda: knn_query(index, queries, k, variant=variant))
            r = recall(np.asarray(gid), np.asarray(exact_ids))
            emit(f"fig9/k{k}/climber-{tag}", secs * 1e6, f"recall={r:.3f}")

        (_, gid_d), t_d = timed(lambda: dpisax_knn(dp, queries, k))
        emit(f"fig9/k{k}/dpisax", t_d * 1e6,
             f"recall={recall(np.asarray(gid_d), np.asarray(exact_ids)):.3f}")
        (_, gid_t), t_t = timed(lambda: tardis_knn(td, queries, k))
        emit(f"fig9/k{k}/tardis", t_t * 1e6,
             f"recall={recall(np.asarray(gid_t), np.asarray(exact_ids)):.3f}")
