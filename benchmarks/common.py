"""Shared benchmark harness.

Every benchmark emits ``name,us_per_call,derived`` CSV rows (one per paper
table/figure cell).  Recall numbers are real measurements on synthetic
datasets matching the paper's generators; wall times are CPU times at
reduced N (the TB-scale wall-times are out of scope per DESIGN.md — the
dry-run/roofline pipeline covers scalability).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

from repro.baselines import exact_knn, recall
from repro.core import build_index, knn_query
from repro.data import make_dataset, make_queries
from repro.utils.config import ClimberConfig

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, seconds) with a warmup call (jit compilation excluded)."""
    result = fn(*args, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(result)[0]) \
        if jax.tree_util.tree_leaves(result) else None
    t0 = time.perf_counter()
    for _ in range(repeats):
        result = fn(*args, **kw)
        leaves = jax.tree_util.tree_leaves(result)
        if leaves:
            jax.block_until_ready(leaves[0])
    return result, (time.perf_counter() - t0) / repeats


def default_cfg(**kw) -> ClimberConfig:
    base = dict(series_len=128, paa_segments=16, num_pivots=96, prefix_len=10,
                capacity=256, sample_frac=0.15, max_centroids=48, k=50,
                candidate_groups=8, adaptive_factor=4)
    base.update(kw)
    return ClimberConfig(**base)


def standard_setup(dataset: str = "randomwalk", n: int = 12_000,
                   num_queries: int = 20, k: int = 50, seed: int = 0,
                   series_len: int = 128):
    data = make_dataset(dataset, jax.random.PRNGKey(seed), n, series_len)
    queries = make_queries(jax.random.PRNGKey(seed + 1), data, num_queries)
    _, exact_ids = exact_knn(queries, data, k)
    return data, queries, exact_ids


def climber_recall(index, queries, exact_ids, k: int, variant="adaptive"):
    (dist, gid, plan), secs = timed(
        lambda: knn_query(index, queries, k, variant=variant))
    r = recall(np.asarray(gid), np.asarray(exact_ids))
    touched = float(np.asarray(plan.partitions_touched()).mean())
    return r, secs, touched
