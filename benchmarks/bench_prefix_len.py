"""Fig. 12 — prefix-length sweep: accuracy / index size / build / query time
relative to the m=10 default."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import climber_recall, default_cfg, emit, standard_setup
from repro.core import build_index


def _skeleton_bytes(index) -> int:
    f = index.forest
    return int(sum(a.nbytes for a in (
        f.child_start, f.edge_pivot, f.edge_child, f.edge_key, f.node_size,
        f.dfs_in, f.dfs_out, f.part_start, f.part_ids))
        + np.asarray(index.pivots).nbytes
        + np.asarray(index.centroid_onehot).nbytes)


def run() -> None:
    data, queries, exact_ids = standard_setup("randomwalk", 16_000, k=50)
    baseline = {}
    for m in (10, 4, 6, 8, 12, 16):          # m=10 first: the reference
        cfg = default_cfg(prefix_len=m, k=50)
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(21), data, cfg)
        t_build = time.perf_counter() - t0
        rec, t_q, _ = climber_recall(index, queries, exact_ids, 50)
        size = _skeleton_bytes(index)
        if m == 10:
            baseline = {"build": t_build, "q": t_q, "rec": rec, "size": size}
        rel = (f"rel_build={t_build/baseline['build']:.2f};"
               f"rel_query={t_q/baseline['q']:.2f};"
               f"rel_size={size/baseline['size']:.2f};"
               f"recall={rec:.3f}")
        emit(f"fig12/m{m}", t_q * 1e6, rel)
