"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle parity + the
jnp-path throughput that the ED-refine/build hot loops actually achieve on
this host (TPU timings are out of scope; see EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ref
from repro.kernels.l2 import pairwise_l2
from repro.kernels.paa_kernel import paa as paa_k
from repro.kernels.pivot_rank import pivot_rank
from repro.kernels.refine_topk import refine_topk


def run() -> None:
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64, 256))
    x = jax.random.normal(jax.random.PRNGKey(1), (4096, 256))

    (_, t_ref) = timed(jax.jit(ref.pairwise_l2_ref), q, x)
    emit("kern/l2/ref_jnp", t_ref * 1e6,
         f"gflops={2*64*4096*256/t_ref/1e9:.1f}")
    out_k = pairwise_l2(q, x, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref.pairwise_l2_ref(q, x))))
    emit("kern/l2/pallas_interpret", 0.0, f"max_abs_err={err:.2e}")

    b = jax.random.normal(key, (8192, 256))
    (_, t_paa) = timed(jax.jit(lambda v: ref.paa_ref(v, 16)), b)
    emit("kern/paa/ref_jnp", t_paa * 1e6,
         f"gbps={b.size*4/t_paa/1e9:.1f}")
    err = float(jnp.max(jnp.abs(paa_k(b, 16, interpret=True)
                                - ref.paa_ref(b, 16))))
    emit("kern/paa/pallas_interpret", 0.0, f"max_abs_err={err:.2e}")

    z = jax.random.normal(key, (4096, 16))
    pv = jax.random.normal(jax.random.PRNGKey(2), (200, 16))
    (_, t_pr) = timed(jax.jit(lambda a, p: ref.pivot_rank_ref(a, p, 10)), z, pv)
    emit("kern/pivot_rank/ref_jnp", t_pr * 1e6,
         f"msigs_per_s={4096/t_pr/1e6:.2f}")
    same = bool(np.array_equal(
        np.asarray(pivot_rank(z, pv, 10, interpret=True)),
        np.asarray(ref.pivot_rank_ref(z, pv, 10))))
    emit("kern/pivot_rank/pallas_interpret", 0.0, f"exact_match={same}")

    # streaming fused refine: oracle throughput + kernel parity
    rng = np.random.default_rng(3)
    p, cap, n, qn, mp, k = 8, 64, 128, 8, 6, 20
    data = jnp.asarray(rng.normal(size=(p, cap, n)).astype(np.float32))
    norms = jnp.sum(data * data, axis=-1)
    dfs = jnp.asarray(rng.integers(0, 50, size=(p, cap)).astype(np.int32))
    gid = jnp.asarray(np.arange(p * cap, dtype=np.int32).reshape(p, cap))
    qs = jnp.asarray(rng.normal(size=(qn, n)).astype(np.float32))
    sp = jnp.sort(jnp.asarray(
        rng.integers(-1, p, size=(qn, mp)).astype(np.int32)), axis=-1)
    lo = jnp.zeros((qn, mp), jnp.int32)
    hi = jnp.full((qn, mp), 50, jnp.int32)
    (_, t_rt) = timed(
        jax.jit(lambda *a: ref.refine_topk_ref(*a, k)),
        data, norms, dfs, gid, qs, sp, lo, hi)
    emit("kern/refine_topk/ref_jnp", t_rt * 1e6,
         f"cand_per_s={qn*mp*cap/t_rt/1e6:.2f}M")
    d2k, gk = refine_topk(data, norms, dfs, gid, qs, sp, lo, hi, k,
                          interpret=True)
    d2r, gr = ref.refine_topk_ref(data, norms, dfs, gid, qs, sp, lo, hi, k)
    same = bool(np.array_equal(np.asarray(gk), np.asarray(gr)))
    err = float(jnp.max(jnp.abs(jnp.minimum(d2k, 1e9)
                                - jnp.minimum(d2r, 1e9))))
    emit("kern/refine_topk/pallas_interpret", 0.0,
         f"gid_exact={same};max_abs_err={err:.2e}")
