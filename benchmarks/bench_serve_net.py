"""Network serving plane — qps + latency tails per client concurrency.

Serves one small fleet through the asyncio :class:`ClimberServer` on a
loopback socket and drives it with 1 / 4 / 16 concurrent client threads
(each its own connection, pipelining its share of the query stream).  Per
concurrency level the cell reports:

  * ``queries_per_sec``  — completed round trips over wall time;
  * ``latency_p50_ms`` / ``latency_p99_ms`` — the *server-side*
    arrival-to-answer tails from the engine's ``serve.latency_ms``
    registry histogram (the PR 7 observability plane), reset per level so
    each cell sees only its own window;
  * ``rtt_p50_ms`` / ``rtt_p99_ms`` — the *client-perceived* round-trip
    tails from the ``net.rtt_ms`` histogram, same window;
  * ``overlap_admissions`` — how many admissions landed while a tick was
    executing: the double buffer visibly overlapping host assembly with
    device execution.

One warm-up batch per level excludes compilation from the window.  Writes
``artifacts/BENCH_serve_net.json``; the bench-trend CI step diffs every
column run over run.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import default_cfg, emit
from repro.data import make_dataset
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.obs import REGISTRY
from repro.serve import api
from repro.serve.net import ClimberClient, RetryLater, serve_in_thread

ART = Path(__file__).resolve().parents[1] / "artifacts"

K = 10
N = 4_000
SERIES_LEN = 128
SHARDS = 2
BATCH_SIZE = 8
NUM_QUERIES = 64                  # per concurrency level
CONCURRENCY = (1, 4, 16)


def _drive(port: int, series: np.ndarray, workers: int) -> int:
    """Fan NUM_QUERIES over `workers` client connections; returns the
    number of completed round trips (RetryLater rejections are retried —
    the bench measures served throughput, not refusal throughput)."""
    done = [0] * workers
    chunks = np.array_split(series, workers)

    def worker(widx: int) -> None:
        with ClimberClient("127.0.0.1", port,
                           client_name="bench") as client:
            for q in chunks[widx]:
                while True:
                    try:
                        client.query(q, k=K)
                        break
                    except RetryLater as exc:
                        time.sleep(max(exc.retry_after_ms, 1.0) / 1e3)
            done[widx] = len(chunks[widx])

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return sum(done)


def run() -> None:
    cfg = default_cfg(k=K)
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   N, SERIES_LEN))
    rng = np.random.default_rng(7)
    queries = data[rng.integers(0, N, NUM_QUERIES)] + \
        0.05 * rng.standard_normal((NUM_QUERIES, SERIES_LEN)).astype(
            np.float32)

    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=1,
                                   delta_capacity=1_024,
                                   auto_compact=False))
    per = N // SHARDS
    for s in range(SHARDS):
        fleet.add_shard(f"t{s}", data[s * per:(s + 1) * per])

    engine = FleetEngine(fleet, config=api.ServingConfig(
        batch_size=BATCH_SIZE, k=K, routing="signature",
        admission_depth=2, max_pending=4 * BATCH_SIZE))
    server, stop = serve_in_thread(engine)
    rtt_hist = REGISTRY.histogram("net.rtt_ms", client="bench")
    cells = []
    try:
        _drive(server.port, queries[:BATCH_SIZE], 1)      # compile warm-up
        for workers in CONCURRENCY:
            engine.latency_hist.reset()
            rtt_hist.reset()
            overlap0 = server.overlap_admissions
            t0 = time.perf_counter()
            served = _drive(server.port, queries, workers)
            secs = time.perf_counter() - t0
            qps = served / secs
            p50 = engine.latency_hist.quantile(0.5)
            p99 = engine.latency_hist.quantile(0.99)
            rtt50 = rtt_hist.quantile(0.5)
            rtt99 = rtt_hist.quantile(0.99)
            overlap = server.overlap_admissions - overlap0
            emit(f"serve_net/c{workers}", 1e6 / qps if qps else 0.0,
                 f"qps={qps:.1f};p50={p50:.1f};p99={p99:.1f};"
                 f"rtt_p50={rtt50:.1f};rtt_p99={rtt99:.1f};"
                 f"overlap={overlap}")
            cells.append({
                "concurrency": workers,
                "queries_per_sec": round(qps, 2),
                "latency_p50_ms": round(p50, 3),
                "latency_p99_ms": round(p99, 3),
                "rtt_p50_ms": round(rtt50, 3),
                "rtt_p99_ms": round(rtt99, 3),
                "overlap_admissions": overlap,
                "num_queries": NUM_QUERIES, "k": K,
                "batch_size": BATCH_SIZE, "shards": SHARDS,
            })
    finally:
        stop()

    ART.mkdir(exist_ok=True)
    out = ART / "BENCH_serve_net.json"
    out.write_text(json.dumps({
        "bench": "serve_net",
        "dataset": {"name": "randomwalk", "n": N, "series_len": SERIES_LEN},
        "batch_size": BATCH_SIZE,
        "cells": cells,
    }, indent=2))
    print(f"# wrote {out}")


if __name__ == "__main__":
    run()
