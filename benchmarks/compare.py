"""Bench-trend comparison: previous run's BENCH_*.json vs a fresh run.

CI's ``bench-smoke`` job downloads the prior ``bench-artifacts`` bundle,
re-runs the benchmarks, and calls this module to post a per-cell delta
table to the job summary, so the perf trajectory accumulates run over run.

Cells are keyed by their identity columns (everything that is not a
measured metric), so reordering or adding cells between runs compares only
what matches.  Throughput noise on shared CI runners is large; the output
is **warn-only** — deltas beyond ``--warn-pct`` are flagged with ⚠ but the
exit code is always 0.  Use it locally the same way:

    PYTHONPATH=src python -m benchmarks.compare artifacts/prev artifacts
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

# measured columns; everything else in a cell identifies it
METRICS = (
    "queries_per_sec", "recall", "mean_partitions_touched",
    "mean_candidates_scanned", "routing_precision", "mean_fanout",
)
# metrics where bigger is better (the rest are informational)
HIGHER_IS_BETTER = {"queries_per_sec", "recall", "routing_precision"}
DEFAULT_FILES = ("BENCH_query_engine.json", "BENCH_fleet.json")


def _cell_key(cell: dict) -> Tuple:
    return tuple(sorted((k, repr(v)) for k, v in cell.items()
                        if k not in METRICS))


def _fmt_key(cell: dict) -> str:
    return " ".join(f"{k}={cell[k]}" for k in sorted(cell)
                    if k not in METRICS and k not in ("num_queries", "k"))


def load_cells(path: Path) -> Dict[Tuple, dict]:
    doc = json.loads(path.read_text())
    return {_cell_key(c): c for c in doc.get("cells", [])}


def compare_file(old: Path, new: Path, warn_pct: float) -> List[str]:
    """Markdown lines for one benchmark file pair."""
    lines = [f"### {new.name}", ""]
    if not new.exists():
        return lines + [f"_fresh run produced no {new.name} — skipped_", ""]
    if not old.exists():
        return lines + ["_no previous artifact — baseline recorded, "
                        "deltas start next run_", ""]
    old_cells, new_cells = load_cells(old), load_cells(new)
    shared = [k for k in new_cells if k in old_cells]
    if not shared:
        return lines + ["_no overlapping cells with the previous run_", ""]
    lines += ["| cell | metric | prev | now | Δ% |",
              "|---|---|---:|---:|---:|"]
    for key in shared:
        oc, nc = old_cells[key], new_cells[key]
        for m in METRICS:
            if m not in nc or m not in oc:
                continue
            ov, nv = float(oc[m]), float(nc[m])
            if ov == 0.0:                # pct undefined; don't print +inf%
                delta = "n/a (prev 0)" if nv != ov else "+0.0%"
                lines.append(f"| {_fmt_key(nc)} | {m} | {ov:g} | {nv:g} | "
                             f"{delta} |")
                continue
            pct = (nv - ov) / abs(ov) * 100.0
            regressed = (pct < -warn_pct if m in HIGHER_IS_BETTER
                         else abs(pct) > warn_pct)
            flag = " ⚠" if regressed else ""
            lines.append(f"| {_fmt_key(nc)} | {m} | {ov:g} | {nv:g} | "
                         f"{pct:+.1f}%{flag} |")
    dropped = len(old_cells) - len(shared)
    added = len(new_cells) - len(shared)
    if dropped or added:
        lines.append(f"\n_{added} new cell(s), {dropped} no longer "
                     f"produced_")
    return lines + [""]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old_dir", help="directory with the previous run's "
                                    "BENCH_*.json (may be empty/missing)")
    ap.add_argument("new_dir", help="directory with the fresh BENCH_*.json")
    ap.add_argument("--files", nargs="+", default=list(DEFAULT_FILES))
    ap.add_argument("--warn-pct", type=float, default=15.0,
                    help="flag deltas beyond this magnitude (default 15)")
    args = ap.parse_args()

    out = ["## Bench trend (warn-only)", ""]
    for name in args.files:
        out += compare_file(Path(args.old_dir) / name,
                            Path(args.new_dir) / name, args.warn_pct)
    print("\n".join(out))
    sys.exit(0)          # warn-only by design: never fail the job


if __name__ == "__main__":
    main()
