"""Bench-trend comparison: previous run's BENCH_*.json vs a fresh run.

CI's ``bench-smoke`` job downloads the prior ``bench-artifacts`` bundle,
re-runs the benchmarks, and calls this module to post a per-cell delta
table to the job summary, so the perf trajectory accumulates run over run.

Cells are keyed by their identity columns (everything that is not a
measured metric), so reordering or adding cells between runs compares only
what matches.  Nothing is skipped silently: suites present in the fresh
run but absent from the previous artifact set get an explicit "new suite,
no baseline" row (and new cells inside a shared suite get "new cell, no
baseline" rows) instead of disappearing from the table.  Unless ``--files``
is given, the suite list is auto-discovered from the fresh run's
``BENCH_*.json`` files (union with the historical defaults), so a newly
registered benchmark shows up in the trend the run it first writes an
artifact.

Throughput noise on shared CI runners is large; the output is **warn-only**
by default — deltas beyond ``--warn-pct`` are flagged with ⚠ but the exit
code stays 0.  ``--fail-on-regression METRIC:PCT`` (repeatable) opts
specific metrics into a hard gate: the process exits 1 when such a metric
regresses beyond PCT percent in any shared cell — the first step toward
promoting the trend table from advisory to enforced.  Use it locally the
same way:

    PYTHONPATH=src python -m benchmarks.compare artifacts/prev artifacts
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

# measured columns; everything else in a cell identifies it
METRICS = (
    "queries_per_sec", "recall", "mean_partitions_touched",
    "mean_candidates_scanned", "routing_precision", "mean_fanout",
    "compaction_ms", "restart_replay_ms",       # fleet lifecycle columns
    "plan_ms", "refine_ms", "merge_ms",         # fleet per-stage breakdown
    "latency_p50_ms", "latency_p99_ms",         # obs histogram quantiles
    "rtt_p50_ms", "rtt_p99_ms",                 # net client round-trip tails
    "overlap_admissions",                       # double-buffer overlap count
    "map", "recall_frontier_auc",               # recall-frontier columns
)
# metrics where bigger is better (the rest are informational)
HIGHER_IS_BETTER = {"queries_per_sec", "recall", "routing_precision",
                    "map", "recall_frontier_auc"}
DEFAULT_FILES = ("BENCH_query_engine.json", "BENCH_fleet.json",
                 "BENCH_serve_net.json", "BENCH_recall_frontier.json")


def _cell_key(cell: dict) -> Tuple:
    return tuple(sorted((k, repr(v)) for k, v in cell.items()
                        if k not in METRICS))


def _fmt_key(cell: dict) -> str:
    return " ".join(f"{k}={cell[k]}" for k in sorted(cell)
                    if k not in METRICS and k not in ("num_queries", "k"))


def load_cells(path: Path) -> Dict[Tuple, dict]:
    doc = json.loads(path.read_text())
    return {_cell_key(c): c for c in doc.get("cells", [])}


def compare_file(old: Path, new: Path, warn_pct: float,
                 fail_on: Optional[Dict[str, float]] = None,
                 regressions: Optional[List[str]] = None) -> List[str]:
    """Markdown lines for one benchmark file pair.

    ``fail_on`` maps metric name → max tolerated regression percent (from
    ``--fail-on-regression``); matching cells whose delta exceeds it are
    appended to ``regressions`` (the caller turns those into exit code 1).
    """
    lines = [f"### {new.name}", ""]
    if not new.exists():
        return lines + [f"_fresh run produced no {new.name} — skipped_", ""]
    if not old.exists():
        # a suite absent from the previous artifact set must not vanish
        # from the table — record it explicitly as the new baseline
        n = len(load_cells(new))
        return lines + [f"_new suite, no baseline — {n} cell(s) recorded, "
                        "deltas start next run_", ""]
    old_cells, new_cells = load_cells(old), load_cells(new)
    shared = [k for k in new_cells if k in old_cells]
    added = [k for k in new_cells if k not in old_cells]
    if not shared and not added:
        return lines + ["_no overlapping cells with the previous run_", ""]
    lines += ["| cell | metric | prev | now | Δ% |",
              "|---|---|---:|---:|---:|"]
    for key in shared:
        oc, nc = old_cells[key], new_cells[key]
        for m in METRICS:
            if m not in nc or m not in oc:
                continue
            ov, nv = float(oc[m]), float(nc[m])
            if ov == 0.0:                # pct undefined; don't print +inf%
                delta = "n/a (prev 0)" if nv != ov else "+0.0%"
                lines.append(f"| {_fmt_key(nc)} | {m} | {ov:g} | {nv:g} | "
                             f"{delta} |")
                continue
            pct = (nv - ov) / abs(ov) * 100.0
            regressed = (pct < -warn_pct if m in HIGHER_IS_BETTER
                         else abs(pct) > warn_pct)
            flag = " ⚠" if regressed else ""
            lines.append(f"| {_fmt_key(nc)} | {m} | {ov:g} | {nv:g} | "
                         f"{pct:+.1f}%{flag} |")
            if fail_on and m in fail_on:
                bad_pct = -pct if m in HIGHER_IS_BETTER else pct
                if bad_pct > fail_on[m]:
                    regressions.append(
                        f"{new.name}: {_fmt_key(nc)} {m} regressed "
                        f"{pct:+.1f}% (limit {fail_on[m]:g}%)")
    for key in added:                    # e.g. a new sweep column value
        lines.append(f"| {_fmt_key(new_cells[key])} | — | — | — | "
                     f"new cell, no baseline |")
    dropped = len(old_cells) - len(shared)
    if dropped:
        lines.append(f"\n_{dropped} cell(s) no longer produced_")
    return lines + [""]


def discover_files(new_dir: Path, old_dir: Optional[Path] = None
                   ) -> List[str]:
    """Suites to compare: every BENCH_*.json either run produced, plus the
    historical defaults — so a suite that stopped producing (even a
    non-default one) still reports its skip line instead of vanishing."""
    found = {p.name for p in new_dir.glob("BENCH_*.json")}
    if old_dir is not None:
        found |= {p.name for p in old_dir.glob("BENCH_*.json")}
    return sorted(found | set(DEFAULT_FILES))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old_dir", help="directory with the previous run's "
                                    "BENCH_*.json (may be empty/missing)")
    ap.add_argument("new_dir", help="directory with the fresh BENCH_*.json")
    ap.add_argument("--files", nargs="+", default=None,
                    help="explicit artifact names (default: auto-discover "
                         "BENCH_*.json in new_dir + the defaults)")
    ap.add_argument("--warn-pct", type=float, default=15.0,
                    help="flag deltas beyond this magnitude (default 15)")
    ap.add_argument("--fail-on-regression", action="append", default=[],
                    metavar="METRIC:PCT",
                    help="opt-in hard gate (repeatable): exit 1 when METRIC "
                         "regresses beyond PCT percent in any shared cell "
                         "(e.g. queries_per_sec:25).  Without it the table "
                         "stays warn-only.")
    args = ap.parse_args()

    fail_on: Dict[str, float] = {}
    for spec in args.fail_on_regression:
        metric, _, pct = spec.partition(":")
        if not pct:
            ap.error(f"--fail-on-regression wants METRIC:PCT, got {spec!r}")
        if metric not in METRICS:
            ap.error(f"unknown metric {metric!r}; choose from {METRICS}")
        fail_on[metric] = float(pct)

    files = args.files if args.files is not None \
        else discover_files(Path(args.new_dir), Path(args.old_dir))
    gated = f"gated on {sorted(fail_on)}" if fail_on else "warn-only"
    out = [f"## Bench trend ({gated})", ""]
    regressions: List[str] = []
    for name in files:
        out += compare_file(Path(args.old_dir) / name,
                            Path(args.new_dir) / name, args.warn_pct,
                            fail_on=fail_on, regressions=regressions)
    print("\n".join(out))
    if regressions:
        print("\n".join(["", "**FAIL: gated metric regressed**"]
                        + [f"- {r}" for r in regressions]))
        sys.exit(1)
    sys.exit(0)          # warn-only by default: never fail the job


if __name__ == "__main__":
    main()
