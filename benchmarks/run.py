"""Benchmark registry — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Run everything:
    PYTHONPATH=src python -m benchmarks.run
or a subset:
    PYTHONPATH=src python -m benchmarks.run --only fig7,fig12
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = {
    "fig7": ("benchmarks.bench_recall", "Fig. 7 recall/time vs baselines"),
    "fig8": ("benchmarks.bench_index_build", "Fig. 8 index construction"),
    "fig9": ("benchmarks.bench_k_sweep", "Fig. 9 K sweep"),
    "fig10": ("benchmarks.bench_pivots", "Fig. 10 pivot-count sweep"),
    "fig11": ("benchmarks.bench_variations", "Fig. 11 variants ablation"),
    "fig12": ("benchmarks.bench_prefix_len", "Fig. 12 prefix-length sweep"),
    "table1": ("benchmarks.bench_memory_systems", "Table I memory-systems"),
    "kernels": ("benchmarks.bench_kernels", "Pallas kernel parity/µbench"),
    "engine": ("benchmarks.bench_query_engine",
               "ClimberEngine queries/sec sweep"),
    "fleet": ("benchmarks.bench_fleet",
              "IndexFleet shards × routing × delta-fill sweep"),
    "serve_net": ("benchmarks.bench_serve_net",
                  "network serving plane qps + tails per concurrency"),
    "recall_frontier": ("benchmarks.bench_recall_frontier",
                        "Hydra-style recall-vs-data-touched frontier"),
    "roofline": ("benchmarks.roofline", "§Roofline table from dry-run"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = list(SUITES) if args.only == "all" else args.only.split(",")

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod_name, desc = SUITES[name]
        print(f"# === {name}: {desc} ===")
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:                       # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s")
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
