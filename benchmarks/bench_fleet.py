"""IndexFleet serving sweep — shards × routing × placement × delta fill,
plus the lifecycle columns.

Drives the sharded multi-index fleet over a synthetic RandomWalk corpus:
splits the corpus into S tenant shards, optionally streams a delta's worth
of fresh records in, and measures queries/sec, recall against brute force
over the *current* fleet contents, mean partitions touched, and the
router's audited precision/fan-out savings.  The exhaustive rows are the
lossless baseline; the signature rows show what the router trades.

The **placement** column compares the two sealed-shard execution paths:
``host`` (the sequential per-shard oracle loop) vs ``mesh`` (the
device-resident stacked stores queried through one shard_map — see
``repro.fleet.placement``).  On a single CPU device the mesh rows mostly
measure dispatch overhead vs the S-dispatch loop; on a real multi-device
host they measure the fan-out overlap.  Either way the bench-trend CI step
tracks the host/mesh ratio run over run, and recall must be identical
between placements (the mesh path is bit-identical by construction).

Each placement cell is timed after one untimed warm-up call (compilation
plus, on the mesh path, the device-plan cache fill), so the numbers are
steady-state serving throughput; every cell also carries the per-stage
wall-time breakdown — ``plan_ms`` / ``refine_ms`` / ``merge_ms`` from
``FleetQueryInfo.stage_ms`` — so the device-resident-planning win shows up
as a column of its own in the bench-trend table, not just in total qps.
Each cell also carries ``latency_p50_ms`` / ``latency_p99_ms`` read from
the fleet's ``fleet.query_latency_ms`` registry histogram (``repro.obs``)
over the timed window, next to queries/sec.  The timed window splits the
query set into ``TIMED_BATCHES`` separate ``query()`` calls per repeat
(histogram reset per cell) so the quantiles summarize a real latency
distribution — a single batched call would observe one duration and
report ``p50 == p99``.

The **lifecycle** rows measure the fleet's persistence/maintenance plane
(``repro.fleet.lifecycle``): wall time of one delta seal (``compaction_ms``
— the INX rebuild that now runs on the compactor worker thread) and of a
full crash restart (``restart_replay_ms`` — ``IndexFleet.open``: shard
snapshot loads + WAL tail replay).  Run only those rows with
``python -m benchmarks.bench_fleet --lifecycle``.

Besides the CSV rows, writes ``artifacts/BENCH_fleet.json`` alongside the
engine trajectory; the bench-trend CI step diffs every column run over
run.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import default_cfg, emit
from repro.baselines import exact_knn, recall
from repro.data import make_dataset
from repro.fleet import FleetConfig, IndexFleet
from repro.launch.mesh import make_mesh

ART = Path(__file__).resolve().parents[1] / "artifacts"

K = 20
NUM_QUERIES = 24
N = 6_000
SERIES_LEN = 128
SHARD_COUNTS = (1, 4)
ROUTING_MODES = ("signature", "exhaustive")
PLACEMENTS = ("host", "mesh")
DELTA_FILLS = (0.0, 0.5)          # fraction of delta_capacity streamed in
DELTA_CAPACITY = 1_024
TIMED_BATCHES = 4                 # query() calls per repeat in the timed
                                  # window (each is one latency observation)
TIMED_REPEATS = 3


def mesh_devices() -> int:
    """Mesh width for the placement sweep: up to 4 devices (the CI cell
    forces 8 host devices; 4 keeps one device per shard on the big cell)."""
    return min(jax.device_count(), 4)


def lifecycle_cells() -> list:
    """Compaction latency + restart-replay time for the bench artifact."""
    cfg = default_cfg(k=K)
    base = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(3),
                                   2_048, SERIES_LEN))
    fresh = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(4),
                                    DELTA_CAPACITY // 2, SERIES_LEN))
    cells = []
    with tempfile.TemporaryDirectory(prefix="bench-fleet-") as storage:
        fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=1,
                                       delta_capacity=DELTA_CAPACITY,
                                       auto_compact=False),
                           storage_dir=storage)
        fleet.add_shard("t0", base)
        for lo in range(0, len(fresh), 128):      # batched streaming ingest
            fleet.insert(fresh[lo: lo + 128])
        n_delta = fleet.delta.occupancy

        t0 = time.perf_counter()
        fleet.compact()
        compaction_ms = (time.perf_counter() - t0) * 1e3
        emit("fleet/lifecycle/compact", compaction_ms * 1e3,
             f"records={n_delta};compaction_ms={compaction_ms:.1f}")
        cells.append({"op": "compaction", "records": n_delta,
                      "compaction_ms": round(compaction_ms, 2)})

        # restart with a replayable WAL tail: stream another half delta in,
        # then time a cold open (snapshot loads + replay)
        for lo in range(0, len(fresh), 128):
            fleet.insert(fresh[lo: lo + 128] * 1.01)
        n_tail = fleet.delta.occupancy
        t0 = time.perf_counter()
        restored = IndexFleet.open(storage)
        restart_ms = (time.perf_counter() - t0) * 1e3
        assert restored.delta.occupancy == n_tail
        emit("fleet/lifecycle/restart", restart_ms * 1e3,
             f"wal_records={n_tail};restart_replay_ms={restart_ms:.1f}")
        cells.append({"op": "restart_replay", "records": n_tail,
                      "restart_replay_ms": round(restart_ms, 2)})
    return cells


def run(lifecycle_only: bool = False) -> None:
    if lifecycle_only:
        _write_artifact(lifecycle_cells(), mesh_devices=mesh_devices())
        return
    cfg = default_cfg(k=K)
    base = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   N, SERIES_LEN))
    fresh = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(1),
                                    int(DELTA_CAPACITY * max(DELTA_FILLS)),
                                    SERIES_LEN))
    queries = base[:NUM_QUERIES] + 0.05 * np.asarray(
        make_dataset("randomwalk", jax.random.PRNGKey(2), NUM_QUERIES,
                     SERIES_LEN))

    cells = []
    for shards in SHARD_COUNTS:
        for fill in DELTA_FILLS:
            fleet = IndexFleet(FleetConfig(
                shard_cfg=cfg, fanout=max(1, shards // 2),
                delta_capacity=DELTA_CAPACITY, auto_compact=False))
            per = N // shards
            for s in range(shards):
                fleet.add_shard(f"t{s}", base[s * per:(s + 1) * per])
            n_fill = int(DELTA_CAPACITY * fill)
            if n_fill:
                fleet.insert(fresh[:n_fill])
            contents = np.concatenate([base[:per * shards], fresh[:n_fill]])
            _, exact_ids = exact_knn(queries, contents, K)
            fleet.attach_mesh(make_mesh((mesh_devices(),), ("data",)))

            qbatches = np.array_split(queries, TIMED_BATCHES)
            for routing in ROUTING_MODES:
                for placement in PLACEMENTS:
                    # warm-up: compile the per-placement programs at both
                    # the full and the timed batch shape (and, on the mesh
                    # path, populate the device-plan cache) so the timed
                    # loop measures steady-state serving throughput
                    fleet.query(queries, K, routing=routing,
                                placement=placement)
                    fleet.query(qbatches[0], K, routing=routing,
                                placement=placement)
                    # quantiles come from the fleet's registry histogram;
                    # reset it so the cell sees only the timed window (the
                    # later audit_routing calls issue more queries).  The
                    # window issues TIMED_BATCHES calls per repeat — one
                    # histogram observation each — so p50/p99 are real
                    # tails, not one batch-sized flush repeated.
                    fleet.query_hist.reset()
                    t0 = time.perf_counter()
                    for _ in range(TIMED_REPEATS):
                        outs = [fleet.query(qb, K, routing=routing,
                                            placement=placement)
                                for qb in qbatches]
                    secs = (time.perf_counter() - t0) / TIMED_REPEATS
                    p50 = fleet.query_hist.quantile(0.5)
                    p99 = fleet.query_hist.quantile(0.99)
                    qps = NUM_QUERIES / secs
                    gid = np.concatenate([o[1] for o in outs])
                    infos = [o[2] for o in outs]
                    r = recall(gid, np.asarray(exact_ids))
                    parts = float(np.concatenate(
                        [i.partitions_touched for i in infos]).mean())
                    masks = np.concatenate([i.routed_mask for i in infos])
                    fanout = float(masks.sum(axis=1).mean()) \
                        if masks.size else 0.0
                    stage = {key: sum((i.stage_ms or {}).get(key, 0.0)
                                      for i in infos)
                             for key in ("plan_ms", "refine_ms", "merge_ms")}
                    precision = fleet.audit_routing(queries, K) \
                        if routing == "signature" else 1.0
                    tag = (f"fleet/s{shards}/fill{fill:.1f}/{routing}"
                           f"/{placement}")
                    emit(tag, 1e6 / qps if qps else 0.0,
                         f"qps={qps:.1f};recall={r:.3f};parts={parts:.1f};"
                         f"precision={precision:.3f};"
                         f"plan_ms={stage.get('plan_ms', 0.0):.1f};"
                         f"p50={p50:.1f};p99={p99:.1f}")
                    cells.append({
                        "shards": shards, "delta_fill": fill,
                        "routing": routing, "placement": placement,
                        "queries_per_sec": round(qps, 2),
                        "latency_p50_ms": round(p50, 3),
                        "latency_p99_ms": round(p99, 3),
                        "recall": round(float(r), 4),
                        "mean_partitions_touched": round(parts, 2),
                        "mean_fanout": round(fanout, 2),
                        "routing_precision": round(float(precision), 4),
                        "plan_ms": round(stage.get("plan_ms", 0.0), 2),
                        "refine_ms": round(stage.get("refine_ms", 0.0), 2),
                        "merge_ms": round(stage.get("merge_ms", 0.0), 2),
                        "delta_occupancy": fleet.delta.occupancy,
                        "num_queries": NUM_QUERIES, "k": K,
                    })

    cells.extend(lifecycle_cells())
    _write_artifact(cells, mesh_devices=mesh_devices())


def _write_artifact(cells: list, *, mesh_devices: int) -> None:
    ART.mkdir(exist_ok=True)
    out = ART / "BENCH_fleet.json"
    out.write_text(json.dumps({
        "bench": "fleet",
        "dataset": {"name": "randomwalk", "n": N, "series_len": SERIES_LEN},
        "delta_capacity": DELTA_CAPACITY,
        "mesh_devices": mesh_devices,
        "cells": cells,
    }, indent=2))
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--lifecycle", action="store_true",
                    help="run (and write) only the lifecycle columns")
    run(lifecycle_only=ap.parse_args().lifecycle)
