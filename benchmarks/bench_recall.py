"""Fig. 7 — query execution: time + recall vs Dss / DPiSAX / TARDIS.

(a)/(b): four datasets at fixed size; (c)/(d): RandomWalk size sweep.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (climber_recall, default_cfg, emit,
                               standard_setup, timed)
from repro.baselines import (build_dpisax, build_tardis, dpisax_knn,
                             exact_knn, recall, tardis_knn)
from repro.core import build_index
from repro.data import make_dataset, make_queries

K = 50


def _one_dataset(name: str, n: int, tag: str) -> None:
    data, queries, exact_ids = standard_setup(name, n, k=K)
    cfg = default_cfg(k=K)

    # Dss (exact, the ground truth generator) — time only, recall = 1
    (_, _), t_dss = timed(lambda: exact_knn(queries, data, K))
    emit(f"fig7/{tag}/dss", t_dss * 1e6, "recall=1.000")

    index = build_index(jax.random.PRNGKey(7), data, cfg)
    r, secs, touched = climber_recall(index, queries, exact_ids, K)
    emit(f"fig7/{tag}/climber", secs * 1e6,
         f"recall={r:.3f};parts={touched:.1f}")

    dp = build_dpisax(data, segments=cfg.paa_segments, cardinality=8,
                      capacity=cfg.capacity)
    (_, gid_d), t_d = timed(lambda: dpisax_knn(dp, queries, K))
    emit(f"fig7/{tag}/dpisax", t_d * 1e6,
         f"recall={recall(np.asarray(gid_d), np.asarray(exact_ids)):.3f}")

    td = build_tardis(jax.random.PRNGKey(8), data, segments=cfg.paa_segments,
                      cardinality=8, capacity=cfg.capacity,
                      sample_frac=cfg.sample_frac)
    (_, gid_t), t_t = timed(lambda: tardis_knn(td, queries, K))
    emit(f"fig7/{tag}/tardis", t_t * 1e6,
         f"recall={recall(np.asarray(gid_t), np.asarray(exact_ids)):.3f}")


def run() -> None:
    # (a)/(b): four domains (the paper's RandomWalk/Texmex/DNA/EEG analogues)
    for name in ("randomwalk", "sift", "dna", "eeg"):
        _one_dataset(name, 12_000, name)
    # (c)/(d): size sweep on RandomWalk
    for n in (4_000, 8_000, 16_000, 32_000):
        _one_dataset("randomwalk", n, f"size{n}")
