"""DPiSAX-like baseline (Yagoubi et al. [65]) — partitioned iSAX.

DPiSAX samples the dataset, computes iSAX words, and derives a partitioning
table by splitting on the words' most-significant bits; every record is then
routed to exactly one partition, and a query scans the single partition its
own word maps to.  We reproduce that design: the partition key concatenates
the top bit of segments chosen round-robin until ~N/capacity partitions
exist.  Accuracy is bounded by the single-partition constraint plus the
two-level iSAX information loss — the behaviour the paper reports (<10%
recall at scale, §I).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.isax import sax_word
from repro.core.index import PartitionStore, build_store
from repro.core.refine import refine


@dataclass
class DPiSAXIndex:
    segments: int
    cardinality: int
    key_bits: int            # number of segments contributing their MSB
    store: PartitionStore

    @property
    def num_partitions(self) -> int:
        return 1 << self.key_bits


def _partition_key(word: jnp.ndarray, cardinality: int, key_bits: int) -> jnp.ndarray:
    """MSB of the first ``key_bits`` segments, concatenated."""
    full_bits = int(cardinality).bit_length() - 1
    msb = (word[..., :key_bits] >> (full_bits - 1)) & 1          # [..., kb]
    weights = (1 << jnp.arange(key_bits - 1, -1, -1)).astype(jnp.int32)
    return jnp.sum(msb * weights, axis=-1).astype(jnp.int32)


def build_dpisax(data: jnp.ndarray, *, segments: int = 16,
                 cardinality: int = 8, capacity: int = 3000) -> DPiSAXIndex:
    n_rec = data.shape[0]
    key_bits = int(np.clip(np.ceil(np.log2(max(n_rec / capacity, 1))),
                           1, segments))
    word = sax_word(data, segments, cardinality)
    part = _partition_key(word, cardinality, key_bits)
    rec_dfs = np.zeros(n_rec, dtype=np.int32)     # single node per partition
    store = build_store(data, np.asarray(part), rec_dfs, 1 << key_bits)
    return DPiSAXIndex(segments=segments, cardinality=cardinality,
                       key_bits=key_bits, store=store)


def dpisax_knn(index: DPiSAXIndex, queries: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-partition approximate kNN (the DPiSAX query model)."""
    word = sax_word(queries, index.segments, index.cardinality)
    part = _partition_key(word, index.cardinality, index.key_bits)
    q = queries.shape[0]
    sel_part = part[:, None]                                     # [Q, 1]
    sel_lo = jnp.zeros((q, 1), jnp.int32)
    sel_hi = jnp.ones((q, 1), jnp.int32)
    return refine(index.store, queries, sel_part, sel_lo, sel_hi, k)
