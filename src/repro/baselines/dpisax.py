"""DPiSAX-like baseline (Yagoubi et al. [65]) — partitioned iSAX.

DPiSAX samples the dataset, computes iSAX words, and derives a *partitioning
table* by recursively splitting dense regions of the word space on the next
iSAX bit until every partition respects the capacity constraint; every record
is then routed to exactly one partition, and a query scans the single
partition its own word maps to.  We reproduce that design: partitions are
leaves of a binary prefix tree over the words' bits (segment-major,
most-significant bit first — the iSAX variable-cardinality order), and a
leaf over capacity is split on its next bit.  Adaptive splitting is what
keeps "data touched" comparable across systems — a fixed global split would
leave giant partitions wherever the word distribution is skewed.  Accuracy
is still bounded by the single-partition constraint plus the two-level iSAX
information loss — the behaviour the paper reports (<10% recall at scale,
§I).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.isax import sax_word
from repro.core.index import PartitionStore, build_store
from repro.core.refine import refine


@dataclass
class DPiSAXIndex:
    segments: int
    cardinality: int
    table: Dict[Tuple[int, ...], int]   # bit-prefix → partition id (leaves)
    store: PartitionStore

    @property
    def num_partitions(self) -> int:
        return self.store.num_partitions


def _word_bits(word: jnp.ndarray, cardinality: int) -> np.ndarray:
    """Flatten iSAX words to their split-order bit matrix ``[..., D]``.

    Bit d compares segment ``d % segments`` at depth ``d // segments`` —
    round-robin over segments, most-significant bit first, so prefix length
    equals iSAX cardinality refinement.
    """
    w = np.asarray(word)
    segments = w.shape[-1]
    full_bits = int(cardinality).bit_length() - 1
    cols = []
    for depth in range(full_bits):
        shift = full_bits - 1 - depth
        cols.append((w >> shift) & 1)                # [..., segments]
    return np.concatenate(cols, axis=-1).astype(np.int8)  # [..., seg*bits]


def _build_table(bits: np.ndarray, capacity: int
                 ) -> Tuple[Dict[Tuple[int, ...], int], np.ndarray]:
    """Adaptive partitioning table: split any over-capacity region further.

    Returns the leaf table (prefix → pid) and each record's pid.
    """
    n, max_depth = bits.shape
    table: Dict[Tuple[int, ...], int] = {}
    part = np.zeros(n, dtype=np.int32)
    stack = [(np.arange(n), 0, ())]
    while stack:
        rows, depth, prefix = stack.pop()
        if len(rows) <= capacity or depth >= max_depth:
            pid = len(table)
            table[prefix] = pid
            part[rows] = pid
            continue
        b = bits[rows, depth]
        stack.append((rows[b == 0], depth + 1, prefix + (0,)))
        stack.append((rows[b == 1], depth + 1, prefix + (1,)))
    return table, part


def _route(table: Dict[Tuple[int, ...], int], bits: np.ndarray) -> np.ndarray:
    """Longest-prefix descent of each word through the leaf table."""
    out = np.empty(bits.shape[0], dtype=np.int32)
    for i, row in enumerate(bits):
        prefix: Tuple[int, ...] = ()
        while prefix not in table:
            prefix = prefix + (int(row[len(prefix)]),)
        out[i] = table[prefix]
    return out


def build_dpisax(data: jnp.ndarray, *, segments: int = 16,
                 cardinality: int = 8, capacity: int = 3000) -> DPiSAXIndex:
    n_rec = data.shape[0]
    word = sax_word(data, segments, cardinality)
    bits = _word_bits(word, cardinality)
    table, part = _build_table(bits, capacity)
    rec_dfs = np.zeros(n_rec, dtype=np.int32)     # single node per partition
    store = build_store(data, part, rec_dfs, len(table))
    return DPiSAXIndex(segments=segments, cardinality=cardinality,
                       table=table, store=store)


def dpisax_knn(index: DPiSAXIndex, queries: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-partition approximate kNN (the DPiSAX query model)."""
    word = sax_word(queries, index.segments, index.cardinality)
    part = _route(index.table, _word_bits(word, index.cardinality))
    q = queries.shape[0]
    sel_part = jnp.asarray(part)[:, None]                        # [Q, 1]
    sel_lo = jnp.zeros((q, 1), jnp.int32)
    sel_hi = jnp.ones((q, 1), jnp.int32)
    return refine(index.store, queries, sel_part, sel_lo, sel_hi, k)
