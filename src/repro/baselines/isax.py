"""SAX / iSAX representation (paper §III-B, Fig. 1) — baseline substrate.

SAX divides the value axis into ``cardinality`` stripes whose boundaries are
standard-normal quantiles (Lin et al. [39]) and assigns each PAA segment the
stripe containing its mean.  Both baseline indexes (DPiSAX, TARDIS) operate
on these lossy words — reproducing the two-level information loss the paper
identifies as the root cause of their low recall.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

from repro.core.paa import paa


def sax_breakpoints(cardinality: int) -> jnp.ndarray:
    """Stripe boundaries: N(0,1) quantiles at i/card, i = 1..card-1."""
    probs = jnp.arange(1, cardinality, dtype=jnp.float32) / cardinality
    return ndtri(probs)


def sax_word(x: jnp.ndarray, segments: int, cardinality: int) -> jnp.ndarray:
    """SAX transform: raw ``[..., n]`` → symbol word ``[..., w]`` int32.

    Symbols are stripe indices in [0, cardinality); all segments share the
    same cardinality (the iSAX variable-cardinality refinement is applied by
    the indexes through bit prefixes of these symbols).
    """
    z = paa(x, segments)
    bp = sax_breakpoints(cardinality)
    return jnp.searchsorted(bp, z).astype(jnp.int32)


def isax_bits(word: jnp.ndarray, bits: int, cardinality: int) -> jnp.ndarray:
    """Keep only the ``bits`` most-significant bits of each symbol.

    This is iSAX's prefix maintenance (Fig. 1b): lower cardinality = shorter
    binary prefix of the same symbol.
    """
    full_bits = int(cardinality).bit_length() - 1
    return (word >> (full_bits - bits)).astype(jnp.int32)
