"""Dss — Distributed Sequential Scan (paper §VII-A baseline).

The vanilla full-scan solution: compare the query against every record in
parallel and take the exact top-k.  Produces the ground truth (recall = 1.0)
for every benchmark; on a mesh it shards the record dimension over the data
axis (each device scans its shard, then one all-gather merges the top-k).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.distances import squared_l2_pairwise

_INF = jnp.float32(3.4e38)


def exact_knn(queries: jnp.ndarray, data: jnp.ndarray, k: int,
              *, chunk: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact kNN by full scan.

    Args:
      queries: ``[Q, n]``; data: ``[N, n]``; k: answers per query.
      chunk: scan the dataset in chunks of this many rows (0 = single pass) —
        bounds the [Q, N] distance matrix for big N.

    Returns:
      (dist, idx): ``[Q, k]`` ascending true ED + record ids.
    """
    qn = queries.shape[0]
    n_rec = data.shape[0]
    k = min(k, n_rec)
    if not chunk or chunk >= n_rec:
        d2 = squared_l2_pairwise(queries, data)
        neg, idx = jax.lax.top_k(-d2, k)
        return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx

    # streaming scan with a running top-k (the disk-resident formulation)
    best_d = jnp.full((qn, k), _INF)
    best_i = jnp.full((qn, k), -1, dtype=jnp.int32)
    for start in range(0, n_rec, chunk):
        block = jax.lax.dynamic_slice_in_dim(
            data, start, min(chunk, n_rec - start), axis=0)
        d2 = squared_l2_pairwise(queries, block)
        ids = start + jnp.arange(block.shape[0], dtype=jnp.int32)
        cat_d = jnp.concatenate([best_d, d2], axis=-1)
        cat_i = jnp.concatenate([best_i, jnp.tile(ids, (qn, 1))], axis=-1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        best_d = -neg
        best_i = jnp.take_along_axis(cat_i, pos, axis=-1)
    return jnp.sqrt(jnp.maximum(best_d, 0.0)), best_i


def exact_knn_sharded(queries: jnp.ndarray, data: jnp.ndarray, k: int,
                      *, mesh, data_axis: str = "data"):
    """Mesh version: records sharded over ``data_axis``, queries replicated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(q, x):
        d2 = squared_l2_pairwise(q, x)
        neg, idx = jax.lax.top_k(-d2, k)
        base = jax.lax.axis_index(data_axis) * x.shape[0]
        idx = idx + base
        d_all = jax.lax.all_gather(-neg, data_axis, axis=0)
        i_all = jax.lax.all_gather(idx, data_axis, axis=0)
        d = d_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        i = i_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        neg2, pos = jax.lax.top_k(-d, k)
        return jnp.sqrt(jnp.maximum(-neg2, 0.0)), jnp.take_along_axis(i, pos, -1)

    fn = shard_map(local, mesh=mesh, in_specs=(P(), P(data_axis)),
                   out_specs=(P(), P()), check_rep=False)
    return fn(queries, data)


def recall(approx_ids: jnp.ndarray, exact_ids: jnp.ndarray) -> float:
    """Def. 4: |S_approx ∩ S_exact| / |S_exact|, averaged over queries."""
    import numpy as np
    a = np.asarray(approx_ids)
    e = np.asarray(exact_ids)
    scores = []
    for i in range(a.shape[0]):
        sa = set(int(v) for v in a[i] if v >= 0)
        se = set(int(v) for v in e[i])
        scores.append(len(sa & se) / max(len(se), 1))
    return float(np.mean(scores))
