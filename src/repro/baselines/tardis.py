"""TARDIS-like baseline (Zhang et al. [67]) — sigTree over iSAX words.

TARDIS builds a wide n-ary tree (sigTree) over full iSAX words — level d
branches on segment d's symbol — splits nodes over capacity, and clusters
subtrees into physical partitions.  A query descends to its deepest matching
node and scans that node's partition(s).

We express the sigTree with the same flattened-trie machinery CLIMBER uses
(``repro.core.trie`` with alphabet = SAX cardinality instead of pivot ids):
the *only* delta between this baseline and CLIMBER is the representation
(lossy iSAX symbols vs the dual P⁴ pivot signatures + OD/WD group level),
which isolates exactly the paper's contribution in the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.isax import sax_word
from repro.core.index import PartitionStore, build_store
from repro.core.refine import refine
from repro.core.traversal import TrieDevice, descend, route_records
from repro.core.trie import TrieForest, build_forest


@dataclass
class TardisIndex:
    segments: int
    cardinality: int
    forest: TrieForest
    trie: TrieDevice
    store: PartitionStore


def build_tardis(key: jax.Array, data: jnp.ndarray, *, segments: int = 16,
                 cardinality: int = 8, capacity: int = 3000,
                 sample_frac: float = 0.1) -> TardisIndex:
    n_rec = data.shape[0]
    sample_size = max(int(n_rec * sample_frac), min(n_rec, 256))
    alpha_eff = sample_size / n_rec
    idx = jax.random.choice(key, n_rec, shape=(sample_size,), replace=False)

    words_s = np.asarray(sax_word(data[idx], segments, cardinality))
    uniq, counts = np.unique(words_s, axis=0, return_counts=True)
    forest = build_forest(uniq.astype(np.int32), counts,
                          np.zeros(len(uniq), dtype=np.int32), 1, cardinality,
                          capacity=float(capacity), sample_frac=alpha_eff)
    trie = TrieDevice.from_forest(forest)

    words = sax_word(data, segments, cardinality)
    grp = jnp.zeros(n_rec, dtype=jnp.int32)
    part, rec_dfs = route_records(trie, words, grp)
    store = build_store(data, np.asarray(part), np.asarray(rec_dfs),
                        forest.num_partitions)
    return TardisIndex(segments=segments, cardinality=cardinality,
                       forest=forest, trie=trie, store=store)


def tardis_knn(index: TardisIndex, queries: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deepest-node single-target query (the sigTree search model)."""
    words = sax_word(queries, index.segments, index.cardinality)
    grp = jnp.zeros(queries.shape[0], dtype=jnp.int32)
    node, pathlen, _ = descend(index.trie, words, grp)
    sel_part = index.trie.part_ids_pad[node]                     # [Q, maxP]
    ones = jnp.ones_like(sel_part)
    sel_lo = index.trie.dfs_in[node][:, None] * ones
    sel_hi = index.trie.dfs_out[node][:, None] * ones
    return refine(index.store, queries, sel_part,
                  sel_lo.astype(jnp.int32), sel_hi.astype(jnp.int32), k)
