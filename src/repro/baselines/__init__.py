from repro.baselines.dss import exact_knn, exact_knn_sharded, recall
from repro.baselines.isax import sax_word, sax_breakpoints, isax_bits
from repro.baselines.dpisax import DPiSAXIndex, build_dpisax, dpisax_knn
from repro.baselines.tardis import TardisIndex, build_tardis, tardis_knn

__all__ = ["exact_knn", "exact_knn_sharded", "recall", "sax_word",
           "sax_breakpoints", "isax_bits", "DPiSAXIndex", "build_dpisax",
           "dpisax_knn", "TardisIndex", "build_tardis", "tardis_knn"]
