"""The jitted training step + its sharding contract.

``make_train_step`` builds the (params, opt_state, batch) → (params',
opt_state', metrics) function; ``shard_train_step`` wraps it with explicit
in/out shardings for a mesh (the object the dry-run lowers and the launcher
runs).  Gradient all-reduces over data/pod axes are inserted by GSPMD from
the sharding contract — the cross-pod axis only ever carries gradients.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.models import Model, param_pspecs
from repro.train.optimizer import AdamW, AdamWState


def make_train_step(model: Model, opt: AdamW, *, kv_chunk: int = 2048,
                    microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) → (params', opt_state', metrics).

    microbatches > 1 enables gradient accumulation: the global batch is
    split along dim 0 and scanned, bounding in-flight activations to one
    microbatch (mandatory for the ≥70B train cells at 16 GB/chip).
    """
    def loss_fn(params, batch):
        return model.train_loss(params, batch, kv_chunk=kv_chunk)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                loss_acc, grad_acc = carry
                if model.mesh is not None:
                    mb = jax.tree_util.tree_map(
                        lambda x: jax.lax.with_sharding_constraint(
                            x, NamedSharding(model.mesh,
                                             batch_pspec(model.mesh,
                                                         x.ndim - 1))),
                        mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), grad_acc, g)
                return (loss_acc + l, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            # honour the dry-run cost-compile unroll flag: a rolled µ-scan
            # is counted once by XLA cost analysis (see dryrun.py)
            from repro.models.layers import INNER_SCAN_UNROLL
            (loss_sum, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), split,
                unroll=INNER_SCAN_UNROLL or 1)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        new_params, new_state, stats = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss, **stats}
    return train_step


def batch_pspec(mesh, extra_dims: int = 1) -> PS:
    """Batch arrays shard their leading dim over every non-model axis."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return PS(axes, *([None] * extra_dims))


def make_batch_shardings(mesh, batch_tree):
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = int(np.prod([sizes[a] for a in mesh.axis_names if a != "model"]))

    def one(x):
        if x.shape and x.shape[0] % n_batch == 0:
            return NamedSharding(mesh, batch_pspec(mesh, x.ndim - 1))
        return NamedSharding(mesh, PS())          # e.g. global_batch=1 decode
    return jax.tree_util.tree_map(one, batch_tree)


def make_state_shardings(mesh, model: Model):
    """NamedShardings for (params, opt_state) from the logical-axis rules."""
    infos = model.infos()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = param_pspecs(infos, sizes)
    p_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_shard = AdamWState(
        step=NamedSharding(mesh, PS()),
        m=p_shard, v=p_shard)
    return p_shard, opt_shard


def shard_train_step(model: Model, opt: AdamW, mesh, batch_shapes,
                     *, kv_chunk: int = 2048, donate: bool = True,
                     microbatches: int = 1):
    """jit(train_step) with the full sharding contract attached.

    batch_shapes: pytree of ShapeDtypeStruct for one global batch.
    Returns (jitted_fn, (param_shardings, opt_shardings, batch_shardings)).
    """
    p_shard, o_shard = make_state_shardings(mesh, model)
    b_shard = make_batch_shardings(mesh, batch_shapes)
    fn = make_train_step(model, opt, kv_chunk=kv_chunk,
                         microbatches=microbatches)
    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard,
                       NamedSharding(mesh, PS())),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (p_shard, o_shard, b_shard)
