"""Fault tolerance for long-running multi-pod jobs.

Three mechanisms, each exercised by tests:

1. **Step watchdog / straggler detection** — every train step runs under a
   deadline derived from a running p95 of past step times; a step that blows
   the deadline marks the fleet "suspect" and triggers the recovery ladder
   (on a real fleet this is where the cluster manager gets paged; here the
   policy object is fully testable).

2. **Retry-with-restore** — transient failures (preemption, ICI glitch,
   numerical NaN-burst) restart from the last atomic checkpoint; the data
   pipeline key is part of the checkpoint so the batch sequence replays
   deterministically.

3. **Elastic re-mesh** — when a pod/slice is lost, the job continues on a
   smaller mesh: ``plan_remesh`` computes the largest valid (pods, data,
   model) grid for the surviving chip count, and restore re-shards the
   checkpoint onto it (see ``checkpoint.restore_checkpoint(shardings=...)``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class WatchdogPolicy:
    """Running-quantile deadline for straggler detection."""

    warmup_steps: int = 5
    multiplier: float = 3.0
    min_deadline_s: float = 5.0
    _history: List[float] = dataclasses.field(default_factory=list)

    def record(self, step_time_s: float) -> None:
        self._history.append(step_time_s)
        if len(self._history) > 100:
            self._history.pop(0)

    @property
    def deadline_s(self) -> float:
        if len(self._history) < self.warmup_steps:
            return float("inf")
        hist = sorted(self._history)
        p95 = hist[int(0.95 * (len(hist) - 1))]
        return max(self.multiplier * p95, self.min_deadline_s)

    def is_straggler(self, step_time_s: float) -> bool:
        return step_time_s > self.deadline_s


def plan_remesh(surviving_chips: int, *, model_parallel: int = 16
                ) -> Optional[Tuple[int, int]]:
    """Largest (data, model) grid on the survivors, keeping TP intact.

    Model-parallel groups must stay whole (a TP shard loss kills its whole
    group), so the surviving chip count is floored to a multiple of
    ``model_parallel``; returns None if not even one group survives.
    """
    data = surviving_chips // model_parallel
    if data < 1:
        return None
    return data, model_parallel


class StepFailure(Exception):
    pass


def run_with_recovery(step_fn: Callable[[int], dict], *, start_step: int,
                      num_steps: int,
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      checkpoint_every: int = 100,
                      max_retries: int = 3,
                      watchdog: Optional[WatchdogPolicy] = None,
                      on_event: Optional[Callable[[str, dict], None]] = None
                      ) -> int:
    """The driver loop: run → checkpoint → (on failure) restore → resume.

    ``step_fn(step)`` raises StepFailure (or any exception) on a failed
    step.  Returns the final completed step.
    """
    watchdog = watchdog or WatchdogPolicy()
    emit = on_event or (lambda kind, info: None)
    step = start_step
    retries = 0
    while step < start_step + num_steps:
        t0 = time.monotonic()
        try:
            metrics = step_fn(step)
            dt = time.monotonic() - t0
            if watchdog.is_straggler(dt):
                emit("straggler", {"step": step, "time_s": dt,
                                   "deadline_s": watchdog.deadline_s})
            watchdog.record(dt)
            retries = 0
            if (step + 1) % checkpoint_every == 0:
                save_fn(step + 1)
                emit("checkpoint", {"step": step + 1})
            step += 1
        except Exception as e:                      # noqa: BLE001
            retries += 1
            emit("failure", {"step": step, "error": repr(e),
                             "retry": retries})
            if retries > max_retries:
                raise
            step = restore_fn()
            emit("restored", {"step": step})
    return step
