from repro.train.optimizer import AdamW, AdamWState, warmup_cosine, constant_lr
from repro.train.train_step import (make_train_step, shard_train_step,
                                    make_state_shardings, make_batch_shardings)
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    latest_step, prune_checkpoints)
from repro.train.fault_tolerance import (WatchdogPolicy, plan_remesh,
                                         run_with_recovery, StepFailure)
