"""AdamW + schedules, built from scratch (no optax in this environment).

State layout mirrors the parameter tree (same shapes, fp32 moments), so the
optimizer state inherits the parameter sharding rules verbatim — m/v for an
FSDP-sharded weight are FSDP-sharded, giving ZeRO-style optimizer sharding
for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    m: Any                     # fp32 tree
    v: Any                     # fp32 tree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        step = state.step + 1
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                             for g in jax.tree_util.tree_leaves(g32)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

        m = jax.tree_util.tree_map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g, state.m, g32)
        v = jax.tree_util.tree_map(
            lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g, state.v, g32)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:                        # decoupled WD on matrices
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), {
            "grad_norm": gnorm, "lr": lr}


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, cos)
    return schedule


def constant_lr(value: float) -> Callable:
    return lambda step: jnp.full((), value, jnp.float32)
