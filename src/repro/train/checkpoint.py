"""Checkpointing: atomic, shard-aware, elastically reshardable.

Layout (one directory per step):
    step_000123/
      MANIFEST.json        — tree structure, global shapes/dtypes, step meta
      shard_p{proc}.npz    — this process's locally-addressable shards

Properties needed at fleet scale, all implemented here:
  * **atomic**: writes go to ``step_X.tmp`` and are renamed only after fsync
    — a killed job never leaves a half checkpoint that restore would pick;
  * **parallel**: every process writes only its own addressable shards
    (single-process here, but addressable-shard iteration is the real API);
  * **elastic**: restore rebuilds global arrays from the manifest and then
    re-shards onto whatever mesh the *new* job brings up — data-axis size
    may differ from the writer's (node loss / elastic rescale);
  * **self-describing**: the manifest stores the pytree structure, so
    restore needs no model code to produce the tree skeleton.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/float8 with numpy's dtype system
import numpy as np


def _with_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """npz stores non-native dtypes (bfloat16, ...) as raw void; view back."""
    if arr.dtype.kind == "V":
        return arr.view(np.dtype(dtype_str))
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None,
                    process_index: int = 0) -> Path:
    """Write one atomic checkpoint.  Returns the final directory path."""
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp{process_index}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    items, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).__repr__(),
        "keys": [],
        "extra": extra or {},
    }
    arrays = {}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i:05d}"
        arrays[name] = arr
        manifest["keys"].append({
            "key": key, "name": name,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        })
    np.savez(tmp / f"shard_p{process_index}.npz", **arrays)
    with open(tmp / "MANIFEST.json", "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_????????")
             if p.is_dir()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
                       shardings=None, process_index: int = 0
                       ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of NamedShardings for the *current*
    mesh — this is the elastic-reshard path: arrays are materialised globally
    and re-placed under the new sharding regardless of how they were sharded
    at save time.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    data = np.load(d / f"shard_p{process_index}.npz")
    by_key = {e["key"]: _with_dtype(data[e["name"]], e["dtype"])
              for e in manifest["keys"]}

    items, treedef = _flatten_with_paths(tree_like)
    leaves = []
    for key, leaf in items:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want_shape}")
        leaves.append(arr)

    if shardings is not None:
        sh_items, _ = _flatten_with_paths(shardings)
        out = [jax.device_put(a, s) for a, (_, s) in zip(leaves, sh_items)]
    else:
        out = [jnp.asarray(a) for a in leaves]
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, manifest.get("extra", {})


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    base = Path(ckpt_dir)
    steps = sorted(p for p in base.glob("step_????????") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p)
