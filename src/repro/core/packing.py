"""Node Packing (paper Def. 13) via First-Fit-Decreasing.

Packs trie leaf nodes into as few physical partitions as possible subject to
the capacity constraint c.  FFD is the paper's choice: O(m log m), 1.5-OPT
worst case [20].  Oversized leaves (possible when the trie ran out of prefix
depth) get a dedicated partition each — capacity is a soft constraint (§V).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def ffd_pack(sizes: Sequence[float], capacity: float) -> Tuple[np.ndarray, int]:
    """First-Fit-Decreasing bin packing.

    Args:
      sizes: per-leaf estimated sizes.
      capacity: c.

    Returns:
      (assignment, num_bins): ``assignment[i]`` is the bin id of leaf i
      (bin ids are dense in [0, num_bins)).
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    n = sizes.shape[0]
    assignment = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return assignment, 0

    order = np.argsort(-sizes, kind="stable")       # decreasing
    bin_load: List[float] = []
    for i in order:
        s = float(sizes[i])
        placed = False
        for b, load in enumerate(bin_load):         # first fit
            if load + s <= capacity:
                bin_load[b] = load + s
                assignment[i] = b
                placed = True
                break
        if not placed:                              # open a new bin
            assignment[i] = len(bin_load)
            bin_load.append(s)
    return assignment, len(bin_load)
