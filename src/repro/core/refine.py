"""Localized record-level similarity — paper §VI (final refine stage).

Given the partitions + trie-node targets selected by the planner, load the
selected partitions, restrict to records belonging to the targeted trie
node(s) (interval test on the DFS tag — the paper's contiguous node clusters),
compute exact ED against the raw series, and rank for the final top-K.

Two execution paths:
  * ``refine``          — jnp path (oracle; default on CPU);
  * ``repro.kernels.l2_topk`` — Pallas kernel for the distance hot loop
    (invoked by passing ``use_kernel=True``; validated against this path).

The distributed variant (``refine_sharded``) is a shard_map over the data
axis: each device scans only its local partition shard, produces a local
top-k, and a single all-gather + merge yields the global answer — the TPU
analogue of the paper's scatter/gather over HDFS partitions.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.index import PartitionStore

_INF = jnp.float32(3.4e38)


def _masked_distances(store: PartitionStore, queries: jnp.ndarray,
                      sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                      sel_hi: jnp.ndarray, *, use_kernel: bool = False):
    """Squared ED of each query against records of its selected partitions.

    Args:
      store: partition store (P partitions × cap slots).
      queries: ``[Q, n]``.
      sel_part: ``[Q, MP]`` partition ids (−1 = unused slot).
      sel_lo / sel_hi: ``[Q, MP]`` DFS interval of the targeting trie node.

    Returns:
      (d2, gid): ``[Q, MP*cap]`` masked squared distances (masked = +inf) and
      the corresponding original record ids.
    """
    q2 = jnp.sum(queries * queries, axis=-1)                    # [Q]
    pid = jnp.maximum(sel_part, 0)                              # clamp pads
    rows = store.data[pid]                                      # [Q, MP, cap, n]
    rows2 = store.norms[pid]                                    # [Q, MP, cap]
    rdfs = store.rec_dfs[pid]
    rgid = store.rec_gid[pid]

    if use_kernel:
        from repro.kernels import ops as kernel_ops
        dots = kernel_ops.batched_query_dots(queries, rows)     # [Q, MP, cap]
    else:
        dots = jnp.einsum("qn,qmcn->qmc", queries, rows)
    d2 = jnp.maximum(q2[:, None, None] - 2.0 * dots + rows2, 0.0)

    valid = rgid >= 0
    in_node = (rdfs >= sel_lo[:, :, None]) & (rdfs < sel_hi[:, :, None])
    incl = valid & in_node & (sel_part >= 0)[:, :, None]
    # Dedupe: if two selected entries cover the same record (e.g. a node and
    # its ancestor were both selected), count it at the first entry only.
    # Key on (partition id, slot): identical across duplicate entries.
    same_pid = pid[:, :, None] == pid[:, None, :]               # [Q, MP, MP]
    earlier = jnp.tril(jnp.ones(same_pid.shape[-2:], bool), k=-1)
    # record included by an earlier entry of the same partition?
    incl_earlier = jnp.einsum("qec,qme->qmc",
                              incl.astype(jnp.float32),
                              (same_pid & earlier).astype(jnp.float32)) > 0
    incl = incl & ~incl_earlier

    q = queries.shape[0]
    d2 = jnp.where(incl, d2, _INF).reshape(q, -1)
    gid = jnp.where(incl, rgid, -1).reshape(q, -1)
    return d2, gid


def refine(store: PartitionStore, queries: jnp.ndarray, sel_part: jnp.ndarray,
           sel_lo: jnp.ndarray, sel_hi: jnp.ndarray, k: int,
           *, use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-ED top-k within the selected (partition, node) targets.

    Returns:
      (dist, gid): ``[Q, k]`` ascending ED (not squared) and record ids
      (−1 where fewer than k candidates existed).
    """
    d2, gid = _masked_distances(store, queries, sel_part, sel_lo, sel_hi,
                                use_kernel=use_kernel)
    neg, idx = jax.lax.top_k(-d2, k)
    top_gid = jnp.take_along_axis(gid, idx, axis=-1)
    dist = jnp.sqrt(jnp.maximum(-neg, 0.0))
    top_gid = jnp.where(-neg >= _INF, -1, top_gid)
    return dist, top_gid


def merge_topk(dist_a, gid_a, dist_b, gid_b, k: int):
    """Merge two top-k lists (used by the sharded all-gather reduction)."""
    dist = jnp.concatenate([dist_a, dist_b], axis=-1)
    gid = jnp.concatenate([gid_a, gid_b], axis=-1)
    neg, idx = jax.lax.top_k(-dist, k)
    return -neg, jnp.take_along_axis(gid, idx, axis=-1)


def refine_sharded(store: PartitionStore, queries: jnp.ndarray,
                   sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                   sel_hi: jnp.ndarray, k: int, *, mesh, data_axis: str = "data"):
    """Distributed refine: local masked scan + local top-k + all-gather merge.

    ``store`` must be sharded over partitions on ``data_axis`` (P → data);
    queries and the plan are replicated.  Partition ids inside ``sel_part``
    are global; each device matches them against its local pid range.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    p_total = store.num_partitions
    n_dev = mesh.shape[data_axis]
    per_dev = p_total // n_dev

    def local_fn(data, norms, rdfs, rgid, count, q, sp, lo, hi):
        dev = jax.lax.axis_index(data_axis)
        base = dev * per_dev
        local_store = PartitionStore(data=data, norms=norms, rec_dfs=rdfs,
                                     rec_gid=rgid, count=count)
        # global → local partition ids; out-of-range → -1 (skipped locally)
        sp_local = jnp.where((sp >= base) & (sp < base + per_dev),
                             sp - base, -1)
        dist, gid = refine(local_store, q, sp_local, lo, hi, k)
        dist_all = jax.lax.all_gather(dist, data_axis, axis=0)   # [D, Q, k]
        gid_all = jax.lax.all_gather(gid, data_axis, axis=0)
        d = dist_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        g = gid_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        d = jnp.where(g >= 0, d, _INF)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, jnp.take_along_axis(g, idx, axis=-1)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(data_axis), P(data_axis), P(data_axis), P(data_axis),
                  P(data_axis), P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_rep=False)
    return fn(store.data, store.norms, store.rec_dfs, store.rec_gid,
              store.count, queries, sel_part, sel_lo, sel_hi)
