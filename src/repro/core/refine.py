"""Localized record-level similarity — paper §VI (final refine stage).

Given the partitions + trie-node targets selected by the planner, load the
selected partitions, restrict to records belonging to the targeted trie
node(s) (interval test on the DFS tag — the paper's contiguous node clusters),
compute exact ED against the raw series, and rank for the final top-K.

Execution backends, unified behind :func:`dispatch_refine` (the only entry
point the query layer and the serving engine use):
  * ``refine``          — dense jnp path: gathers the selected rows, masks
    the full ``[Q, slots, cap]`` distance tensor, separate top-k.  The
    parity **oracle** and the CPU default;
  * ``use_kernel=True`` — the streaming fused Pallas kernel
    (``repro.kernels.refine_topk``): one pass per candidate block that
    applies the DFS-interval mask + segment-dedupe predicate inline and
    maintains an online per-query k-best accumulator in VMEM, never
    materializing the ``[Q, slots, cap]`` tensor (or the gathered rows —
    blocks are DMA'd straight from the store via scalar-prefetched
    partition ids).  Validated against the dense oracle; gids match
    exactly under the shared lowest-flat-index tie-break;
  * ``use_kernel=None`` (the default everywhere) — resolves via
    :func:`default_use_kernel`: fused kernel on accelerator backends,
    dense oracle on CPU (where the kernel runs in slow interpret mode);
  * ``refine_sharded``  — shard_map over the data axis: each device scans
    only its local partition shard, produces a local **fused** (or dense)
    top-k, and a single all-gather + merge yields the global answer — the
    TPU analogue of the paper's scatter/gather over HDFS partitions.
    Composes with ``use_kernel``; stores whose partition count is ragged
    over the mesh (``P % n_dev != 0``) are padded via
    ``repro.distributed.pad_store``.

Duplicate-coverage removal (a node and its ancestor both selected) is a
sorted-slot segmented scan: plan entries are sorted by partition id, and a
record is dropped when an earlier entry of the same partition already
included it — O(Q·MP·cap) instead of the former O(Q·MP²·cap) pairwise
einsum over entry pairs.  The fused kernel evaluates the identical
predicate per streamed block, so both backends drop the same records.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import PartitionStore

_INF = jnp.float32(3.4e38)

# Sentinel distance of a pad answer (gid = -1): both refine paths emit
# sqrt(_INF) for slots with fewer than k candidates, so consumers that merge
# top-k lists across calls (the fleet) seed their accumulators with this.
PAD_DIST = float(np.sqrt(np.float32(3.4e38)))


def default_use_kernel() -> bool:
    """Backend default for the refine implementation.

    Accelerator backends run the streaming fused kernel (the whole point of
    it — HBM-resident stores, no [Q, slots, cap] materialization); CPU runs
    the dense jnp oracle, where the kernel would only execute in slow
    Pallas interpret mode.
    """
    return jax.default_backend() == "tpu"


def resolve_use_kernel(use_kernel: Optional[bool]) -> bool:
    """``None`` → the backend default; explicit flags are honored as-is."""
    return default_use_kernel() if use_kernel is None else bool(use_kernel)


def _sort_by_partition(sel_part, sel_lo, sel_hi):
    """Stable-sort plan entries by partition id (pads first, ties by entry
    order) so duplicate coverage is detectable by a segmented scan."""
    order = jnp.argsort(sel_part, axis=-1, stable=True)
    take = lambda t: jnp.take_along_axis(t, order, axis=-1)
    return take(sel_part), take(sel_lo), take(sel_hi)


def _dedupe_segments(sel_part, incl):
    """Drop records already included by an earlier same-partition entry.

    ``sel_part`` must be sorted along the entry axis so equal partition ids
    form contiguous segments.  Within a segment, a slot is kept at the first
    entry whose node interval covers it: the exclusive running inclusion
    count since the segment start is zero.
    """
    mp = sel_part.shape[-1]
    pos = jnp.arange(mp)
    seg_new = jnp.concatenate(
        [jnp.ones_like(sel_part[:, :1], bool),
         sel_part[:, 1:] != sel_part[:, :-1]], axis=-1)
    seg_start = jax.lax.cummax(jnp.where(seg_new, pos[None, :], 0), axis=1)
    ex_cum = jnp.cumsum(incl.astype(jnp.int32), axis=1) - incl
    start_cum = jnp.take_along_axis(ex_cum, seg_start[:, :, None], axis=1)
    return incl & ((ex_cum - start_cum) == 0)


def _masked_distances(store: PartitionStore, queries: jnp.ndarray,
                      sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                      sel_hi: jnp.ndarray):
    """Squared ED of each query against records of its selected partitions.

    The dense formulation (gather + full distance tensor) — the parity
    oracle the fused kernel is validated against.

    Args:
      store: partition store (P partitions × cap slots).
      queries: ``[Q, n]``.
      sel_part: ``[Q, MP]`` partition ids (−1 = unused slot).
      sel_lo / sel_hi: ``[Q, MP]`` DFS interval of the targeting trie node.

    Returns:
      (d2, gid): ``[Q, MP*cap]`` masked squared distances (masked = +inf) and
      the corresponding original record ids.
    """
    sel_part, sel_lo, sel_hi = _sort_by_partition(sel_part, sel_lo, sel_hi)

    q2 = jnp.sum(queries * queries, axis=-1)                    # [Q]
    pid = jnp.maximum(sel_part, 0)                              # clamp pads
    rows = store.data[pid]                                      # [Q, MP, cap, n]
    rows2 = store.norms[pid]                                    # [Q, MP, cap]
    rdfs = store.rec_dfs[pid]
    rgid = store.rec_gid[pid]

    dots = jnp.einsum("qn,qmcn->qmc", queries, rows)
    d2 = jnp.maximum(q2[:, None, None] - 2.0 * dots + rows2, 0.0)

    valid = rgid >= 0
    in_node = (rdfs >= sel_lo[:, :, None]) & (rdfs < sel_hi[:, :, None])
    incl = valid & in_node & (sel_part >= 0)[:, :, None]
    incl = _dedupe_segments(sel_part, incl)

    q = queries.shape[0]
    d2 = jnp.where(incl, d2, _INF).reshape(q, -1)
    gid = jnp.where(incl, rgid, -1).reshape(q, -1)
    return d2, gid


def refine(store: PartitionStore, queries: jnp.ndarray, sel_part: jnp.ndarray,
           sel_lo: jnp.ndarray, sel_hi: jnp.ndarray, k: int,
           *, use_kernel: Optional[bool] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-ED top-k within the selected (partition, node) targets.

    ``use_kernel=True`` runs the streaming fused Pallas kernel (masked
    distance + online top-k in one pass, nothing of shape [Q, slots, cap]
    materialized); ``False`` the dense jnp oracle; ``None`` the backend
    default (:func:`default_use_kernel`).

    Returns:
      (dist, gid): ``[Q, k]`` ascending ED (not squared) and record ids
      (−1 where fewer than k candidates existed; their distance is the
      :data:`PAD_DIST` sentinel on both paths).
    """
    if resolve_use_kernel(use_kernel):
        from repro.kernels import ops as kernel_ops
        # the device-plan variant owns the partition sort the kernel's
        # scalar-prefetch grid requires, so plans coming straight off a
        # device planner (fleet fused pass) and host-built plans share it
        d2, gid = kernel_ops.fused_refine_topk_device_plan(
            store.data, store.norms, store.rec_dfs, store.rec_gid,
            queries, sel_part, sel_lo, sel_hi, k)
        # under-k slots keep the +inf/-1 accumulator init → PAD_DIST/-1,
        # the same sentinel convention as the dense branch below
        return jnp.sqrt(d2), jnp.where(d2 >= _INF, -1, gid)
    d2, gid = _masked_distances(store, queries, sel_part, sel_lo, sel_hi)
    if d2.shape[-1] < k:        # tiny store: fewer slots than answers asked
        tail = [(0, 0)] * (d2.ndim - 1) + [(0, k - d2.shape[-1])]
        d2 = jnp.pad(d2, tail, constant_values=_INF)
        gid = jnp.pad(gid, tail, constant_values=-1)
    neg, idx = jax.lax.top_k(-d2, k)
    top_gid = jnp.take_along_axis(gid, idx, axis=-1)
    dist = jnp.sqrt(jnp.maximum(-neg, 0.0))
    top_gid = jnp.where(-neg >= _INF, -1, top_gid)
    return dist, top_gid


def merge_topk(dist_a, gid_a, dist_b, gid_b, k: int, *, dedupe: bool = False):
    """Merge two per-query top-k lists into one ``[..., k]`` top-k.

    Args:
      dist_a / dist_b: ``[..., ka]`` / ``[..., kb]`` ascending distances
        (any matching leading batch shape; ka and kb may differ).
      gid_a / gid_b: matching record-id arrays (``-1`` = pad entry).
      k: output answer size.

    Returns:
      (dist ``[..., k]`` ascending, gid ``[..., k]``).  Ties break toward
      input a, then slot order (``jax.lax.top_k`` lowest-index rule) — the
      property the fleet's in-shard-order merge fold relies on for
      bit-identical host/mesh placements.

    Pad entries (``gid = -1``) must carry the :data:`PAD_DIST` sentinel so
    they lose to every real candidate; the sentinel propagates into the
    output wherever fewer than k real candidates exist across both inputs
    (merging a pure-pad list into anything is therefore the identity).

    ``dedupe=False`` (default) assumes the inputs hold disjoint record sets
    — the sharded all-gather reduction and the fleet's sealed shards satisfy
    this — and keeps duplicate gids if the caller violates it.
    ``dedupe=True`` keeps only the best-ranked copy of each gid (ties break
    toward input a, then slot order); it costs O(k²) pairwise compares, so
    reserve it for merges that can legitimately see the same record twice.

    Example — fusing two shards' answers (the second has only one real
    candidate; its pad slot carries the sentinel and loses every merge)::

        >>> import jax.numpy as jnp
        >>> d_a = jnp.asarray([[1.0, 3.0]])
        >>> g_a = jnp.asarray([[10, 11]])
        >>> d_b = jnp.asarray([[2.0, PAD_DIST]])
        >>> g_b = jnp.asarray([[20, -1]])
        >>> dist, gid = merge_topk(d_a, g_a, d_b, g_b, k=3)
        >>> gid.tolist()
        [[10, 20, 11]]
        >>> [round(float(x), 1) for x in dist[0]]
        [1.0, 2.0, 3.0]
    """
    dist = jnp.concatenate([dist_a, dist_b], axis=-1)
    gid = jnp.concatenate([gid_a, gid_b], axis=-1)
    if dedupe:
        # entry j dominates entry i when they carry the same real gid and j
        # ranks strictly better: smaller distance, or equal distance and an
        # earlier slot.  Dominated entries become pads before the top-k.
        same = (gid[..., :, None] == gid[..., None, :]) & \
            (gid[..., None, :] >= 0)
        d_i, d_j = dist[..., :, None], dist[..., None, :]
        n2 = dist.shape[-1]
        earlier = jnp.arange(n2)[None, :] < jnp.arange(n2)[:, None]  # j < i
        dominated = jnp.any(
            same & ((d_j < d_i) | ((d_j == d_i) & earlier)), axis=-1)
        dist = jnp.where(dominated, jnp.float32(PAD_DIST), dist)
        gid = jnp.where(dominated, -1, gid)
    if dist.shape[-1] < k:                   # fewer candidates than asked for
        tail = [(0, 0)] * (dist.ndim - 1) + [(0, k - dist.shape[-1])]
        dist = jnp.pad(dist, tail, constant_values=PAD_DIST)
        gid = jnp.pad(gid, tail, constant_values=-1)
    neg, idx = jax.lax.top_k(-dist, k)
    return -neg, jnp.take_along_axis(gid, idx, axis=-1)


def refine_sharded(store: PartitionStore, queries: jnp.ndarray,
                   sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                   sel_hi: jnp.ndarray, k: int, *, mesh,
                   data_axis: str = "data",
                   use_kernel: Optional[bool] = None):
    """Distributed refine: local masked scan + local top-k + all-gather merge.

    ``store`` must be sharded over partitions on ``data_axis`` (P → data);
    queries and the plan are replicated.  Partition ids inside ``sel_part``
    are global; each device matches them against its local pid range.  A
    ragged store (``P % n_dev != 0``) is padded with empty partitions first.
    With ``use_kernel`` (the accelerator default) each device runs the
    streaming fused kernel over its local shard, so the per-device top-k is
    produced without materializing any local distance tensor either.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    use_kernel = resolve_use_kernel(use_kernel)
    n_dev = mesh.shape[data_axis]
    if store.num_partitions % n_dev:
        from repro.distributed.store import shard_store
        store = shard_store(store, mesh, data_axis=data_axis)
    per_dev = store.num_partitions // n_dev

    def local_fn(data, norms, rdfs, rgid, count, q, sp, lo, hi):
        dev = jax.lax.axis_index(data_axis)
        base = dev * per_dev
        local_store = PartitionStore(data=data, norms=norms, rec_dfs=rdfs,
                                     rec_gid=rgid, count=count)
        # global → local partition ids; out-of-range → -1 (skipped locally)
        sp_local = jnp.where((sp >= base) & (sp < base + per_dev),
                             sp - base, -1)
        dist, gid = refine(local_store, q, sp_local, lo, hi, k,
                           use_kernel=use_kernel)
        dist_all = jax.lax.all_gather(dist, data_axis, axis=0)   # [D, Q, k]
        gid_all = jax.lax.all_gather(gid, data_axis, axis=0)
        d = dist_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        g = gid_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
        d = jnp.where(g >= 0, d, _INF)
        neg, idx = jax.lax.top_k(-d, k)
        g_top = jnp.take_along_axis(g, idx, axis=-1)
        # pad answers carry the same sentinel as the dense path (sqrt(_INF))
        return jnp.where(g_top >= 0, -neg, jnp.sqrt(_INF)), g_top

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(data_axis), P(data_axis), P(data_axis), P(data_axis),
                  P(data_axis), P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_rep=False)
    return fn(store.data, store.norms, store.rec_dfs, store.rec_gid,
              store.count, queries, sel_part, sel_lo, sel_hi)


def dispatch_refine(store: PartitionStore, queries: jnp.ndarray,
                    sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                    sel_hi: jnp.ndarray, k: int, *, mesh=None,
                    data_axis: str = "data",
                    use_kernel: Optional[bool] = None):
    """Single execution-dispatch layer for the whole query stack.

    Every consumer (``knn_query``, the serving engines, the fleet's exact
    scan) funnels through here, so backend selection lives in exactly one
    place.

    Args:
      store: PartitionStore — replicated, or sharded over ``data_axis``
        when ``mesh`` is given (``repro.distributed.shard_store``).
      queries: ``[Q, n]`` raw series.
      sel_part / sel_lo / sel_hi: ``[Q, MP]`` plan — global partition ids
        (``-1`` = unused slot) and the targeting node's DFS interval.
      k: answer size.
      mesh / data_axis: ``mesh=None`` (or a 1-device data axis) runs the
        single-device path; a multi-device mesh runs the ``refine_sharded``
        shard_map path (local top-k per device + all-gather merge).
      use_kernel: refine implementation on either path — ``True`` the
        streaming fused Pallas kernel, ``False`` the dense jnp oracle,
        ``None`` (default) the backend default via
        :func:`default_use_kernel`: fused on accelerators, dense on CPU.

    Returns:
      (dist, gid): ``[Q, k]`` ascending ED and record ids; rows with fewer
      than k candidates carry :data:`PAD_DIST` and ``gid = -1`` on every
      backend, so outputs merge safely via :func:`merge_topk`.
    """
    if mesh is not None and mesh.shape[data_axis] > 1:
        return refine_sharded(store, queries, sel_part, sel_lo, sel_hi, k,
                              mesh=mesh, data_axis=data_axis,
                              use_kernel=use_kernel)
    return refine(store, queries, sel_part, sel_lo, sel_hi, k,
                  use_kernel=use_kernel)
