"""CLIMBER core — the paper's contribution as composable JAX modules."""
from repro.core.paa import paa, znormalize
from repro.core.pivots import select_pivots
from repro.core.signatures import (compute_signatures, rank_signature,
                                   set_signature, set_onehot, decay_weights,
                                   weighted_onehot, pivot_distances)
from repro.core.distances import (euclidean, squared_l2_pairwise,
                                  overlap_distance, weight_distance,
                                  total_weight)
from repro.core.centroids import compute_centroids, CentroidSet
from repro.core.assignment import assign_groups, assignment_distances
from repro.core.trie import build_forest, TrieForest
from repro.core.packing import ffd_pack
from repro.core.traversal import TrieDevice, descend, route_records
from repro.core.index import ClimberIndex, PartitionStore, build_index, build_store
from repro.core.query import (QueryPlan, candidates_scanned, compact_plan,
                              default_slot_budget, get_planner, knn_query,
                              plan, plan_knn, plan_adaptive, plan_exhaustive,
                              plan_od_smallest, planner_names,
                              register_planner)
from repro.core.refine import (PAD_DIST, default_use_kernel, dispatch_refine,
                               refine, refine_sharded, merge_topk,
                               resolve_use_kernel)

__all__ = [
    "paa", "znormalize", "select_pivots", "compute_signatures",
    "rank_signature", "set_signature", "set_onehot", "decay_weights",
    "weighted_onehot", "pivot_distances", "euclidean", "squared_l2_pairwise",
    "overlap_distance", "weight_distance", "total_weight",
    "compute_centroids", "CentroidSet", "assign_groups",
    "assignment_distances", "build_forest", "TrieForest", "ffd_pack",
    "TrieDevice", "descend", "route_records", "ClimberIndex",
    "PartitionStore", "build_index", "build_store", "QueryPlan", "knn_query",
    "plan", "plan_knn", "plan_adaptive", "plan_exhaustive",
    "plan_od_smallest", "register_planner", "get_planner", "planner_names",
    "compact_plan", "default_slot_budget", "candidates_scanned",
    "dispatch_refine", "refine", "refine_sharded", "merge_topk", "PAD_DIST",
    "default_use_kernel", "resolve_use_kernel",
]
