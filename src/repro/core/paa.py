"""Piecewise Aggregate Approximation (PAA) — paper §IV-B Step 1.

PAA divides a length-n series into w equal segments and represents each
segment by its mean (Keogh et al. [35]).  This is the dimensionality-reduction
front of CLIMBER-FX.  The jnp implementation below is the reference path; the
Pallas kernel lives in ``repro.kernels.paa`` and is numerically identical.
"""
from __future__ import annotations

import jax.numpy as jnp


def paa(x: jnp.ndarray, segments: int) -> jnp.ndarray:
    """PAA transform.

    Args:
      x: ``[..., n]`` raw data series (n divisible by ``segments``).
      segments: w — the PAA word length.

    Returns:
      ``[..., w]`` segment means, same dtype as ``x`` promoted to float.
    """
    n = x.shape[-1]
    if n % segments != 0:
        raise ValueError(f"series length {n} not divisible by w={segments}")
    seg = n // segments
    x = x.reshape(x.shape[:-1] + (segments, seg))
    return jnp.mean(x, axis=-1)


def znormalize(x: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Z-normalise each series (standard preprocessing for data-series search)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / (sd + eps)
