"""P⁴ dual signature generation — paper §IV-B Step 2 (Def. 5/6).

Given PAA signatures and the fixed pivot set, each object receives:
  * ``p4_rank`` — the *rank-sensitive* signature P4→: ids of its m nearest
    pivots ordered by ascending distance (the pivot-permutation prefix).
  * ``p4_set``  — the *rank-insensitive* signature P4⇄: the same ids under a
    global (ascending-id ≡ lexicographic) order; semantically a set.

For vectorised distance computations the set signature is materialised as an
r-dim one-hot ("bitset") row, and the rank signature as a *weighted* one-hot
row carrying the decay weights of Def. 9 — both make OD/WD single matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pivot_distances(paa: jnp.ndarray, pivots: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances to every pivot.

    Args:
      paa:    ``[..., w]``.
      pivots: ``[r, w]``.
    Returns:
      ``[..., r]`` squared distances (monotone in ED — ranking-equivalent).
    """
    # |a-b|^2 = |a|^2 - 2ab + |b|^2 ; the -2ab term is the MXU-friendly matmul.
    a2 = jnp.sum(paa * paa, axis=-1, keepdims=True)
    b2 = jnp.sum(pivots * pivots, axis=-1)
    ab = paa @ pivots.T
    return jnp.maximum(a2 - 2.0 * ab + b2, 0.0)


def rank_signature(paa: jnp.ndarray, pivots: jnp.ndarray, m: int) -> jnp.ndarray:
    """P4→ (Def. 5): ids of the m nearest pivots, nearest first.  ``[..., m]``."""
    d = pivot_distances(paa, pivots)
    # top_k of negated distances == m smallest; ties break toward lower id,
    # which matches a deterministic sort on (distance, id).
    _, idx = jax.lax.top_k(-d, m)
    return idx.astype(jnp.int32)


def set_signature(p4_rank: jnp.ndarray) -> jnp.ndarray:
    """P4⇄ (Def. 6): lexicographic (ascending-id) ordering.  ``[..., m]``."""
    return jnp.sort(p4_rank, axis=-1)


def set_onehot(p4: jnp.ndarray, r: int, dtype=jnp.float32) -> jnp.ndarray:
    """Bitset form of a signature: ``[..., r]`` with 1 at member pivot ids.

    Works for either signature ordering (membership is order-free).
    """
    return jax.nn.one_hot(p4, r, dtype=dtype).sum(axis=-2)


def decay_weights(m: int, kind: str = "exp", lam: float = 0.5,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Pivot weights of Def. 9.

    exp:    W_i = λ^(i-1)                         (i = 1..m)
    linear: W_i = λ·(m-i+1) with λ = 1/m  →  [1, (m-1)/m, ..., 1/m]
    """
    i = jnp.arange(1, m + 1, dtype=dtype)
    if kind == "exp":
        w = lam ** (i - 1.0)
    elif kind == "linear":
        w = (m - i + 1.0) / m
    else:
        raise ValueError(f"unknown decay {kind!r}")
    return w.astype(dtype)


def weighted_onehot(p4_rank: jnp.ndarray, r: int, weights: jnp.ndarray) -> jnp.ndarray:
    """``[..., r]`` row with W_i at the i-th ranked pivot's id (Def. 9).

    This turns the Weight Distance (Def. 11) into a single matmul against the
    centroid bitset matrix.
    """
    oh = jax.nn.one_hot(p4_rank, r, dtype=weights.dtype)          # [..., m, r]
    return jnp.einsum("...mr,m->...r", oh, weights)


def compute_signatures(paa: jnp.ndarray, pivots: jnp.ndarray, m: int):
    """Convenience: (p4_rank, p4_set) for a batch of PAA signatures."""
    p4r = rank_signature(paa, pivots, m)
    return p4r, set_signature(p4r)
