"""CLIMBER query processing — paper §VI (Algorithm 3 + the Adaptive variant).

Planner outputs are static-shape selections so the whole query path jits:

  * ``plan_knn``       — CLIMBER-kNN (Algorithm 3): one best trie node, the
    partitions associated with it (Example 2 returns multiple partitions when
    the landing node is internal).
  * ``plan_adaptive``  — CLIMBER-kNN-Adaptive: memorises the top-T candidate
    groups and, per group, the landing node and its parent (the longest and
    2nd-longest best matches).  When the best node holds < K records it
    expands down the memorised ranking until the cumulative size covers K,
    capped at ``adaptive_factor`` × the partitions CLIMBER-kNN would touch
    (the paper's 2X / 4X variants).
  * ``plan_od_smallest`` — the §VII-C ablation: scan every partition of every
    group at the minimal OD (stop at Algorithm 3 line 6).

All ladders follow Algorithm 3's tie-breaks: OD → WD → PathLen (desc) →
node size (desc) → deterministic lowest id (paper: random among equals).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import assignment
from repro.core.refine import refine as _refine
from repro.core.index import ClimberIndex
from repro.core.traversal import descend

_BIG = jnp.float32(1e9)


class QueryPlan(NamedTuple):
    """Static-shape partition/node targets for a batch of queries."""

    sel_part: jnp.ndarray   # [Q, MP] partition ids, -1 padded
    sel_lo: jnp.ndarray     # [Q, MP] dfs interval lo of targeting node
    sel_hi: jnp.ndarray     # [Q, MP] dfs interval hi
    node: jnp.ndarray       # [Q] the Algorithm-3 landing node (best group)
    pathlen: jnp.ndarray    # [Q]

    def partitions_touched(self) -> jnp.ndarray:
        """#distinct partitions accessed per query (benchmark metric)."""
        sp = jnp.sort(self.sel_part, axis=-1)
        fresh = jnp.concatenate(
            [sp[:, :1] >= 0,
             (sp[:, 1:] != sp[:, :-1]) & (sp[:, 1:] >= 0)], axis=-1)
        return jnp.sum(fresh, axis=-1)


def _candidates(index: ClimberIndex, p4_rank_q: jnp.ndarray):
    """Top-T candidate groups by the (OD, WD) ladder + their trie descent."""
    cfg = index.cfg
    t = min(cfg.candidate_groups, index.num_groups - 1) or 1
    od, wd = assignment.assignment_distances(
        p4_rank_q, index.centroid_onehot, cfg.num_pivots,
        decay=cfg.decay, decay_lambda=cfg.decay_lambda)
    # lexicographic (od, wd): od is integral in [0, m]; wd bounded by TW < m+1.
    score = od * (cfg.prefix_len + 2.0) + wd
    neg, grp = jax.lax.top_k(-score, t)                        # [Q, T]
    cand_od = jnp.take_along_axis(od, grp, axis=-1)
    cand_wd = jnp.take_along_axis(wd, grp, axis=-1)

    node, pathlen, parent = descend(
        index.trie, p4_rank_q[:, None, :].repeat(t, axis=1), grp)
    size = index.trie.node_size[node]
    return grp, cand_od, cand_wd, node, pathlen, parent, size


def _rank_best(cand_od, cand_wd, pathlen, size, m: int):
    """Algorithm 3 lines 5–19 as one composite key; returns argbest [Q]."""
    # Groups not at the minimal OD are out; then minimal WD; then longest
    # path; then largest node.  Encode as a single score to argmin.
    min_od = jnp.min(cand_od, axis=-1, keepdims=True)
    min_wd = jnp.min(jnp.where(cand_od <= min_od + 0.5, cand_wd, _BIG),
                     axis=-1, keepdims=True)
    eligible = (cand_od <= min_od + 0.5) & (cand_wd <= min_wd + 1e-6)
    # among eligible: maximize (pathlen, size) → minimize negatives
    key = jnp.where(eligible,
                    -(pathlen.astype(jnp.float32) * 1e6 +
                      jnp.minimum(size, 1e5)),
                    _BIG)
    return jnp.argmin(key, axis=-1)                             # [Q]


def _node_targets(index: ClimberIndex, nodes: jnp.ndarray):
    """Partitions + dfs intervals of a batch of nodes.  [..., maxP]."""
    parts = index.trie.part_ids_pad[nodes]                      # [..., maxP]
    lo = index.trie.dfs_in[nodes][..., None] * jnp.ones_like(parts)
    hi = index.trie.dfs_out[nodes][..., None] * jnp.ones_like(parts)
    return parts, lo.astype(jnp.int32), hi.astype(jnp.int32)


def plan_knn(index: ClimberIndex, p4_rank_q: jnp.ndarray) -> QueryPlan:
    """CLIMBER-kNN (Algorithm 3)."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = _candidates(index, p4_rank_q)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)    # [Q]
    q = p4_rank_q.shape[0]
    rows = jnp.arange(q)
    node_star = node[rows, best]
    parts, lo, hi = _node_targets(index, node_star)
    return QueryPlan(sel_part=parts, sel_lo=lo, sel_hi=hi,
                     node=node_star, pathlen=pathlen[rows, best])


def plan_adaptive(index: ClimberIndex, p4_rank_q: jnp.ndarray) -> QueryPlan:
    """CLIMBER-kNN-Adaptive (paper §VI)."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = _candidates(index, p4_rank_q)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)
    q, t = grp.shape
    rows = jnp.arange(q)
    node_star = node[rows, best]
    pathlen_star = pathlen[rows, best]

    # Memorised entries: per group the landing node then its parent.
    ent_node = jnp.stack([node, parent], axis=-1).reshape(q, 2 * t)
    ent_od = jnp.repeat(od, 2, axis=-1)
    ent_wd = jnp.repeat(wd, 2, axis=-1)
    ent_path = jnp.stack([pathlen, jnp.maximum(pathlen - 1, 0)],
                         axis=-1).reshape(q, 2 * t)
    ent_size = index.trie.node_size[ent_node]

    # Quality order: (od, wd, -pathlen, -size); the winner ranks first by
    # construction.  Drop duplicate nodes (parent == node at roots, or the
    # same node reached from several ladders).
    order_key = (ent_od * (cfg.prefix_len + 2.0) + ent_wd) * 1e6 \
        - ent_path.astype(jnp.float32) * 1e3 \
        - jnp.minimum(ent_size, 999.0)
    # force the Algorithm-3 winner to rank strictly first
    is_star = ent_node == node_star[:, None]
    order_key = jnp.where(is_star, -_BIG, order_key)
    order = jnp.argsort(order_key, axis=-1)
    ent_node = jnp.take_along_axis(ent_node, order, axis=-1)
    ent_size = jnp.take_along_axis(ent_size, order, axis=-1)

    dup = jnp.cumsum(
        (ent_node[:, :, None] == ent_node[:, None, :]).astype(jnp.int32),
        axis=-1)
    first_occurrence = jnp.take_along_axis(
        dup, jnp.arange(2 * t)[None, :, None], axis=-1)[..., 0] == 1
    ent_size = jnp.where(first_occurrence, ent_size, 0.0)

    # Expansion rule (§VI): the adaptive algorithm memorises (a) all groups
    # tied at the smallest OD distance and (b) per group the longest/2nd-
    # longest matching nodes; it expands over them until the cumulative size
    # covers K.  The MaxNumPartitions-style cap below keeps the data touched
    # bounded at `adaptive_factor`× what CLIMBER-kNN reads.
    ent_od_sorted = jnp.take_along_axis(ent_od, order, axis=-1)
    min_od = jnp.min(ent_od_sorted, axis=-1, keepdims=True)
    od_tied = ent_od_sorted <= min_od + 0.5
    cum_before = jnp.cumsum(ent_size, axis=-1) - ent_size
    need = cum_before < float(cfg.k)
    selected = first_occurrence & (need | od_tied)
    selected = selected.at[:, 0].set(True)

    # Partition cap: adaptive_factor × the partitions CLIMBER-kNN touches.
    star_parts = index.trie.part_ids_pad[node_star]             # [Q, maxP]
    n_star_parts = jnp.sum(star_parts >= 0, axis=-1)
    cap = n_star_parts * cfg.adaptive_factor                    # [Q]

    parts, lo, hi = _node_targets(index, ent_node)              # [Q, 2T, maxP]
    sel3 = selected[:, :, None] & (parts >= 0)
    flat_parts = jnp.where(sel3, parts, -1).reshape(q, -1)
    flat_lo = lo.reshape(q, -1)
    flat_hi = hi.reshape(q, -1)
    # enforce the cap in entry order (first-node partitions always survive)
    live = flat_parts >= 0
    idx_within = jnp.cumsum(live.astype(jnp.int32), axis=-1) - 1
    keep = live & (idx_within < cap[:, None])
    flat_parts = jnp.where(keep, flat_parts, -1)
    return QueryPlan(sel_part=flat_parts, sel_lo=flat_lo, sel_hi=flat_hi,
                     node=node_star, pathlen=pathlen_star)


def plan_od_smallest(index: ClimberIndex, p4_rank_q: jnp.ndarray) -> QueryPlan:
    """OD-Smallest ablation (§VII-C): all partitions of all min-OD groups."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = _candidates(index, p4_rank_q)
    min_od = jnp.min(od, axis=-1, keepdims=True)
    sel_grp = od <= min_od + 0.5                                # [Q, T]
    roots = index.trie.group_root[grp]                          # [Q, T]
    parts, lo, hi = _node_targets(index, roots)                 # [Q, T, maxP]
    q = grp.shape[0]
    sel3 = sel_grp[:, :, None] & (parts >= 0)
    flat_parts = jnp.where(sel3, parts, -1).reshape(q, -1)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)
    rows = jnp.arange(q)
    return QueryPlan(sel_part=flat_parts,
                     sel_lo=lo.reshape(q, -1), sel_hi=hi.reshape(q, -1),
                     node=node[rows, best], pathlen=pathlen[rows, best])


def compact_plan(plan: QueryPlan, max_slots: int) -> QueryPlan:
    """Compress the plan's padded slot axis to ``max_slots``.

    Beyond-paper optimisation: the refine gather costs Q×slots×cap×n bytes
    regardless of how many slots are real; moving valid entries to the front
    and slicing bounds the gather by the *actual* partition budget instead
    of the static worst case (2T×maxP).  Entries beyond max_slots are
    dropped — by construction the adaptive cap keeps the real entry count
    below the budget, so this is lossless for the paper's defaults.
    """
    order = jnp.argsort((plan.sel_part < 0).astype(jnp.int32), axis=-1,
                        stable=True)
    take = lambda t: jnp.take_along_axis(t, order, axis=-1)[:, :max_slots]
    return QueryPlan(sel_part=take(plan.sel_part), sel_lo=take(plan.sel_lo),
                     sel_hi=take(plan.sel_hi), node=plan.node,
                     pathlen=plan.pathlen)


_PLANNERS = {
    "knn": plan_knn,
    "adaptive": plan_adaptive,
    "od_smallest": plan_od_smallest,
}


def knn_query(index: ClimberIndex, queries: jnp.ndarray, k: int = 0,
              *, variant: str = "adaptive", use_kernel: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray, QueryPlan]:
    """End-to-end approximate kNN (feature extraction → plan → exact refine).

    Args:
      queries: ``[Q, n]`` raw query series.
      k: answer size (defaults to cfg.k).
      variant: "knn" | "adaptive" | "od_smallest".

    Returns:
      (dist, gid, plan): ``[Q, k]`` ED + original record ids (−1 pad).
    """
    k = k or index.cfg.k
    p4r_q, _ = index.featurize(queries)
    plan = _PLANNERS[variant](index, p4r_q)
    dist, gid = _refine(index.store, queries, plan.sel_part,
                                  plan.sel_lo, plan.sel_hi, k,
                                  use_kernel=use_kernel)
    return dist, gid, plan
