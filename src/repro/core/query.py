"""CLIMBER query processing — paper §VI (Algorithm 3 + the Adaptive variant).

Planner outputs are static-shape selections so the whole query path jits:

  * ``plan_knn``       — CLIMBER-kNN (Algorithm 3): one best trie node, the
    partitions associated with it (Example 2 returns multiple partitions when
    the landing node is internal).
  * ``plan_adaptive``  — CLIMBER-kNN-Adaptive: memorises the top-T candidate
    groups and, per group, the landing node and its parent (the longest and
    2nd-longest best matches).  When the best node holds < K records it
    expands down the memorised ranking until the cumulative size covers K,
    capped at ``adaptive_factor`` × the partitions CLIMBER-kNN would touch
    (the paper's 2X / 4X variants).
  * ``plan_od_smallest`` — the §VII-C ablation: scan every partition of every
    group at the minimal OD (stop at Algorithm 3 line 6).

All ladders follow Algorithm 3's tie-breaks: OD → WD → PathLen (desc) →
node size (desc) → deterministic lowest id (paper: random among equals).

Public planning API — registry + budget
---------------------------------------

Planners live in a registry keyed by variant name (:func:`register_planner`
/ :func:`get_planner`; the three paper variants above are pre-registered,
and e.g. the serving engine resolves variants purely by name).  The single
public planning entry point is :func:`plan`, which runs the named planner
and then **compacts** the plan to a static slot budget via
:func:`compact_plan`: valid entries are moved to the front of the padded
slot axis and the axis is sliced to the budget.  The default budget
(:func:`default_slot_budget`) is the tightest bound that is provably
lossless for the variant — e.g. the adaptive planner caps the partitions it
reads at ``adaptive_factor ×`` what CLIMBER-kNN touches, so its budget is
``min(2·T·maxP, maxP·adaptive_factor)`` while its raw plan is ``2·T·maxP``
wide.  The refine gather costs Q×slots×cap×n bytes regardless of how many
slots are real, so the budget — not the static worst case — is what scales
memory.  Override with ``ClimberConfig.query_max_slots`` or the
``max_slots=`` argument (smaller budgets trade recall for memory).

:func:`knn_query` composes featurize → :func:`plan` →
:func:`repro.core.refine.dispatch_refine`, so a ``mesh=`` argument is all it
takes to execute the refine stage sharded over the data axis.

Device-resident planning
------------------------

Every planner also runs *inside* a traced device program against a padded
shard skeleton (the fleet's stacked-trie mesh planner,
``repro.fleet.device_plan``).  The static shapes there are fleet-wide
maxima, so the planner receives a :class:`ShardPlanContext` carrying the
shard's *real* (traced) group/candidate/partition counts next to the padded
static widths; candidate columns beyond the real counts are masked to the
``_BIG`` sentinel before any top-k / argmin, which keeps the device plan's
live entries identical (values and order) to the host planner's — the
bit-identity contract the mesh fleet path is tested against.  Planners that
support the device path are registered in a parallel registry
(:func:`register_device_planner` / :func:`get_device_planner`); the four
built-ins all do.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import assignment
from repro.core.refine import dispatch_refine
from repro.core.index import ClimberIndex, PartitionStore
from repro.core.traversal import descend
from repro.utils.config import ClimberConfig

_BIG = jnp.float32(1e9)


class ShardPlanContext(NamedTuple):
    """Real-vs-padded shape context for planning inside a device program.

    The fleet's stacked-trie planner pads every shard skeleton to fleet-wide
    maxima so one jitted pass covers all shards; the planner then needs the
    shard's *real* counts (traced scalars) next to the padded static widths
    to mask the padding out before any top-k / arg-reduction.  ``None`` ctx
    (the host path) means real == static and no masking is needed.
    """

    num_groups: jnp.ndarray       # [] traced — real centroid rows (incl. 0)
    num_candidates: jnp.ndarray   # [] traced — real T for this shard
    num_partitions: jnp.ndarray   # [] traced — real partition count
    t_static: int                 # padded candidate width (top_k size)
    p_static: int                 # padded partition width (exhaustive plans)


class QueryPlan(NamedTuple):
    """Static-shape partition/node targets for a batch of queries."""

    sel_part: jnp.ndarray   # [Q, MP] partition ids, -1 padded
    sel_lo: jnp.ndarray     # [Q, MP] dfs interval lo of targeting node
    sel_hi: jnp.ndarray     # [Q, MP] dfs interval hi
    node: jnp.ndarray       # [Q] the Algorithm-3 landing node (best group)
    pathlen: jnp.ndarray    # [Q]

    def partitions_touched(self) -> jnp.ndarray:
        """#distinct partitions accessed per query (benchmark metric)."""
        sp = jnp.sort(self.sel_part, axis=-1)
        return jnp.sum(_first_occurrence_mask(sp), axis=-1)


def _first_occurrence_mask(sp_sorted: jnp.ndarray) -> jnp.ndarray:
    """Mask of the first occurrence of each distinct non-pad id along the
    sorted slot axis (shared by the distinct-partition metrics)."""
    return jnp.concatenate(
        [sp_sorted[:, :1] >= 0,
         (sp_sorted[:, 1:] != sp_sorted[:, :-1]) & (sp_sorted[:, 1:] >= 0)],
        axis=-1)


def _num_candidates(index: ClimberIndex) -> int:
    """T — candidate groups actually retained (static, bounded by #groups)."""
    return min(index.cfg.candidate_groups, index.num_groups - 1) or 1


def candidates_scanned(plan: QueryPlan, store: PartitionStore) -> jnp.ndarray:
    """#records resident in the distinct partitions a query reads.

    The per-query scan cost of the refine stage (serving-engine metric);
    counts each selected partition once even when several plan entries
    target different nodes of the same partition.
    """
    sp = jnp.sort(plan.sel_part, axis=-1)
    cnt = store.count[jnp.maximum(sp, 0)]
    return jnp.sum(jnp.where(_first_occurrence_mask(sp), cnt, 0), axis=-1)


def _candidates(index: ClimberIndex, p4_rank_q: jnp.ndarray,
                ctx: Optional[ShardPlanContext] = None):
    """Top-T candidate groups by the (OD, WD) ladder + their trie descent.

    With ``ctx`` (device path over a padded skeleton) the centroid columns
    beyond the shard's real group count are masked to ``_BIG`` before the
    top-k, and candidate slots beyond the real T are masked afterwards —
    ``jax.lax.top_k``'s lowest-index tie-break then makes the first
    ``ctx.num_candidates`` picks identical to the host planner's (padding
    columns tie with the column-0 fallback but lose on index), so every
    downstream arg-reduction sees the host values where it matters.
    """
    cfg = index.cfg
    t = ctx.t_static if ctx is not None else _num_candidates(index)
    od, wd = assignment.assignment_distances(
        p4_rank_q, index.centroid_onehot, cfg.num_pivots,
        decay=cfg.decay, decay_lambda=cfg.decay_lambda)
    if ctx is not None:
        pad_col = jnp.arange(od.shape[-1]) >= ctx.num_groups   # [G_pad]
        od = jnp.where(pad_col[None, :], _BIG, od)
        wd = jnp.where(pad_col[None, :], _BIG, wd)
    # lexicographic (od, wd): od is integral in [0, m]; wd bounded by TW < m+1.
    score = od * (cfg.prefix_len + 2.0) + wd
    neg, grp = jax.lax.top_k(-score, t)                        # [Q, T]
    cand_od = jnp.take_along_axis(od, grp, axis=-1)
    cand_wd = jnp.take_along_axis(wd, grp, axis=-1)

    node, pathlen, parent = descend(
        index.trie, p4_rank_q[:, None, :].repeat(t, axis=1), grp)
    size = index.trie.node_size[node]
    if ctx is not None:
        valid = jnp.arange(t) < ctx.num_candidates             # [T]
        cand_od = jnp.where(valid[None, :], cand_od, _BIG)
        cand_wd = jnp.where(valid[None, :], cand_wd, _BIG)
        size = jnp.where(valid[None, :], size, 0.0)
    return grp, cand_od, cand_wd, node, pathlen, parent, size


def _rank_best(cand_od, cand_wd, pathlen, size, m: int):
    """Algorithm 3 lines 5–19 as one composite key; returns argbest [Q]."""
    # Groups not at the minimal OD are out; then minimal WD; then longest
    # path; then largest node.  Encode as a single score to argmin.
    min_od = jnp.min(cand_od, axis=-1, keepdims=True)
    min_wd = jnp.min(jnp.where(cand_od <= min_od + 0.5, cand_wd, _BIG),
                     axis=-1, keepdims=True)
    eligible = (cand_od <= min_od + 0.5) & (cand_wd <= min_wd + 1e-6)
    # among eligible: maximize (pathlen, size) → minimize negatives
    key = jnp.where(eligible,
                    -(pathlen.astype(jnp.float32) * 1e6 +
                      jnp.minimum(size, 1e5)),
                    _BIG)
    return jnp.argmin(key, axis=-1)                             # [Q]


def _node_targets(index: ClimberIndex, nodes: jnp.ndarray):
    """Partitions + dfs intervals of a batch of nodes.  [..., maxP]."""
    parts = index.trie.part_ids_pad[nodes]                      # [..., maxP]
    lo = index.trie.dfs_in[nodes][..., None] * jnp.ones_like(parts)
    hi = index.trie.dfs_out[nodes][..., None] * jnp.ones_like(parts)
    return parts, lo.astype(jnp.int32), hi.astype(jnp.int32)


def plan_knn(index: ClimberIndex, p4_rank_q: jnp.ndarray,
             ctx: Optional[ShardPlanContext] = None) -> QueryPlan:
    """CLIMBER-kNN (Algorithm 3)."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = \
        _candidates(index, p4_rank_q, ctx)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)    # [Q]
    q = p4_rank_q.shape[0]
    rows = jnp.arange(q)
    node_star = node[rows, best]
    parts, lo, hi = _node_targets(index, node_star)
    return QueryPlan(sel_part=parts, sel_lo=lo, sel_hi=hi,
                     node=node_star, pathlen=pathlen[rows, best])


def plan_adaptive(index: ClimberIndex, p4_rank_q: jnp.ndarray,
                  ctx: Optional[ShardPlanContext] = None) -> QueryPlan:
    """CLIMBER-kNN-Adaptive (paper §VI)."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = \
        _candidates(index, p4_rank_q, ctx)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)
    q, t = grp.shape
    rows = jnp.arange(q)
    node_star = node[rows, best]
    pathlen_star = pathlen[rows, best]

    # Memorised entries: per group the landing node then its parent.
    ent_node = jnp.stack([node, parent], axis=-1).reshape(q, 2 * t)
    ent_od = jnp.repeat(od, 2, axis=-1)
    ent_wd = jnp.repeat(wd, 2, axis=-1)
    ent_path = jnp.stack([pathlen, jnp.maximum(pathlen - 1, 0)],
                         axis=-1).reshape(q, 2 * t)
    ent_size = index.trie.node_size[ent_node]

    # Quality order: (od, wd, -pathlen, -size); the winner ranks first by
    # construction.  Drop duplicate nodes (parent == node at roots, or the
    # same node reached from several ladders).
    order_key = (ent_od * (cfg.prefix_len + 2.0) + ent_wd) * 1e6 \
        - ent_path.astype(jnp.float32) * 1e3 \
        - jnp.minimum(ent_size, 999.0)
    # force the Algorithm-3 winner to rank strictly first
    is_star = ent_node == node_star[:, None]
    order_key = jnp.where(is_star, -_BIG, order_key)
    order = jnp.argsort(order_key, axis=-1)
    ent_node = jnp.take_along_axis(ent_node, order, axis=-1)
    ent_size = jnp.take_along_axis(ent_size, order, axis=-1)

    dup = jnp.cumsum(
        (ent_node[:, :, None] == ent_node[:, None, :]).astype(jnp.int32),
        axis=-1)
    first_occurrence = jnp.take_along_axis(
        dup, jnp.arange(2 * t)[None, :, None], axis=-1)[..., 0] == 1
    ent_size = jnp.where(first_occurrence, ent_size, 0.0)
    if ctx is not None:
        # device path: padded candidate slots can land on the *real*
        # fallback group 0 (top_k fills the tail with the _BIG-tied
        # columns, lowest index first) — the host planner never memorises
        # them, so they must not be expandable or count toward coverage
        ent_valid = jnp.broadcast_to(
            jnp.repeat(jnp.arange(t) < ctx.num_candidates, 2)[None, :],
            ent_node.shape)
        ent_valid = jnp.take_along_axis(ent_valid, order, axis=-1)
        ent_size = jnp.where(ent_valid, ent_size, 0.0)

    # Expansion rule (§VI): the adaptive algorithm memorises (a) all groups
    # tied at the smallest OD distance and (b) per group the longest/2nd-
    # longest matching nodes; it expands over them until the cumulative size
    # covers K.  The MaxNumPartitions-style cap below keeps the data touched
    # bounded at `adaptive_factor`× what CLIMBER-kNN reads.
    ent_od_sorted = jnp.take_along_axis(ent_od, order, axis=-1)
    min_od = jnp.min(ent_od_sorted, axis=-1, keepdims=True)
    od_tied = ent_od_sorted <= min_od + 0.5
    cum_before = jnp.cumsum(ent_size, axis=-1) - ent_size
    need = cum_before < float(cfg.k)
    selected = first_occurrence & (need | od_tied)
    if ctx is not None:
        selected = selected & ent_valid
    selected = selected.at[:, 0].set(True)

    # Partition cap: adaptive_factor × the partitions CLIMBER-kNN touches.
    star_parts = index.trie.part_ids_pad[node_star]             # [Q, maxP]
    n_star_parts = jnp.sum(star_parts >= 0, axis=-1)
    cap = n_star_parts * cfg.adaptive_factor                    # [Q]

    parts, lo, hi = _node_targets(index, ent_node)              # [Q, 2T, maxP]
    sel3 = selected[:, :, None] & (parts >= 0)
    flat_parts = jnp.where(sel3, parts, -1).reshape(q, -1)
    flat_lo = lo.reshape(q, -1)
    flat_hi = hi.reshape(q, -1)
    # enforce the cap in entry order (first-node partitions always survive)
    live = flat_parts >= 0
    idx_within = jnp.cumsum(live.astype(jnp.int32), axis=-1) - 1
    keep = live & (idx_within < cap[:, None])
    flat_parts = jnp.where(keep, flat_parts, -1)
    return QueryPlan(sel_part=flat_parts, sel_lo=flat_lo, sel_hi=flat_hi,
                     node=node_star, pathlen=pathlen_star)


def exhaustive_selection(num_partitions: int, q: int):
    """(sel_part, sel_lo, sel_hi) selecting every record of every partition.

    The one place the scan-everything convention lives (full partition
    range, DFS interval [0, int32 max) covering every node); shared by
    :func:`plan_exhaustive` and the fleet's fused full-scan fallback.
    """
    parts = jnp.broadcast_to(
        jnp.arange(num_partitions, dtype=jnp.int32)[None, :],
        (q, num_partitions))
    lo = jnp.zeros((q, num_partitions), jnp.int32)
    hi = jnp.full((q, num_partitions), jnp.iinfo(jnp.int32).max, jnp.int32)
    return parts, lo, hi


def plan_exhaustive(index: ClimberIndex, p4_rank_q: jnp.ndarray,
                    ctx: Optional[ShardPlanContext] = None) -> QueryPlan:
    """Lossless fallback: scan every partition of every group (exact kNN).

    Selects all P partitions with a DFS interval covering every node, so the
    refine stage computes exact ED against the whole store — the answer
    equals brute-force kNN over the indexed data.  This is the fleet's
    exhaustive fan-out unit and the recall oracle for routing audits; it is
    never the serving default (it reads everything).
    """
    q = p4_rank_q.shape[0]
    if ctx is not None:
        parts, lo, hi = exhaustive_selection(ctx.p_static, q)
        parts = jnp.where(parts < ctx.num_partitions, parts, -1)
    else:
        parts, lo, hi = exhaustive_selection(index.store.num_partitions, q)
    zero = jnp.zeros((q,), jnp.int32)
    return QueryPlan(sel_part=parts, sel_lo=lo, sel_hi=hi,
                     node=zero, pathlen=zero)


def plan_od_smallest(index: ClimberIndex, p4_rank_q: jnp.ndarray,
                     ctx: Optional[ShardPlanContext] = None) -> QueryPlan:
    """OD-Smallest ablation (§VII-C): all partitions of all min-OD groups."""
    cfg = index.cfg
    grp, od, wd, node, pathlen, parent, size = \
        _candidates(index, p4_rank_q, ctx)
    min_od = jnp.min(od, axis=-1, keepdims=True)
    sel_grp = od <= min_od + 0.5                                # [Q, T]
    roots = index.trie.group_root[grp]                          # [Q, T]
    parts, lo, hi = _node_targets(index, roots)                 # [Q, T, maxP]
    q = grp.shape[0]
    sel3 = sel_grp[:, :, None] & (parts >= 0)
    flat_parts = jnp.where(sel3, parts, -1).reshape(q, -1)
    best = _rank_best(od, wd, pathlen, size, cfg.prefix_len)
    rows = jnp.arange(q)
    return QueryPlan(sel_part=flat_parts,
                     sel_lo=lo.reshape(q, -1), sel_hi=hi.reshape(q, -1),
                     node=node[rows, best], pathlen=pathlen[rows, best])


def compact_plan(plan: QueryPlan, max_slots: int) -> QueryPlan:
    """Compress the plan's padded slot axis to ``max_slots``.

    Beyond-paper optimisation: the refine gather costs Q×slots×cap×n bytes
    regardless of how many slots are real; moving valid entries to the front
    and slicing bounds the gather by the *actual* partition budget instead
    of the static worst case (2T×maxP).  Entries beyond max_slots are
    dropped — by construction the adaptive cap keeps the real entry count
    below the budget, so this is lossless for the paper's defaults.
    """
    order = jnp.argsort((plan.sel_part < 0).astype(jnp.int32), axis=-1,
                        stable=True)
    take = lambda t: jnp.take_along_axis(t, order, axis=-1)[:, :max_slots]
    return QueryPlan(sel_part=take(plan.sel_part), sel_lo=take(plan.sel_lo),
                     sel_hi=take(plan.sel_hi), node=plan.node,
                     pathlen=plan.pathlen)


# ----------------------------------------------------------------------
# Planner registry + budgeted planning (the public planning API)
# ----------------------------------------------------------------------
Planner = Callable[[ClimberIndex, jnp.ndarray], QueryPlan]

_PLANNERS: Dict[str, Planner] = {}


def register_planner(name: str, fn: Optional[Planner] = None):
    """Register a planner under ``name`` (usable as a decorator).

    Planners map ``(index, p4_rank_q [Q, m]) -> QueryPlan`` and become
    addressable by every consumer that takes a ``variant`` string
    (:func:`plan`, :func:`knn_query`, the serving engine, the benchmarks).
    """
    if fn is None:
        return partial(register_planner, name)
    _PLANNERS[name] = fn
    return fn


def get_planner(name: str) -> Planner:
    try:
        return _PLANNERS[name]
    except KeyError:
        raise KeyError(f"unknown planner variant {name!r}; "
                       f"registered: {sorted(_PLANNERS)}") from None


def planner_names() -> Tuple[str, ...]:
    return tuple(sorted(_PLANNERS))


register_planner("knn", plan_knn)
register_planner("adaptive", plan_adaptive)
register_planner("od_smallest", plan_od_smallest)
register_planner("exhaustive", plan_exhaustive)


# -- device variants ----------------------------------------------------
# A device planner has the same signature plus a mandatory
# ShardPlanContext: ``(index_view, p4_rank_q, ctx) -> QueryPlan``.  It must
# be traceable against a *padded* skeleton (static shapes = fleet maxima,
# real counts in ctx) and produce the host planner's live entries in the
# same order — that is what lets the fleet's fused mesh pass
# (``repro.fleet.device_plan`` / ``MeshFleetPlacement.query``) stay
# bit-identical to the host-loop oracle.  User-registered host planners
# without a device variant simply fall back to host planning under mesh
# placement.
DevicePlanner = Callable[..., QueryPlan]

_DEVICE_PLANNERS: Dict[str, DevicePlanner] = {}


def register_device_planner(name: str, fn: Optional[DevicePlanner] = None):
    """Register the device (padded-skeleton) variant of planner ``name``."""
    if fn is None:
        return partial(register_device_planner, name)
    _DEVICE_PLANNERS[name] = fn
    return fn


def get_device_planner(name: str) -> Optional[DevicePlanner]:
    """Device variant of ``name``, or None (→ host-planning fallback)."""
    return _DEVICE_PLANNERS.get(name)


def device_planner_names() -> Tuple[str, ...]:
    return tuple(sorted(_DEVICE_PLANNERS))


# the four built-ins are ctx-aware host planners: same function, both paths
register_device_planner("knn", plan_knn)
register_device_planner("adaptive", plan_adaptive)
register_device_planner("od_smallest", plan_od_smallest)
register_device_planner("exhaustive", plan_exhaustive)


# -- recall-targeted planning -------------------------------------------
def _with_cfg(index, cfg: ClimberConfig):
    """The same index/view with ``cfg`` swapped in.

    Host indexes are dataclasses; the mesh path hands planners a
    ``repro.fleet.device_plan.ShardView`` (a ``__slots__`` class), which is
    rebuilt field-by-field instead.
    """
    import dataclasses as _dc
    if _dc.is_dataclass(index):
        return _dc.replace(index, cfg=cfg)
    return type(index)(cfg, index.centroid_onehot, index.trie)


def make_recall_target_planner(spend_factor: float) -> Planner:
    """An adaptive-planner variant that spends ``spend_factor`` × more.

    ``plan_adaptive`` expands memorised trie entries until their cumulative
    size covers ``cfg.k`` records, bounded by ``adaptive_factor`` × the
    partitions CLIMBER-kNN touches.  Scaling both knobs by ``spend_factor``
    widens the coverage requirement *and* the cap together, so predicted
    recall rises smoothly with spend (``repro.eval.target`` chooses the
    factor from the live ``fleet.partitions_touched`` histogram against a
    calibrated partitions→recall curve).  ``spend_factor == 1`` is
    bit-identical to ``plan_adaptive``.

    The returned planner is ctx-aware (same function for host and device
    registration) and carries ``spend_factor`` as an attribute.
    """
    if spend_factor < 1.0:
        raise ValueError(f"spend_factor must be >= 1, got {spend_factor}")

    def planner(index, p4_rank_q: jnp.ndarray,
                ctx: Optional[ShardPlanContext] = None) -> QueryPlan:
        cfg = index.cfg
        if spend_factor == 1.0:
            return plan_adaptive(index, p4_rank_q, ctx)
        boosted = cfg.replace(
            k=int(math.ceil(cfg.k * spend_factor)),
            adaptive_factor=int(math.ceil(cfg.adaptive_factor
                                          * spend_factor)))
        return plan_adaptive(_with_cfg(index, boosted), p4_rank_q, ctx)

    planner.spend_factor = spend_factor
    return planner


def register_recall_target(spend_factor: float,
                           name: str = "recall_target") -> Planner:
    """Register a recall-targeted variant under ``name`` (host + device).

    Re-registering the same name with a new factor replaces it — the fleet
    must invalidate its plan caches afterwards (``IndexFleet`` keys cached
    plans on the placement epoch; ``repro.eval.target.install_recall_target``
    does the bump).
    """
    planner = make_recall_target_planner(spend_factor)
    register_planner(name, planner)
    register_device_planner(name, planner)
    return planner


def default_slot_budget(index: ClimberIndex,
                        variant: str) -> Optional[int]:
    """Tightest slot budget that is lossless for ``variant``'s plans.

    * ``knn`` emits one node's partitions: ``maxP`` slots, all potentially
      real — no compaction win.
    * ``adaptive`` emits ``2·T·maxP`` padded slots but caps the *live*
      entries per query at ``adaptive_factor ×`` the partitions CLIMBER-kNN
      touches, itself ≤ ``maxP``.
    * ``od_smallest`` deliberately scans all partitions of every min-OD
      group: no bound tighter than its full width.

    Unknown (user-registered) variants return ``None`` — no lossless bound
    is knowable for them, so by default their plans are not compacted.
    """
    cfg = index.cfg
    max_p = int(index.trie.part_ids_pad.shape[-1])
    t = _num_candidates(index)
    if variant == "knn":
        return max_p
    if variant == "adaptive":
        return min(2 * t * max_p, max_p * cfg.adaptive_factor)
    if variant == "od_smallest":
        return t * max_p
    if variant == "exhaustive":
        return index.store.num_partitions
    return None


def plan(index: ClimberIndex, p4_rank_q: jnp.ndarray, *,
         variant: str = "adaptive",
         max_slots: Optional[int] = None) -> QueryPlan:
    """Run the registered planner and compact to a static slot budget.

    ``max_slots`` resolution: explicit argument → ``cfg.query_max_slots`` →
    :func:`default_slot_budget` (lossless; ``None`` for user-registered
    variants, whose plans are then left uncompacted).  Compaction only ever
    shrinks the slot axis; a budget at or above the plan width is a no-op.
    """
    qp = get_planner(variant)(index, p4_rank_q)
    budget = max_slots if max_slots is not None \
        else index.cfg.query_max_slots
    if budget is None:
        budget = default_slot_budget(index, variant)
    if budget is not None and budget < qp.sel_part.shape[-1]:
        qp = compact_plan(qp, budget)
    return qp


def knn_query(index: ClimberIndex, queries: jnp.ndarray, k: int = 0,
              *, variant: str = "adaptive",
              use_kernel: Optional[bool] = None,
              mesh=None, data_axis: str = "data",
              max_slots: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray, QueryPlan]:
    """End-to-end approximate kNN (feature extraction → plan → exact refine).

    Args:
      queries: ``[Q, n]`` raw query series.
      k: answer size (defaults to cfg.k).
      variant: any registered planner name ("knn" | "adaptive" |
        "od_smallest" out of the box).
      use_kernel: refine implementation — True the streaming fused Pallas
        kernel, False the dense jnp oracle, None (default) the backend
        default (fused on accelerators, dense on CPU).
      mesh / data_axis: execute refine sharded over the mesh's data axis
        (the store must be laid out via ``repro.distributed.shard_store``;
        a ragged partition count is padded automatically).
      max_slots: static slot budget for plan compaction (see :func:`plan`).

    Returns:
      (dist, gid, plan): ``dist [Q, k]`` ascending ED; ``gid [Q, k]``
      original record row ids, ``-1`` where fewer than k candidates
      existed (those slots carry the :data:`repro.core.refine.PAD_DIST`
      sentinel in ``dist``, so per-call outputs fuse safely through
      :func:`repro.core.refine.merge_topk`); and the executed QueryPlan
      (for ``partitions_touched`` / ``candidates_scanned`` metrics).
    """
    k = k or index.cfg.k
    p4r_q, _ = index.featurize(queries)
    qp = plan(index, p4r_q, variant=variant, max_slots=max_slots)
    dist, gid = dispatch_refine(index.store, queries, qp.sel_part,
                                qp.sel_lo, qp.sel_hi, k, mesh=mesh,
                                data_axis=data_axis, use_kernel=use_kernel)
    return dist, gid, qp
