"""Computation of group centroids — paper Algorithm 2 (§V Step 2).

The skeleton is built on the host from a small sample (exactly as the paper
builds it on the Spark driver): rank-insensitive signatures are aggregated by
exact match into (signature, frequency) pairs, sorted by descending frequency,
and admitted greedily as centroids subject to
  (1) OD ≥ ε from every previously accepted centroid   (spread),
  (2) estimated group size ≥ α·c                        (no tiny groups),
  (3) an optional MaxCentroids cap.
The special fall-back centroid (G0, the empty set ``<*,*,...>``) is always
present; we place it at index 0 so that "assign to group 0" is the no-overlap
escape hatch of Algorithm 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class CentroidSet:
    """Skeleton-level output of Algorithm 2.

    onehot:  [G, r] float32 bitset rows; row 0 is the all-zeros fall-back.
    sigs:    [G, m] int32; row 0 is all -1 (fall-back has no members a priori).
    """

    onehot: np.ndarray
    sigs: np.ndarray

    @property
    def num_groups(self) -> int:
        return self.onehot.shape[0]


def aggregate_signatures(p4_set: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """List L of Algorithm 2: unique rank-insensitive signatures + frequencies."""
    uniq, counts = np.unique(np.asarray(p4_set), axis=0, return_counts=True)
    return uniq.astype(np.int32), counts.astype(np.int64)


def _overlap_dist_np(a: np.ndarray, b: np.ndarray, m: int) -> int:
    """OD between two set signatures (host-side helper)."""
    return int(m - np.intersect1d(a, b, assume_unique=True).size)


def compute_centroids(
    p4_set_sample: np.ndarray,
    num_pivots: int,
    *,
    sample_frac: float,
    capacity: int,
    min_od: int = 2,
    max_centroids: int = 0,
) -> CentroidSet:
    """Algorithm 2.

    Args:
      p4_set_sample: ``[S, m]`` rank-insensitive signatures of the sample.
      num_pivots: r.
      sample_frac: α ∈ (0,1].
      capacity: c (storage capacity constraint).
      min_od: ε — signatures closer than this to an accepted centroid are
        skipped (Alg. 2 lines 5–9 use strict ``<``).
      max_centroids: optional stopping condition (0 = unlimited).

    Returns:
      CentroidSet with the fall-back group at index 0.
    """
    sigs, freqs = aggregate_signatures(p4_set_sample)
    m = sigs.shape[1]
    order = np.argsort(-freqs, kind="stable")           # line 2: sort desc
    sigs, freqs = sigs[order], freqs[order]

    chosen: list[int] = []
    total_freq = int(freqs.sum())

    for i in range(len(sigs)):
        if not chosen:
            chosen.append(i)                            # line 3: L[0]
            continue
        # line 5-9: too close to an existing centroid -> skip this candidate
        too_close = any(
            _overlap_dist_np(sigs[i], sigs[j], m) < min_od for j in chosen
        )
        if too_close:
            continue
        # line 10-13: avoid tiny groups.  Estimated membership assumes the
        # remaining (non-centroid) mass spreads uniformly over the current
        # centroids (+1 for the candidate itself).
        chosen_freq = int(freqs[list(chosen)].sum())
        size_est = freqs[i] + (total_freq - chosen_freq - freqs[i]) / (len(chosen) + 1)
        if size_est < sample_frac * capacity:
            break                                        # S_c is final
        chosen.append(i)
        if max_centroids and len(chosen) == max_centroids:
            break

    g = len(chosen) + 1                                  # +1 fall-back (line 17)
    onehot = np.zeros((g, num_pivots), dtype=np.float32)
    out_sigs = np.full((g, m), -1, dtype=np.int32)
    for gi, idx in enumerate(chosen, start=1):
        onehot[gi, sigs[idx]] = 1.0
        out_sigs[gi] = sigs[idx]
    return CentroidSet(onehot=onehot, sigs=out_sigs)
