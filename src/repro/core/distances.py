"""Similarity metrics of the dual representation — paper Defs. 3, 7, 9–11.

All metrics are expressed as dense linear algebra over bitset/weighted-bitset
rows so they vectorise over millions of objects and shard cleanly under pjit.
"""
from __future__ import annotations

import jax.numpy as jnp


def euclidean(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """ED (Def. 3) between broadcast-compatible series.  ``[...]``."""
    return jnp.sqrt(jnp.maximum(jnp.sum((x - y) ** 2, axis=-1), 0.0))


def squared_l2_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared ED: x ``[Q, n]``, y ``[N, n]`` → ``[Q, N]``.

    Ranking-equivalent to ED; the sqrt is deferred to presentation time.
    """
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    return jnp.maximum(x2 - 2.0 * (x @ y.T) + y2, 0.0)


def overlap_distance(x_onehot: jnp.ndarray, c_onehot: jnp.ndarray,
                     m: int) -> jnp.ndarray:
    """OD (Def. 7): m − |X ∩ Y| for bitset rows.

    Args:
      x_onehot: ``[..., r]`` object bitsets.
      c_onehot: ``[G, r]`` centroid bitsets.
      m: prefix length.
    Returns:
      ``[..., G]`` integer-valued distances in [0, m] (float dtype).
    """
    return m - x_onehot @ c_onehot.T


def total_weight(weights: jnp.ndarray) -> jnp.ndarray:
    """TW (Def. 10) — constant given fixed m and decay."""
    return jnp.sum(weights)


def weight_distance(x_weighted: jnp.ndarray, c_onehot: jnp.ndarray,
                    tw: jnp.ndarray) -> jnp.ndarray:
    """WD (Def. 11): TW − Σ_i W_i·1[pivot_i ∈ centroid].

    Args:
      x_weighted: ``[..., r]`` weighted bitsets (decay weight at pivot id).
      c_onehot:   ``[G, r]``.
      tw: scalar total weight.
    Returns:
      ``[..., G]``.
    """
    return tw - x_weighted @ c_onehot.T
