"""Group assignment rules — paper Algorithm 1 (§IV-C), fully vectorised.

Decision ladder for each object X:
  1. all OD distances == m (no pivot overlap with any centroid)  → group 0;
  2. unique smallest OD                                          → that group;
  3. tie → smallest WD (Def. 11) among the OD-tied centroids     → that group;
  4. second tie → deterministic lowest-id selection (the paper picks
     randomly among equally-good groups; we default to the lowest group id
     for reproducibility and provide a seeded random variant).

Everything is one-hot linear algebra: OD and WD against all centroids are two
matmuls, so assignment of a billion objects is embarrassingly data-parallel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import signatures as S

_BIG = jnp.float32(1e9)


def assign_groups(
    p4_rank: jnp.ndarray,
    centroid_onehot: jnp.ndarray,
    num_pivots: int,
    *,
    decay: str = "exp",
    decay_lambda: float = 0.5,
    tie_key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Assign every object to a group id.

    Args:
      p4_rank: ``[N, m]`` rank-sensitive signatures.
      centroid_onehot: ``[G, r]`` centroid bitsets, row 0 = fall-back (zeros).
      num_pivots: r.
      tie_key: optional PRNG key for the paper's random second-tie break.

    Returns:
      ``[N]`` int32 group ids in [0, G).
    """
    m = p4_rank.shape[-1]
    x_oh = S.set_onehot(p4_rank, num_pivots)                   # [N, r]
    od = D.overlap_distance(x_oh, centroid_onehot, m)          # [N, G]

    # Row 0 is the fall-back: its OD is always m; exclude it from the min.
    od_real = od.at[:, 0].set(_BIG)
    min_od = jnp.min(od_real, axis=-1, keepdims=True)          # [N, 1]
    no_overlap = jnp.min(od_real, axis=-1) >= m                # [N] → group 0

    tie = od_real <= min_od + 0.5                              # OD is integral

    # WD tie-break (lines 9-12): weights from the rank-sensitive signature.
    w = S.decay_weights(m, decay, decay_lambda)
    x_w = S.weighted_onehot(p4_rank, num_pivots, w)            # [N, r]
    wd = D.weight_distance(x_w, centroid_onehot, D.total_weight(w))
    wd_masked = jnp.where(tie, wd, _BIG)
    min_wd = jnp.min(wd_masked, axis=-1, keepdims=True)
    tie2 = wd_masked <= min_wd + 1e-6                          # [N, G]

    if tie_key is None:
        # deterministic: lowest group id among the final tie set
        group = jnp.argmax(tie2, axis=-1)
    else:
        # paper-faithful random selection among the final tie set
        gumbel = jax.random.gumbel(tie_key, tie2.shape)
        group = jnp.argmax(jnp.where(tie2, gumbel, -_BIG), axis=-1)

    return jnp.where(no_overlap, 0, group).astype(jnp.int32)


def assignment_distances(
    p4_rank: jnp.ndarray,
    centroid_onehot: jnp.ndarray,
    num_pivots: int,
    *,
    decay: str = "exp",
    decay_lambda: float = 0.5,
):
    """Return (od, wd) against all centroids — used by the query planner.

    od, wd: ``[N, G]`` with the fall-back column 0 set to +inf-like values.
    """
    m = p4_rank.shape[-1]
    x_oh = S.set_onehot(p4_rank, num_pivots)
    od = D.overlap_distance(x_oh, centroid_onehot, m).at[:, 0].set(_BIG)
    w = S.decay_weights(m, decay, decay_lambda)
    x_w = S.weighted_onehot(p4_rank, num_pivots, w)
    wd = D.weight_distance(x_w, centroid_onehot, D.total_weight(w)).at[:, 0].set(_BIG)
    return od, wd
