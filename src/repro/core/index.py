"""CLIMBER-INX — index construction workflow (paper §V, Fig. 6).

Four steps, exactly as the paper stages them:
  1. sample → PAA → random pivots → rank-sensitive signatures;
  2. aggregate rank-insensitive signatures → group centroids (Algorithm 2);
  3. assign sample to groups → per-group tries → FFD leaf packing → skeleton;
  4. full-dataset pass: signatures → group (Algorithm 1) → trie routing →
     physical partitions.

Steps 1–3 run on the host over the sample (the paper runs them on the Spark
driver).  Step 4 is the heavy distributed pass and is pure jitted JAX: on a
mesh it shards over the batch ("data") axis with no sequential dependencies.

The physical store is the TPU adaptation of HDFS blocks: a dense
``[P, cap, n]`` array with validity masks (static shapes).  Records carry
their trie-node DFS tag so that record↔node attribution at query time is an
interval test (the paper's contiguous node clusters + header offsets).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment
from repro.core import centroids as centroids_mod
from repro.core import pivots as pivots_mod
from repro.core import signatures as sig_mod
from repro.core.paa import paa as _paa
from repro.core.traversal import TrieDevice, descend, route_records
from repro.core.trie import TrieForest, build_forest
from repro.utils.config import ClimberConfig


class PartitionStore(NamedTuple):
    """Physical partitions: the TPU analogue of the paper's HDFS blocks."""

    data: jnp.ndarray      # [P, cap, n] raw series (for exact ED refine)
    norms: jnp.ndarray     # [P, cap]    precomputed |x|^2
    rec_dfs: jnp.ndarray   # [P, cap]    dfs_in of the record's trie node
    rec_gid: jnp.ndarray   # [P, cap]    original dataset row id (-1 = pad)
    count: jnp.ndarray     # [P]         live records per partition

    @property
    def num_partitions(self) -> int:
        return self.data.shape[0]

    @property
    def capacity(self) -> int:
        return self.data.shape[1]


@dataclass
class ClimberIndex:
    """The complete index: skeleton (replicated) + store (sharded)."""

    cfg: ClimberConfig
    pivots: jnp.ndarray            # [r, w]
    centroid_onehot: jnp.ndarray   # [G, r], row 0 = fall-back
    forest: TrieForest             # host skeleton (numpy)
    trie: TrieDevice               # device skeleton (replicated)
    store: PartitionStore

    @property
    def num_groups(self) -> int:
        return self.centroid_onehot.shape[0]

    # -- feature extraction for any batch of raw series -------------------
    def featurize(self, series: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """raw ``[..., n]`` → (p4_rank ``[..., m]``, paa ``[..., w]``)."""
        z = _paa(series, self.cfg.paa_segments)
        p4r = sig_mod.rank_signature(z, self.pivots, self.cfg.prefix_len)
        return p4r, z


def _route_full_dataset(data: jnp.ndarray, pivots: jnp.ndarray,
                        centroid_onehot: jnp.ndarray, trie: TrieDevice,
                        cfg: ClimberConfig):
    """Step 4 (jitted): signatures → groups → partitions for every record."""
    z = _paa(data, cfg.paa_segments)
    p4r = sig_mod.rank_signature(z, pivots, cfg.prefix_len)
    grp = assignment.assign_groups(
        p4r, centroid_onehot, cfg.num_pivots,
        decay=cfg.decay, decay_lambda=cfg.decay_lambda)
    part, rec_dfs = route_records(trie, p4r, grp)
    return part, rec_dfs


_route_full_dataset_jit = jax.jit(_route_full_dataset, static_argnames=("cfg",))


def build_store(data: jnp.ndarray, part: np.ndarray, rec_dfs: np.ndarray,
                num_partitions: int, pad: Optional[int] = None) -> PartitionStore:
    """Scatter records into the fixed-capacity partition array."""
    n_rec = data.shape[0]
    part = np.asarray(part)
    rec_dfs_np = np.asarray(rec_dfs)
    counts = np.bincount(part, minlength=num_partitions)
    cap = int(counts.max()) if pad is None else int(max(pad, counts.max()))
    cap = max(cap, 1)

    order = np.argsort(part, kind="stable")
    part_sorted = part[order]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(n_rec) - starts[part_sorted]

    series_len = data.shape[1]
    store_data = np.zeros((num_partitions, cap, series_len), dtype=np.float32)
    store_dfs = np.full((num_partitions, cap), -1, dtype=np.int32)
    store_gid = np.full((num_partitions, cap), -1, dtype=np.int32)
    data_np = np.asarray(data, dtype=np.float32)
    store_data[part_sorted, slot] = data_np[order]
    store_dfs[part_sorted, slot] = rec_dfs_np[order]
    store_gid[part_sorted, slot] = order

    norms = np.sum(store_data.astype(np.float64) ** 2, axis=-1).astype(np.float32)
    return PartitionStore(
        data=jnp.asarray(store_data),
        norms=jnp.asarray(norms),
        rec_dfs=jnp.asarray(store_dfs),
        rec_gid=jnp.asarray(store_gid),
        count=jnp.asarray(counts.astype(np.int32)),
    )


def build_index(key: jax.Array, data: jnp.ndarray, cfg: ClimberConfig,
                *, pivot_method: str = "random") -> ClimberIndex:
    """End-to-end CLIMBER-INX construction (Fig. 6)."""
    n_rec, series_len = data.shape
    if series_len != cfg.series_len:
        raise ValueError(f"data series_len {series_len} != cfg {cfg.series_len}")
    k_sample, k_pivot, k_tie = jax.random.split(key, 3)

    # ---- Step 1: sample, PAA, pivots, signatures ------------------------
    sample_size = int(np.clip(int(n_rec * cfg.sample_frac),
                              min(n_rec, max(4 * cfg.num_pivots, 256)), n_rec))
    alpha_eff = sample_size / n_rec
    sample_idx = jax.random.choice(k_sample, n_rec, shape=(sample_size,),
                                   replace=False)
    sample_paa = _paa(data[sample_idx], cfg.paa_segments)
    pivots = pivots_mod.select_pivots(k_pivot, sample_paa, cfg.num_pivots,
                                      method=pivot_method)
    p4r_s, p4s_s = sig_mod.compute_signatures(sample_paa, pivots, cfg.prefix_len)

    # ---- Step 2: centroids (host, Algorithm 2) --------------------------
    cents = centroids_mod.compute_centroids(
        np.asarray(p4s_s), cfg.num_pivots,
        sample_frac=alpha_eff, capacity=cfg.capacity,
        min_od=cfg.centroid_min_od, max_centroids=cfg.max_centroids)
    c_onehot = jnp.asarray(cents.onehot)

    # ---- Step 3: sample groups → tries → packing (host) -----------------
    # Aggregate rank-sensitive signatures by exact match (paper: [(P4→, freq)]).
    p4r_np = np.asarray(p4r_s)
    uniq, inverse, counts = np.unique(p4r_np, axis=0, return_inverse=True,
                                      return_counts=True)
    grp_s = assignment.assign_groups(
        jnp.asarray(uniq), c_onehot, cfg.num_pivots,
        decay=cfg.decay, decay_lambda=cfg.decay_lambda)
    forest = build_forest(uniq, counts, np.asarray(grp_s),
                          cents.num_groups, cfg.num_pivots,
                          capacity=float(cfg.capacity), sample_frac=alpha_eff)
    trie_dev = TrieDevice.from_forest(forest)

    # ---- Step 4: full-dataset routing + physical store -------------------
    part, rec_dfs = _route_full_dataset_jit(data, pivots, c_onehot, trie_dev, cfg)
    store = build_store(data, np.asarray(part), np.asarray(rec_dfs),
                        forest.num_partitions, pad=cfg.partition_pad)

    return ClimberIndex(cfg=cfg, pivots=pivots, centroid_onehot=c_onehot,
                        forest=forest, trie=trie_dev, store=store)
