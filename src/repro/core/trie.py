"""Trie-based partition formation — paper §IV-D / §V Step 3.

Each group whose estimated size exceeds the capacity c is recursively split
into a trie over *rank-sensitive* prefixes: level d distributes the group's
signatures by their d-th pivot.  Leaves are packed into physical partitions
with FFD (``repro.core.packing``).  Internal nodes are labelled with the
partition ids of their subtree (Fig. 5), and every group keeps a *default
partition* (smallest occupancy) for unseen signatures (§V Step 3).

TPU adaptation: pointer-chasing tries don't vectorise, so the forest is
flattened into sorted edge tables.  Descent for a batch of signatures is then
m rounds of ``searchsorted`` over ``node_id * r + pivot_id`` keys — O(m log E)
per object, fully vmappable, and identical in result to the paper's walk.
Subtree membership is encoded as DFS entry/exit intervals so that
record-to-node attribution (the paper's contiguous node clusters inside a
partition + header offsets) becomes a single interval test per record.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.packing import ffd_pack


@dataclass
class TrieForest:
    """Flattened forest: one trie per group, shared node/edge tables."""

    # topology (CSR: edges of node i live in [child_start[i], child_start[i+1]))
    child_start: np.ndarray     # [num_nodes + 1] int32
    edge_pivot: np.ndarray      # [E] int32 — sorted within each node's range
    edge_child: np.ndarray      # [E] int32
    edge_key: np.ndarray        # [E] int64 — node_id * r + pivot (globally sorted)

    # node attributes
    node_size: np.ndarray       # [num_nodes] float64 — estimated subtree size
    node_depth: np.ndarray      # [num_nodes] int32
    dfs_in: np.ndarray          # [num_nodes] int32
    dfs_out: np.ndarray         # [num_nodes] int32

    # node -> partitions (CSR over distinct partition ids of the subtree)
    part_start: np.ndarray      # [num_nodes + 1] int32
    part_ids: np.ndarray        # [sum] int32

    # per-group
    group_root: np.ndarray      # [G] int32
    group_default_part: np.ndarray  # [G] int32

    num_partitions: int
    num_pivots: int             # r — for edge keys
    max_parts_per_node: int     # static bound used by the query planner

    @property
    def num_nodes(self) -> int:
        return self.node_size.shape[0]

    def node_partitions(self, node: int) -> np.ndarray:
        return self.part_ids[self.part_start[node]: self.part_start[node + 1]]


class _Node:
    __slots__ = ("depth", "entries", "children", "size", "nid", "part_set")

    def __init__(self, depth: int):
        self.depth = depth
        self.entries: List[Tuple[np.ndarray, float]] = []  # (sig, scaled freq)
        self.children: Dict[int, "_Node"] = {}
        self.size = 0.0
        self.nid = -1
        self.part_set: List[int] = []


def _split(node: _Node, capacity: float, max_depth: int) -> None:
    """Recursive trie split (paper Fig. 5): distribute by the depth-th pivot."""
    node.size = sum(f for _, f in node.entries)
    if node.size <= capacity or node.depth >= max_depth:
        return                                           # leaf
    for sig, f in node.entries:
        p = int(sig[node.depth])
        child = node.children.get(p)
        if child is None:
            child = node.children[p] = _Node(node.depth + 1)
        child.entries.append((sig, f))
    for child in node.children.values():
        _split(child, capacity, max_depth)


def build_forest(
    p4_rank: np.ndarray,
    freqs: np.ndarray,
    groups: np.ndarray,
    num_groups: int,
    num_pivots: int,
    *,
    capacity: float,
    sample_frac: float,
) -> TrieForest:
    """Build the partition skeleton from the sample's rank-sensitive sigs.

    Args:
      p4_rank: ``[S, m]`` sample signatures (aggregated or raw).
      freqs: ``[S]`` frequencies (1 for raw rows).
      groups: ``[S]`` group id of every signature (Algorithm 1 output).
      num_groups: G (including fall-back group 0).
      num_pivots: r.
      capacity: c.
      sample_frac: α — sample counts are scaled by 1/α for size estimates (§V).
    """
    p4_rank = np.asarray(p4_rank)
    freqs = np.asarray(freqs, dtype=np.float64) / sample_frac
    groups = np.asarray(groups)
    m = p4_rank.shape[1]

    # -- per-group trie construction ------------------------------------
    roots: List[_Node] = []
    for g in range(num_groups):
        root = _Node(0)
        sel = np.nonzero(groups == g)[0]
        root.entries = [(p4_rank[i], float(freqs[i])) for i in sel]
        _split(root, capacity, m)
        roots.append(root)

    # -- flatten with DFS numbering --------------------------------------
    nodes: List[_Node] = []

    def dfs_assign(nd: _Node):
        nd.nid = len(nodes)
        nodes.append(nd)
        for p in sorted(nd.children):
            dfs_assign(nd.children[p])

    group_root = np.zeros(num_groups, dtype=np.int32)
    for g, root in enumerate(roots):
        group_root[g] = len(nodes)
        dfs_assign(root)

    n_nodes = len(nodes)
    child_start = np.zeros(n_nodes + 1, dtype=np.int32)
    edge_pivot: List[int] = []
    edge_child: List[int] = []
    node_size = np.zeros(n_nodes, dtype=np.float64)
    node_depth = np.zeros(n_nodes, dtype=np.int32)
    dfs_in = np.zeros(n_nodes, dtype=np.int32)
    dfs_out = np.zeros(n_nodes, dtype=np.int32)

    counter = [0]

    def dfs_intervals(nd: _Node):
        dfs_in[nd.nid] = counter[0]
        counter[0] += 1
        for p in sorted(nd.children):
            dfs_intervals(nd.children[p])
        dfs_out[nd.nid] = counter[0]

    for root in roots:
        dfs_intervals(root)

    for nd in nodes:
        node_size[nd.nid] = nd.size
        node_depth[nd.nid] = nd.depth
        child_start[nd.nid + 1] = len(nd.children)
        for p in sorted(nd.children):
            edge_pivot.append(p)
            edge_child.append(nd.children[p].nid)
    child_start = np.cumsum(child_start).astype(np.int32)
    edge_pivot_a = np.asarray(edge_pivot, dtype=np.int32)
    edge_child_a = np.asarray(edge_child, dtype=np.int32)
    # Edge keys: node ids ascend along the edge list and pivots ascend within
    # a node, so the concatenated key array is globally sorted already.
    src = np.repeat(np.arange(n_nodes, dtype=np.int64), np.diff(child_start))
    edge_key = src * num_pivots + edge_pivot_a.astype(np.int64)
    assert np.all(np.diff(edge_key) > 0), "edge keys must be strictly sorted"
    # int32 keys keep the device tables compact; guard the range.
    assert n_nodes * num_pivots < 2**31, "trie too large for int32 edge keys"
    edge_key = edge_key.astype(np.int32)

    # -- FFD packing of leaves, per group (paper packs within a group) ----
    part_of_leaf: Dict[int, int] = {}
    group_default = np.zeros(num_groups, dtype=np.int32)
    next_pid = 0
    for g, root in enumerate(roots):
        leaves: List[_Node] = []

        def collect(nd: _Node):
            if not nd.children:
                leaves.append(nd)
            for p in sorted(nd.children):
                collect(nd.children[p])

        collect(root)
        sizes = [nd.size for nd in leaves]
        assign, nbins = ffd_pack(sizes, capacity)
        nbins = max(nbins, 1)                       # every group owns >= 1 partition
        load = np.zeros(nbins)
        for nd, b in zip(leaves, assign):
            pid = next_pid + (int(b) if b >= 0 else 0)
            part_of_leaf[nd.nid] = pid
            load[int(b) if b >= 0 else 0] += nd.size
        group_default[g] = next_pid + int(np.argmin(load))  # smallest occupancy
        next_pid += nbins

    # -- node -> subtree partition sets (bottom-up union) ----------------
    def fill_parts(nd: _Node) -> List[int]:
        if not nd.children:
            nd.part_set = [part_of_leaf[nd.nid]]
        else:
            acc = set()
            for p in sorted(nd.children):
                acc.update(fill_parts(nd.children[p]))
            nd.part_set = sorted(acc)
        return nd.part_set

    for g, root in enumerate(roots):
        fill_parts(root)
        # ensure the group's default partition is reachable from every node
        for nd_id in range(group_root[g],
                           group_root[g + 1] if g + 1 < num_groups else n_nodes):
            ps = nodes[nd_id].part_set
            if int(group_default[g]) not in ps:
                nodes[nd_id].part_set = sorted(ps + [int(group_default[g])])

    part_start = np.zeros(n_nodes + 1, dtype=np.int32)
    part_ids: List[int] = []
    for nd in nodes:
        part_start[nd.nid + 1] = len(nd.part_set)
        part_ids.extend(nd.part_set)
    part_start = np.cumsum(part_start).astype(np.int32)
    part_ids_a = np.asarray(part_ids, dtype=np.int32)
    max_ppn = int(np.max(np.diff(part_start))) if n_nodes else 1

    return TrieForest(
        child_start=child_start,
        edge_pivot=edge_pivot_a,
        edge_child=edge_child_a,
        edge_key=edge_key,
        node_size=node_size,
        node_depth=node_depth,
        dfs_in=dfs_in,
        dfs_out=dfs_out,
        part_start=part_start,
        part_ids=part_ids_a,
        group_root=group_root,
        group_default_part=group_default,
        num_partitions=next_pid,
        num_pivots=num_pivots,
        max_parts_per_node=max_ppn,
    )
