"""Pivot selection — paper §V Step 1.

The paper uses *random* pivot selection from the PAA'd sample ("random
selection works competitively well compared to any other sophisticated
selection methods" citing [24], [29], [44], [45], [59]).  We implement that
as the faithful default and additionally provide farthest-point (max-min)
selection as a beyond-paper option used in §Perf experiments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def select_pivots_random(key: jax.Array, paa_data: jnp.ndarray, r: int) -> jnp.ndarray:
    """Uniformly sample ``r`` distinct rows of ``paa_data`` as pivots.

    Args:
      key: PRNG key.
      paa_data: ``[N, w]`` PAA signatures of the sample.
      r: number of pivots.

    Returns:
      ``[r, w]`` pivot matrix (fixed for the lifetime of the index).
    """
    n = paa_data.shape[0]
    if r > n:
        raise ValueError(f"cannot select r={r} pivots from {n} samples")
    idx = jax.random.choice(key, n, shape=(r,), replace=False)
    return paa_data[idx]


def select_pivots_maxmin(key: jax.Array, paa_data: jnp.ndarray, r: int) -> jnp.ndarray:
    """Farthest-point ("max-min") pivot selection.  Beyond-paper option.

    Greedy k-center: start from a random point, repeatedly add the point
    whose distance to the current pivot set is maximal.  O(r·N·w); runs on a
    modest sample so this is cheap, and yields better-spread Voronoi cells.
    """
    n = paa_data.shape[0]
    if r > n:
        raise ValueError(f"cannot select r={r} pivots from {n} samples")
    first = jax.random.randint(key, (), 0, n)
    chosen = [first]
    d2 = jnp.sum((paa_data - paa_data[first]) ** 2, axis=-1)
    for _ in range(r - 1):
        nxt = jnp.argmax(d2)
        chosen.append(nxt)
        d2 = jnp.minimum(d2, jnp.sum((paa_data - paa_data[nxt]) ** 2, axis=-1))
    idx = jnp.stack(chosen)
    return paa_data[idx]


def select_pivots(key: jax.Array, paa_data: jnp.ndarray, r: int,
                  method: str = "random") -> jnp.ndarray:
    if method == "random":
        return select_pivots_random(key, paa_data, r)
    if method == "maxmin":
        return select_pivots_maxmin(key, paa_data, r)
    raise ValueError(f"unknown pivot selection method {method!r}")
