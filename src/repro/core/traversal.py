"""Vectorised trie descent — device-side counterpart of ``repro.core.trie``.

The forest is a sorted edge-key table (``node_id * r + pivot``); descending a
rank-sensitive signature is m rounds of binary search.  This replaces the
paper's per-object pointer walk with a batched, XLA-friendly formulation that
produces identical landing nodes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trie import TrieForest


class TrieDevice(NamedTuple):
    """Device-resident (replicated) view of the skeleton."""

    edge_key: jnp.ndarray          # [E] int64, sorted
    edge_child: jnp.ndarray        # [E] int32
    has_children: jnp.ndarray      # [num_nodes] bool
    node_size: jnp.ndarray         # [num_nodes] float32
    node_depth: jnp.ndarray        # [num_nodes] int32
    dfs_in: jnp.ndarray            # [num_nodes] int32
    dfs_out: jnp.ndarray           # [num_nodes] int32
    part_start: jnp.ndarray        # [num_nodes + 1] int32
    part_ids_pad: jnp.ndarray      # [num_nodes, maxP] int32, -1 padded
    group_root: jnp.ndarray        # [G] int32
    group_default_part: jnp.ndarray  # [G] int32
    num_pivots: int
    num_partitions: int

    @classmethod
    def from_forest(cls, f: TrieForest) -> "TrieDevice":
        n = f.num_nodes
        maxp = max(f.max_parts_per_node, 1)
        pad = np.full((n, maxp), -1, dtype=np.int32)
        for i in range(n):
            ps = f.node_partitions(i)
            pad[i, : len(ps)] = ps
        return cls(
            edge_key=jnp.asarray(f.edge_key),
            edge_child=jnp.asarray(f.edge_child),
            has_children=jnp.asarray(np.diff(f.child_start) > 0),
            node_size=jnp.asarray(f.node_size, dtype=jnp.float32),
            node_depth=jnp.asarray(f.node_depth),
            dfs_in=jnp.asarray(f.dfs_in),
            dfs_out=jnp.asarray(f.dfs_out),
            part_start=jnp.asarray(f.part_start),
            part_ids_pad=jnp.asarray(pad),
            group_root=jnp.asarray(f.group_root),
            group_default_part=jnp.asarray(f.group_default_part),
            num_pivots=f.num_pivots,
            num_partitions=f.num_partitions,
        )


def pad_trie(trie: TrieDevice, *, num_nodes: int, num_edges: int,
             max_parts: int, num_groups: int) -> TrieDevice:
    """Pad a skeleton to static dims with *inert* entries.

    The fleet's stacked-trie planner (``repro.fleet.device_plan``) stacks
    ragged per-shard skeletons into one ``[S, ...]`` table set, so every
    shard must first be padded to the fleet-wide maxima in a way that can
    never change a descent or a plan:

      * edge keys pad with int32 max — a real key is ``node * r + pivot``
        with ``node * r < 2**31`` (asserted at build), so no probe ever
        matches a pad edge and ``searchsorted`` still sees a sorted table;
      * the node axis pads with inert nodes (no children, size 0, empty
        DFS interval ``[0, 0)``, no partitions) — ``num_nodes`` must exceed
        the real node count so index ``num_nodes - 1`` is guaranteed inert;
      * pad groups root at that inert node and default to partition ``-1``,
        so a descent from a pad group lands nowhere and plans nothing.

    Returns the padded TrieDevice (num_pivots/num_partitions unchanged).
    """
    n = int(trie.has_children.shape[0])
    e = int(trie.edge_key.shape[0])
    g = int(trie.group_root.shape[0])
    p = int(trie.part_ids_pad.shape[1])
    if num_nodes <= n:
        raise ValueError(f"num_nodes={num_nodes} must exceed the real node "
                         f"count {n} (the last index must be inert)")
    if num_edges < e or num_groups < g or max_parts < p:
        raise ValueError("pad_trie cannot shrink a skeleton")
    dn, de, dg = num_nodes - n, num_edges - e, num_groups - g
    inert = num_nodes - 1
    pad1 = lambda x, w, cv: jnp.pad(x, ((0, w),), constant_values=cv)
    part_ids = jnp.pad(trie.part_ids_pad,
                       ((0, dn), (0, max_parts - p)), constant_values=-1)
    return TrieDevice(
        edge_key=pad1(trie.edge_key, de, jnp.iinfo(jnp.int32).max),
        edge_child=pad1(trie.edge_child, de, 0),
        has_children=pad1(trie.has_children, dn, False),
        node_size=pad1(trie.node_size, dn, 0.0),
        node_depth=pad1(trie.node_depth, dn, 0),
        dfs_in=pad1(trie.dfs_in, dn, 0),
        dfs_out=pad1(trie.dfs_out, dn, 0),
        part_start=pad1(trie.part_start, dn,
                        int(trie.part_start[-1])),
        part_ids_pad=part_ids,
        group_root=pad1(trie.group_root, dg, inert),
        group_default_part=pad1(trie.group_default_part, dg, -1),
        num_pivots=trie.num_pivots,
        num_partitions=trie.num_partitions,
    )


def descend(trie: TrieDevice, p4_rank: jnp.ndarray,
            group: jnp.ndarray):
    """Walk each signature down its group's trie as far as possible.

    Args:
      trie: device skeleton.
      p4_rank: ``[..., m]`` rank-sensitive signatures.
      group: ``[...]`` group ids.

    Returns:
      (node, pathlen, parent): landing node id (the paper's G_N), the number
      of matched prefix pivots (PathLen in Algorithm 3), and the landing
      node's parent (the "2nd-longest best match" memorised by
      CLIMBER-kNN-Adaptive; equals the node itself at the root).
    """
    m = p4_rank.shape[-1]
    e = trie.edge_key.shape[0]
    node = trie.group_root[group].astype(jnp.int32)
    parent = node
    alive = jnp.ones(node.shape, dtype=bool)
    pathlen = jnp.zeros(node.shape, dtype=jnp.int32)

    if e == 0:        # edgeless forest (tiny builds): everyone stays at root
        return node, pathlen, parent

    for d in range(m):                             # m is small and static
        key = node * trie.num_pivots + p4_rank[..., d].astype(jnp.int32)
        pos = jnp.searchsorted(trie.edge_key, key)
        pos_c = jnp.minimum(pos, e - 1)
        found = alive & (trie.edge_key[pos_c] == key) & (pos < e)
        parent = jnp.where(found, node, parent)
        node = jnp.where(found, trie.edge_child[pos_c].astype(jnp.int32), node)
        pathlen = pathlen + found.astype(jnp.int32)
        alive = found
    return node.astype(jnp.int32), pathlen, parent.astype(jnp.int32)


def route_records(trie: TrieDevice, p4_rank: jnp.ndarray, group: jnp.ndarray):
    """Placement routing (§V Step 4).

    A record that completes a root-to-leaf walk goes to the leaf's partition;
    a record stuck at an internal node goes to its group's default partition.
    Its dfs tag is the landing node's dfs_in, which makes record↔node
    attribution a single interval test at query time.

    Returns:
      (partition, rec_dfs): ``[...]`` each.
    """
    node, _, _ = descend(trie, p4_rank, group)
    is_leaf = ~trie.has_children[node]
    # A leaf's own partition is the first entry of its (singleton ∪ default)
    # partition list; sorting in trie.py keeps the leaf's own pid present.
    leaf_part = trie.part_ids_pad[node, 0]
    # When default was prepended by sorting, the leaf's true pid may sit at
    # slot 1; disambiguate via the dfs interval: a leaf's list is {own, default}
    # and own != default only matters for placement balance, so prefer the
    # non-default entry when available.
    second = trie.part_ids_pad[node, 1]
    default = trie.group_default_part[group]
    own = jnp.where((leaf_part == default) & (second >= 0), second, leaf_part)
    part = jnp.where(is_leaf, own, default)
    return part.astype(jnp.int32), trie.dfs_in[node]
