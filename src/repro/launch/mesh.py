"""Production mesh construction.

Single-pod: (16, 16) = (data, model) — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = (pod, data, model) — 512 chips; the ``pod`` axis
is pure data parallelism (weights replicated across pods, gradients
all-reduced over DCI once per step).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(shape, axes)
    except (ValueError, TypeError):
        # fall back for environments where jax.make_mesh insists on using
        # every device: build explicitly from the first prod(shape) devices.
        from jax.sharding import Mesh
        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (4, 2) on 8 host devices)."""
    import jax
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
