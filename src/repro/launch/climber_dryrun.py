"""Dry-run of the PAPER'S OWN technique at production scale.

Lowers + compiles the two distributed CLIMBER steps on the 16×16 (and
2×16×16) mesh with ShapeDtypeStruct data — no allocation:

  * ``index_build_step`` — §V Step 4: PAA → P⁴ signatures → Algorithm-1
    group assignment → trie routing, for every record (sharded over all
    non-model axes; embarrassingly parallel, zero collectives expected);
  * ``query_step``      — §VI: featurise queries → OD/WD planning → trie
    descent → sharded masked-ED refine + all-gather top-k merge.

Scale: 128M series × 256 readings (the paper's 200GB-class RandomWalk
regime at c=3000 partition capacity), r=200 pivots, m=10 prefix, K=500,
50 queries per batch — the paper's §VII defaults.

Writes artifacts/dryrun/climber_{build,query}_{mesh}.json.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import (ClimberIndex, PartitionStore, build_forest,
                        plan_adaptive)
from repro.core.query import compact_plan
from repro.core.index import _route_full_dataset
from repro.core.refine import refine
from repro.core.traversal import TrieDevice
from repro.launch.mesh import make_production_mesh
from repro.utils import roofline as RL
from repro.utils.config import ClimberConfig

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

CFG = ClimberConfig(series_len=256, paa_segments=16, num_pivots=200,
                    prefix_len=10, capacity=3000, sample_frac=0.01,
                    max_centroids=512, k=500, candidate_groups=8,
                    adaptive_factor=4)
N_SERIES = 128_000_000
N_QUERIES = 50


def synthetic_skeleton(cfg: ClimberConfig, num_groups: int = 256,
                       sample: int = 60_000, seed: int = 0):
    """Host-built skeleton with realistic shape statistics (trace-time only)."""
    rng = np.random.default_rng(seed)
    sigs = np.stack([rng.choice(cfg.num_pivots, cfg.prefix_len, replace=False)
                     for _ in range(sample)]).astype(np.int32)
    freqs = rng.integers(1, 50, size=sample)
    groups = rng.integers(0, num_groups, size=sample)
    forest = build_forest(sigs, freqs, groups, num_groups, cfg.num_pivots,
                          capacity=float(cfg.capacity),
                          sample_frac=cfg.sample_frac)
    trie = TrieDevice.from_forest(forest)
    onehot = np.zeros((num_groups, cfg.num_pivots), np.float32)
    for g in range(1, num_groups):
        onehot[g, rng.choice(cfg.num_pivots, cfg.prefix_len, replace=False)] = 1
    return forest, trie, jnp.asarray(onehot)


def _mesh_and_axes(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_axes = tuple(mesh.axis_names)          # all axes shard the records
    return mesh, shard_axes


def lower_build_step(multi_pod: bool):
    """§V Step 4 at scale: every record → (partition, dfs tag).

    Expressed with shard_map (each worker routes only its block — the exact
    Spark-executor semantics): left to GSPMD, the one-hot/top-k pipeline got
    partitioned with a full [N, r] replication (100 GB/device of involuntary
    all-gather).  Manual sharding pins every intermediate to the record
    shard; the step is embarrassingly parallel with zero collectives.
    """
    from jax.experimental.shard_map import shard_map

    mesh, axes = _mesh_and_axes(multi_pod)
    forest, trie, onehot = synthetic_skeleton(CFG)
    data = jax.ShapeDtypeStruct((N_SERIES, CFG.series_len), jnp.float32)
    data_sh = NamedSharding(mesh, PS(axes, None))
    out_sh = NamedSharding(mesh, PS(axes))
    pivots = jnp.zeros((CFG.num_pivots, CFG.paa_segments), jnp.float32)

    def local_route(x):
        return _route_full_dataset(x, pivots, onehot, trie, CFG)

    def step(x):
        return shard_map(local_route, mesh=mesh,
                         in_specs=PS(axes, None),
                         out_specs=(PS(axes), PS(axes)),
                         check_rep=False)(x)

    jitted = jax.jit(step, in_shardings=(data_sh,),
                     out_shardings=(out_sh, out_sh))
    return jitted.lower(data), mesh, forest


def lower_query_step(multi_pod: bool):
    """§VI at scale: plan + sharded masked-ED refine + top-k merge."""
    from jax.experimental.shard_map import shard_map

    mesh, axes = _mesh_and_axes(multi_pod)
    forest, trie, onehot = synthetic_skeleton(CFG)
    n_dev = mesh.devices.size
    p_total = ((N_SERIES // CFG.capacity) // n_dev) * n_dev
    cap = CFG.capacity

    index = ClimberIndex(
        cfg=CFG,
        pivots=jnp.zeros((CFG.num_pivots, CFG.paa_segments), jnp.float32),
        centroid_onehot=onehot, forest=forest, trie=trie, store=None)

    sds = jax.ShapeDtypeStruct
    store_sds = PartitionStore(
        data=sds((p_total, cap, CFG.series_len), jnp.float32),
        norms=sds((p_total, cap), jnp.float32),
        rec_dfs=sds((p_total, cap), jnp.int32),
        rec_gid=sds((p_total, cap), jnp.int32),
        count=sds((p_total,), jnp.int32))
    store_sh = PartitionStore(
        *[NamedSharding(mesh, PS(axes, *([None] * (len(s.shape) - 1))))
          for s in store_sds])
    q_sds = sds((N_QUERIES, CFG.series_len), jnp.float32)
    rep = NamedSharding(mesh, PS())
    per_dev = p_total // n_dev

    def query_step(store, queries):
        p4r_q, _ = index.featurize(queries)
        # compact the slot axis: the refine gather is Q×slots×cap×n bytes,
        # so the static 2T×maxP padding must not reach the gather
        plan = compact_plan(plan_adaptive(index, p4r_q), 16)

        def local_fn(data, norms, rdfs, rgid, count, q, sp, lo, hi):
            # flat device id over all shard axes
            dev = 0
            for a in axes:
                dev = dev * mesh.shape[a] + jax.lax.axis_index(a)
            base = dev * per_dev
            local = PartitionStore(data=data, norms=norms, rec_dfs=rdfs,
                                   rec_gid=rgid, count=count)
            sp_l = jnp.where((sp >= base) & (sp < base + per_dev),
                             sp - base, -1)
            dist, gid = refine(local, q, sp_l, lo, hi, CFG.k)
            d_all = jax.lax.all_gather(dist, axes, axis=0, tiled=False)
            g_all = jax.lax.all_gather(gid, axes, axis=0, tiled=False)
            d = d_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
            g = g_all.transpose(1, 0, 2).reshape(q.shape[0], -1)
            d = jnp.where(g >= 0, d, 3.4e38)
            neg, idx = jax.lax.top_k(-d, CFG.k)
            return -neg, jnp.take_along_axis(g, idx, axis=-1)

        fn = shard_map(
            local_fn, mesh=mesh,
            in_specs=(PS(axes), PS(axes), PS(axes), PS(axes), PS(axes),
                      PS(), plan_spec, plan_spec, plan_spec),
            out_specs=(PS(), PS()), check_rep=False)
        return fn(store.data, store.norms, store.rec_dfs, store.rec_gid,
                  store.count, queries, plan.sel_part, plan.sel_lo,
                  plan.sel_hi)

    plan_spec = PS()
    jitted = jax.jit(query_step, in_shardings=(store_sh, rep),
                     out_shardings=(rep, rep))
    return jitted.lower(store_sds, q_sds), mesh, forest


def run(kind: str, multi_pod: bool) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    lowered, mesh, forest = (lower_build_step(multi_pod) if kind == "build"
                             else lower_query_step(multi_pod))
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = RL.collective_bytes(compiled.as_text())

    n_dev = mesh.devices.size
    if kind == "build":
        # useful work: one pass over every record (PAA+pivot dots dominate)
        useful_flops = N_SERIES * (CFG.series_len                 # PAA
                                   + 2 * CFG.paa_segments * CFG.num_pivots)
        useful_bytes = N_SERIES * CFG.series_len * 4
    else:
        # useful work: ED refine over the selected partitions
        sel_rows = N_QUERIES * 8 * CFG.capacity
        useful_flops = 2 * sel_rows * CFG.series_len
        useful_bytes = sel_rows * CFG.series_len * 4

    report = RL.RooflineReport(
        arch="climber", shape=kind, mesh=mesh_name,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops_per_device=useful_flops / n_dev,
        model_bytes_per_device=useful_bytes / n_dev,
        peak_memory_bytes=float(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes))
    res = {"status": "ok", "num_devices": n_dev,
           "partitions": forest.num_partitions,
           "memory": {
               "argument_bytes": int(mem.argument_size_in_bytes),
               "output_bytes": int(mem.output_size_in_bytes),
               "temp_bytes": int(mem.temp_size_in_bytes)},
           **report.to_dict()}
    print(f"[climber-{kind} × {mesh_name}] "
          f"args={mem.argument_size_in_bytes/2**30:.2f}GiB/dev "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB/dev "
          f"flops/dev={report.flops_per_device:.3g} "
          f"coll/dev={report.coll_bytes_per_device/1e6:.1f}MB "
          f"bottleneck={report.bottleneck} frac={report.roofline_fraction:.3f}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="both", choices=["build", "query", "both"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    args = ap.parse_args()
    kinds = ["build", "query"] if args.kind == "both" else [args.kind]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ART.mkdir(parents=True, exist_ok=True)
    for kind in kinds:
        for multi in meshes:
            res = run(kind, multi)
            name = f"climber_{kind}_{'2x16x16' if multi else '16x16'}.json"
            (ART / name).write_text(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
