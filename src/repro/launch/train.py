"""End-to-end training driver.

Runs any assigned arch (smoke or full config) for N steps with the complete
substrate engaged: sharded train step, deterministic resumable data pipeline,
atomic checkpointing, watchdog + retry-with-restore recovery.

CPU example (used by tests and examples/quickstart):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --smoke \
      --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import Model
from repro.train import checkpoint as ckpt_mod
from repro.train.fault_tolerance import WatchdogPolicy, run_with_recovery
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import make_train_step, shard_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 20, batch: int = 4,
          seq: int = 64, ckpt_dir: Optional[str] = None,
          checkpoint_every: int = 10, lr: float = 3e-4, kv_chunk: int = 64,
          mesh=None, microbatches: int = 1, log_every: int = 5,
          seed: int = 0, data_mode: str = "uniform"):
    cfg = get_config(arch, smoke=smoke)
    model = Model(cfg, mesh=mesh,
                  batch_axes=tuple(a for a in (mesh.axis_names if mesh else ())
                                   if a != "model") or ("data",))
    opt = AdamW(lr=warmup_cosine(lr, max(steps // 10, 1), steps))
    pipe = TokenPipeline(cfg, batch, seq, seed=seed, mode=data_mode)

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start_step = 0

    if mesh is not None:
        batch_shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            pipe.batch_at(0))
        step_fn, (p_sh, o_sh, _) = shard_train_step(
            model, opt, mesh, batch_shapes, kv_chunk=kv_chunk,
            donate=False, microbatches=microbatches)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
    else:
        step_fn = jax.jit(make_train_step(model, opt, kv_chunk=kv_chunk,
                                          microbatches=microbatches))
        p_sh = o_sh = None

    if ckpt_dir and ckpt_mod.latest_step(ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        sh = {"params": p_sh, "opt": o_sh} if p_sh is not None else None
        state, start_step, _ = ckpt_mod.restore_checkpoint(
            ckpt_dir, state, shardings=sh)
        params, opt_state = state["params"], state["opt"]
        print(f"restored checkpoint at step {start_step}")

    losses = []
    state = {"params": params, "opt": opt_state}

    def one_step(step: int) -> dict:
        batch_step = pipe.batch_at(step)
        p, o, metrics = step_fn(state["params"], state["opt"], batch_step)
        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            raise RuntimeError(f"non-finite loss at step {step}")
        state["params"], state["opt"] = p, o
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        return metrics

    def save(step: int) -> None:
        if ckpt_dir:
            ckpt_mod.save_checkpoint(
                ckpt_dir, step, {"params": state["params"],
                                 "opt": state["opt"]},
                extra={"pipeline": pipe.state_dict(step)})
            ckpt_mod.prune_checkpoints(ckpt_dir)

    def restore() -> int:
        if not ckpt_dir:
            return start_step
        st = {"params": state["params"], "opt": state["opt"]}
        sh = {"params": p_sh, "opt": o_sh} if p_sh is not None else None
        st, step, _ = ckpt_mod.restore_checkpoint(ckpt_dir, st, shardings=sh)
        state["params"], state["opt"] = st["params"], st["opt"]
        return step

    final = run_with_recovery(
        one_step, start_step=start_step, num_steps=steps, save_fn=save,
        restore_fn=restore, checkpoint_every=checkpoint_every,
        watchdog=WatchdogPolicy())
    if ckpt_dir:
        save(final)
    return state["params"], losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    _, losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                      batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                      lr=args.lr, microbatches=args.microbatches)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
