"""§Perf hillclimbing harness.

Runs named optimization variants of selected dry-run cells, re-deriving the
roofline terms after each change, and writes ``artifacts/perf/*.json`` for
the EXPERIMENTS.md iteration log.

Variants are combinations of the knobs:
  flash_bf16     — bf16 flash operands (f32 accumulation)
  masked_cache   — one-hot decode-cache write (no DUS resharding)
  seq_acts=0     — disable sequence-parallel saved activations
  mu=N           — override gradient-accumulation depth
  pad_heads=N    — zero-pad attention heads to a model-axis-divisible count
  kv_chunk=N     — flash chunk size

Usage:
  PYTHONPATH=src python -m repro.launch.perf --cell starcoder2-15b:decode_32k \
      --variant masked_cache --variant masked_cache+flash_bf16
"""
from __future__ import annotations

# must precede jax init (see dryrun.py)
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch import dryrun as DR
from repro.models import layers as L
from repro.models import model as M
from repro.utils.config import ModelConfig

ART = Path(__file__).resolve().parents[3] / "artifacts" / "perf"


def pad_heads_cfg(cfg: ModelConfig, to: int) -> ModelConfig:
    """Zero-pad q (and kv, when kv == heads) heads so they shard.

    Padding heads with zero-initialised wq/wk/wv/wo rows leaves the function
    mathematically identical while making the head dim divisible by the
    model axis — trades +(to/heads − 1) redundant head FLOPs for full 16-way
    parallelism instead of full replication.
    """
    kv = to if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads
    return cfg.replace(num_heads=to, num_kv_heads=kv)


def apply_variant(cfg: ModelConfig, variant: str):
    """Parse 'knob+knob' into (cfg', knobs dict); set module flags."""
    L.set_flash_bf16(False)
    L.set_cache_update_masked(False)
    M.set_seq_shard_acts(True)
    kv_chunk = 2048
    mu = None
    for knob in [k for k in variant.split("+") if k and k != "baseline"]:
        if knob == "flash_bf16":
            L.set_flash_bf16(True)
        elif knob == "masked_cache":
            L.set_cache_update_masked(True)
        elif knob == "decode_shard":
            # resolved to the actual mesh in run_variant
            pass
        elif knob == "serve_weights":
            # serving profile: weights replicated over the data axis (no
            # per-token FSDP re-gathers); TP over model stays.  Valid when
            # params/model-shards fit HBM — checked by the memory proof.
            from repro.models import params as P
            P.DEFAULT_RULES["embed"] = None
        elif knob == "seq_acts=0":
            M.set_seq_shard_acts(False)
        elif knob.startswith("mu="):
            mu = int(knob.split("=")[1])
        elif knob.startswith("pad_heads="):
            cfg = pad_heads_cfg(cfg, int(knob.split("=")[1]))
        elif knob.startswith("kv_chunk="):
            kv_chunk = int(knob.split("=")[1])
        else:
            raise ValueError(f"unknown knob {knob!r}")
    return cfg, kv_chunk, mu


def run_variant(arch: str, shape_name: str, variant: str,
                *, multi_pod: bool = False) -> dict:
    cfg, kv_chunk, mu = apply_variant(get_config(arch), variant)
    if "decode_shard" in variant.split("+"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=multi_pod)
        L.set_decode_shard(mesh, tuple(a for a in mesh.axis_names
                                       if a != "model"))
    if mu is not None:
        orig = DR.pick_microbatches
        DR.pick_microbatches = lambda *a, **k: mu
    try:
        # run through the standard cell runner with the modified config
        orig_get = DR.get_config
        DR.get_config = lambda a: cfg if a == arch else orig_get(a)
        try:
            res = DR.run_cell(arch, shape_name, multi_pod=multi_pod,
                              kv_chunk=kv_chunk, verbose=False)
        finally:
            DR.get_config = orig_get
    finally:
        if mu is not None:
            DR.pick_microbatches = orig
        L.set_flash_bf16(False)
        L.set_cache_update_masked(False)
        L.set_decode_shard(None)
        M.set_seq_shard_acts(True)
        from repro.models import params as P
        P.DEFAULT_RULES["embed"] = "data"
    res["variant"] = variant
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    variants = args.variant or ["baseline"]

    ART.mkdir(parents=True, exist_ok=True)
    for v in variants:
        res = run_variant(arch, shape, v, multi_pod=args.multi_pod)
        tag = v.replace("+", "_").replace("=", "")
        out = ART / f"{arch}_{shape}_{tag}.json"
        out.write_text(json.dumps(res, indent=2))
        if res.get("status") == "ok":
            print(f"{arch}×{shape} [{v}]: compute={res['compute_s']:.4f}s "
                  f"memory={res['memory_s']:.4f}s "
                  f"collective={res['collective_s']:.4f}s "
                  f"bottleneck={res['bottleneck']} "
                  f"frac={res['roofline_fraction']:.3f} "
                  f"temp={res['memory']['temp_bytes']/2**30:.1f}GiB")
        else:
            print(f"{arch}×{shape} [{v}]: {res.get('status')} "
                  f"{res.get('error', '')[:100]}")


if __name__ == "__main__":
    main()
