"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs the abstract parameter/optimizer/batch/cache trees
     (ShapeDtypeStruct only — nothing is allocated),
  3. jits the right step (train_step / prefill / decode a.k.a. serve_step)
     with the full sharding contract, ``.lower().compile()``s it,
  4. prints ``memory_analysis()`` + ``cost_analysis()`` and writes the
     roofline terms to ``artifacts/dryrun/<arch>_<shape>_<mesh>.json``.

Skip rules (recorded in DESIGN.md): ``long_500k`` runs only for the
sub-quadratic archs (zamba2, mamba2) — dense-attention archs would need a
500k dense KV per step, exactly the blow-up the harness exempts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--force]
"""
from __future__ import annotations

# The container has ONE real CPU device; the dry-run needs 512 placeholder
# devices BEFORE jax initialises (jax locks the device count on first init).
# These two lines MUST precede every other import, including `from repro...`.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import cache_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import Model, count_params, decode_step, prefill
from repro.models.decoding import cache_shapes
from repro.train.optimizer import AdamW, constant_lr
from repro.train.train_step import (make_batch_shardings,
                                    make_state_shardings, shard_train_step)
from repro.utils import roofline as RL
from repro.utils.config import ModelConfig, SHAPES, get_shape

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ----------------------------------------------------------------------
# abstract inputs
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    shape = get_shape(shape_name)
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((b, cfg.num_image_tokens, cfg.d_model),
                                        jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((b, cfg.num_image_tokens, cfg.d_model),
                                        jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    enc_len = s if cfg.family == "encdec" else 0
    img_len = cfg.num_image_tokens if cfg.family == "vlm" else 0
    return {
        "token": sds((b, 1), jnp.int32),
        "cache": cache_shapes(cfg, b, s, enc_len=enc_len, img_len=img_len),
    }


def cell_is_skipped(cfg: ModelConfig, shape_name: str) -> str:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k dense KV per decode step is "
                "the quadratic blow-up the long_500k rule exempts")
    return ""


# ----------------------------------------------------------------------
# the cell runner
# ----------------------------------------------------------------------
def unit_scaler(cfg: ModelConfig):
    """(unit_count, make_cfg(units)) — 'unit' = one scanned layer group."""
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        return cfg.num_layers // per, \
            lambda u: cfg.replace(num_layers=u * per)
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        return cfg.num_layers // per, \
            lambda u: cfg.replace(num_layers=u * per)
    if cfg.family == "encdec":
        return cfg.num_layers, \
            lambda u: cfg.replace(num_layers=u, num_encoder_layers=u)
    return cfg.num_layers, lambda u: cfg.replace(num_layers=u)


def pick_microbatches(cfg: ModelConfig, shape, mesh) -> int:
    """Gradient-accumulation depth so saved activations stay ≤ ~3 GB/device.

    Napkin model: the remat residual set is 2 block outputs per layer,
    [B, S, D] bf16, sharded over batch shards × the model axis (sequence
    parallelism).  µ splits the global batch; capped so each microbatch
    still shards evenly.
    """
    if shape.kind != "train":
        return 1
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shards = int(np.prod([v for k, v in sizes.items() if k != "model"]))
    layers = cfg.num_layers + cfg.num_encoder_layers
    per_layer = (2 * shape.global_batch * shape.seq_len * cfg.d_model * 2
                 / (shards * sizes["model"]))
    total = per_layer * layers
    target = 3 * (1 << 30)
    cap = max(shape.global_batch // shards, 1)
    mu = 1
    while total / mu > target and mu < cap:
        mu *= 2
    return mu


def active_params(cfg: ModelConfig, n_params: int) -> int:
    """MoE: only top-k of the routed experts are active per token
    (MODEL_FLOPS = 6·N_active·D per the roofline spec)."""
    if cfg.family != "moe" or not cfg.num_experts:
        return n_params
    routed = 3 * cfg.num_experts * cfg.d_model * cfg.d_ff * cfg.num_layers
    inactive = routed * (1.0 - cfg.experts_per_token / cfg.num_experts)
    return int(n_params - inactive)


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, kv_chunk: int,
               microbatches: int = 0):
    """Build + .lower() the right step for one cell.  Returns (lowered, meta).

    ``microbatches``: 0 = derive from this cfg.  Cost compiles must pass the
    FULL config's µ so the reduced-depth graphs share the real structure.
    """
    shape = get_shape(shape_name)
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    model = Model(cfg, mesh=mesh, batch_axes=batch_axes)
    params_abs = model.abstract()
    n_params = count_params(model.infos())

    if shape.kind == "train":
        opt = AdamW(lr=constant_lr(3e-4))
        batch_abs = input_specs(cfg, shape_name)
        mu = microbatches or pick_microbatches(cfg, shape, mesh)
        jitted, _ = shard_train_step(model, opt, mesh, batch_abs,
                                     kv_chunk=kv_chunk, donate=False,
                                     microbatches=mu)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        kind = "train"
    else:
        p_shard, _ = make_state_shardings(mesh, model)
        enc_len = shape.seq_len if cfg.family == "encdec" else 0
        img_len = cfg.num_image_tokens if cfg.family == "vlm" else 0
        n_batch_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))
        logits_bspec = batch_axes if shape.global_batch % n_batch_shards == 0 \
            else None
        logits_shard = NamedSharding(mesh, PS(logits_bspec, None, None))
        c_shard = cache_shardings(cfg, mesh, shape.global_batch,
                                  shape.seq_len, enc_len=enc_len,
                                  img_len=img_len)
        if shape.kind == "prefill":
            batch_abs = input_specs(cfg, shape_name)
            b_shard = make_batch_shardings(mesh, batch_abs)

            def fn(params, batch):
                return prefill(model, params, batch, kv_chunk=kv_chunk)

            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                             out_shardings=(logits_shard, c_shard))
            lowered = jitted.lower(params_abs, batch_abs)
            tokens = shape.global_batch * shape.seq_len
        else:                                            # decode
            spec = input_specs(cfg, shape_name)
            t_shard = make_batch_shardings(mesh, {"token": spec["token"]})

            def fn(params, cache, token):
                return decode_step(model, params, cache, token)

            jitted = jax.jit(
                fn, in_shardings=(p_shard, c_shard, t_shard["token"]),
                out_shardings=(logits_shard, c_shard))
            lowered = jitted.lower(params_abs, spec["cache"], spec["token"])
            tokens = shape.global_batch                   # one token / seq
        kind = "serve"
    return lowered, {"n_params": n_params, "tokens": tokens, "kind": kind}


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = RL.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def measure_scaled_cost(cfg: ModelConfig, shape_name: str, mesh,
                        kv_chunk: int):
    """Exact per-step cost via two fully-unrolled reduced-depth compiles.

    XLA cost analysis counts while-loop bodies ONCE, so the scanned
    full-depth module undercounts.  We compile 1-unit and 2-unit variants
    with every inner scan unrolled; the difference is exactly one layer
    group, and  total = cost(1) + (units-1) * Δ  is exact.
    """
    from repro.models.layers import set_inner_unroll
    units, make_cfg = unit_scaler(cfg)
    # µ comes from the FULL config: the reduced-depth cost graphs must share
    # the real step's microbatch structure (fully unrolled below)
    mu = pick_microbatches(cfg, get_shape(shape_name), mesh)
    set_inner_unroll(True)
    try:
        c1 = lower_cell(make_cfg(1), shape_name, mesh, kv_chunk,
                        microbatches=mu)[0].compile()
        f1, b1, coll1 = _cost_of(c1)
        del c1
        c2 = lower_cell(make_cfg(2), shape_name, mesh, kv_chunk,
                        microbatches=mu)[0].compile()
        f2, b2, coll2 = _cost_of(c2)
        del c2
    finally:
        set_inner_unroll(False)
    scale = units - 1
    # deltas can be slightly negative from XLA rewrite differences between
    # the two depths (e.g. a reduce pattern fusing differently); clamp —
    # a negative per-layer cost is physically meaningless.
    flops = f1 + scale * max(f2 - f1, 0.0)
    byts = b1 + scale * max(b2 - b1, 0.0)
    coll = {k: int(coll1[k] + scale * max(coll2[k] - coll1[k], 0))
            for k in coll1}
    return flops, byts, coll


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             kv_chunk: int = 2048, verbose: bool = True,
             skip_cost: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = cell_is_skipped(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    # ---- 1. full-config compile: the "it compiles and fits" proof --------
    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape_name, mesh, kv_chunk)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    del lowered, compiled

    # ---- 2. exact cost via unrolled two-point measurement ---------------
    if skip_cost:
        flops = byts = 0.0
        coll = {}
    else:
        flops, byts, coll = measure_scaled_cost(cfg, shape_name, mesh,
                                                kv_chunk)

    mflops = RL.model_flops(meta["n_params"], meta["tokens"], meta["kind"],
                            active_params=active_params(cfg,
                                                        meta["n_params"]))
    # decode: the mandatory per-token traffic is one read of weights + cache
    model_bytes = 0.0
    if shape.kind == "decode":
        cache_bytes = sum(
            float(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree_util.tree_leaves(
                input_specs(cfg, shape_name)["cache"]))
        model_bytes = meta["n_params"] * 2 + cache_bytes
    report = RL.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops_per_device=mflops / n_dev,
        model_bytes_per_device=model_bytes / n_dev,
        peak_memory_bytes=float(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes),
    )
    result = {
        "status": "ok", "num_params": meta["n_params"], "num_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        **report.to_dict(),
    }
    if verbose:
        gb = 1 << 30
        print(f"[{arch} × {shape_name} × {mesh_name}]"
              f" params={meta['n_params']/1e9:.2f}B"
              f" args={result['memory']['argument_bytes']/gb:.2f}GiB/dev"
              f" temp={result['memory']['temp_bytes']/gb:.2f}GiB/dev"
              f" flops/dev={report.flops_per_device:.3g}"
              f" coll/dev={report.coll_bytes_per_device/1e6:.1f}MB"
              f" bottleneck={report.bottleneck}"
              f" roofline={report.roofline_fraction:.2f}"
              f" (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", {k: v for k, v in result["memory"].items()})
        print("  cost_analysis: flops=%.4g bytes=%.4g" %
              (report.flops_per_device, report.bytes_per_device))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-chunk", type=int, default=2048)
    ap.add_argument("--skip-cost", action="store_true",
                    help="compile-proof only (multi-pod pass); roofline "
                         "terms come from the single-pod artifacts")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    ART_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                out = ART_DIR / f"{arch}_{shape_name}_{mesh_name}.json"
                if out.exists() and not args.force:
                    print(f"skip existing {out.name}")
                    continue
                try:
                    res = run_cell(arch, shape_name, multi_pod=multi,
                                   kv_chunk=args.kv_chunk,
                                   skip_cost=args.skip_cost)
                except Exception as e:                     # noqa: BLE001
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append(out.name)
                out.write_text(json.dumps(res, indent=2))
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
