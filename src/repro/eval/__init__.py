"""Recall evaluation plane: Hydra-style accuracy measurement for the fleet.

The CLIMBER++ headline claim is accuracy at scale; the two "Lernaean
Hydra" evaluations (PAPERS.md) set the bar for *how* to measure it —
multiple datasets, queries stratified by difficulty, and recall judged
against the data each configuration actually touched (a frontier, not a
point).  This package is that measurement plane:

* :mod:`repro.eval.datasets` — seeded tenant-sharded corpora (per-shard
  regimes, so routing has real signal) and hard/easy query splits
  stratified by ground-truth contrast;
* :mod:`repro.eval.ground_truth` — exact-kNN answers cached on disk,
  keyed by the generating parameters (seed changes invalidate);
* :mod:`repro.eval.metrics` — tie-aware recall@k, MAP, frontier AUC;
* :mod:`repro.eval.frontier` — the sweep runner behind
  ``benchmarks/bench_recall_frontier.py`` /
  ``artifacts/BENCH_recall_frontier.json``;
* :mod:`repro.eval.target` — recall-targeted planning: calibrate a
  partitions→recall curve from frontier cells and install a
  ``recall_target`` planner variant sized from the live
  ``fleet.partitions_touched`` histogram.
"""
from repro.eval.datasets import (TenantCorpus, hardness_split,
                                 perturbed_queries, tenant_corpus)
from repro.eval.frontier import FrontierSpec, build_eval_fleet, run_frontier
from repro.eval.ground_truth import GroundTruthCache
from repro.eval.metrics import (frontier_auc, mean_average_precision,
                                recall_at_k)
from repro.eval.target import RecallCalibration, install_recall_target

__all__ = [
    "TenantCorpus", "tenant_corpus", "perturbed_queries", "hardness_split",
    "GroundTruthCache", "recall_at_k", "mean_average_precision",
    "frontier_auc", "FrontierSpec", "run_frontier", "build_eval_fleet",
    "RecallCalibration", "install_recall_target",
]
