"""Recall / ranking metrics for approximate-kNN evaluation.

The Hydra papers' central lesson is that approximate data-series search
must be judged by *accuracy per unit of data touched*, measured carefully:
ties at the k-th distance boundary must not be scored as misses (any
record at exactly the boundary distance is as correct as the one the
oracle happened to return), and pad sentinel rows (``gid = -1`` /
:data:`repro.core.refine.PAD_DIST`) must be excluded on both sides.

Everything here is pure numpy over ``(dist, gid)`` answer arrays in the
fleet's wire shape — ``[Q, k]`` ascending distance, ``-1``-padded ids.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["recall_at_k", "mean_average_precision", "frontier_auc"]


def _valid(ids: np.ndarray) -> np.ndarray:
    return ids[ids >= 0]


def recall_at_k(approx_ids: np.ndarray, exact_ids: np.ndarray,
                k: Optional[int] = None, *,
                approx_dist: Optional[np.ndarray] = None,
                exact_dist: Optional[np.ndarray] = None,
                tie_tol: float = 1e-5) -> float:
    """Mean fraction of the true k nearest neighbours returned.

    Args:
      approx_ids / exact_ids: ``[Q, >=k]`` id arrays; ``-1`` marks pad
        slots and is excluded on both sides.
      k: evaluate the first ``k`` columns (default: exact answer width).
      approx_dist / exact_dist: when both are given, ties are handled:
        an approximate id *not* in the exact id set still counts as a hit
        if its distance is within ``tie_tol`` of the k-th exact distance —
        the oracle's choice among boundary-equidistant records is
        arbitrary, so any of them is correct.

    Returns the mean over queries with a non-empty exact answer (1.0 when
    no query has one).
    """
    approx_ids = np.asarray(approx_ids)
    exact_ids = np.asarray(exact_ids)
    k = k or exact_ids.shape[1]
    per_query = []
    for i in range(len(exact_ids)):
        truth = _valid(exact_ids[i, :k])
        if truth.size == 0:
            continue
        got = _valid(approx_ids[i, :k])
        hits = np.isin(got, truth).sum()
        if approx_dist is not None and exact_dist is not None:
            boundary = exact_dist[i, :k][exact_ids[i, :k] >= 0].max()
            tied = (~np.isin(got, truth)) \
                & (approx_dist[i, :k][approx_ids[i, :k] >= 0]
                   <= boundary + tie_tol)
            hits = min(int(hits + tied.sum()), truth.size)
        per_query.append(hits / truth.size)
    return float(np.mean(per_query)) if per_query else 1.0


def mean_average_precision(approx_ids: np.ndarray,
                           exact_ids: np.ndarray,
                           k: Optional[int] = None) -> float:
    """MAP@k: order-sensitive quality of the returned ranking.

    Average precision rewards placing true neighbours early: for each
    approximate rank holding a true neighbour, take the precision of the
    prefix up to it, and average over the number of true neighbours.  Pad
    slots (``id < 0``) are skipped without occupying a rank.
    """
    approx_ids = np.asarray(approx_ids)
    exact_ids = np.asarray(exact_ids)
    k = k or exact_ids.shape[1]
    per_query = []
    for i in range(len(exact_ids)):
        truth = set(int(x) for x in _valid(exact_ids[i, :k]))
        if not truth:
            continue
        hits, precisions, rank = 0, [], 0
        for g in approx_ids[i, :k]:
            if g < 0:
                continue
            rank += 1
            if int(g) in truth:
                hits += 1
                precisions.append(hits / rank)
        per_query.append(sum(precisions) / len(truth))
    return float(np.mean(per_query)) if per_query else 1.0


def frontier_auc(points: Sequence[Tuple[float, float]]) -> float:
    """Area under a (cost, recall) frontier, normalised to [0, 1].

    ``points`` are ``(fraction_of_data_scanned, recall)`` pairs from one
    sweep (any order; deduplicated on cost by best recall).  The curve is
    extended flat to cost 1.0 from its last point and starts at
    ``(min_cost, its recall)`` — so AUC rewards reaching high recall at
    *low* cost, the Hydra frontier criterion.  One point yields its recall
    × the covered interval.  Empty input yields 0.
    """
    if not points:
        return 0.0
    best = {}
    for c, r in points:
        c = float(min(max(c, 0.0), 1.0))
        best[c] = max(best.get(c, 0.0), float(r))
    xs = sorted(best)
    # step-function integral (conservative: recall holds until the next
    # measured cost), extended flat to cost 1.0
    auc, prev_x = 0.0, xs[0]
    for i, x in enumerate(xs[1:], 1):
        auc += best[xs[i - 1]] * (x - prev_x)
        prev_x = x
    auc += best[xs[-1]] * (1.0 - prev_x)
    span = 1.0 - xs[0]
    return auc / span if span > 0 else best[xs[-1]]
