"""Exact ground-truth caching for recall evaluation.

Exact kNN over the corpus union is the one cost in the harness that
dwarfs everything else and never changes for a fixed (corpus, queries, k)
triple, so it is computed once and cached on disk.  The cache key is a
content hash of the *generating parameters* (corpus meta + query spec +
k), not the arrays — change any seed, size, or noise level and the key
changes with it, so a stale truth can never be read back for a different
dataset (tested).
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import numpy as np

from repro.baselines.dss import exact_knn

__all__ = ["GroundTruthCache"]


class GroundTruthCache:
    """Disk cache of exact kNN answers keyed by dataset identity."""

    def __init__(self, cache_dir: Path):
        self.cache_dir = Path(cache_dir)
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key_for(meta: Dict) -> str:
        """Stable content hash of the generating parameters."""
        blob = json.dumps(meta, sort_keys=True,
                          separators=(",", ":")).encode()
        return hashlib.sha1(blob).hexdigest()[:16]

    def _path(self, key: str) -> Path:
        return self.cache_dir / f"gt_{key}.npz"

    def get(self, meta: Dict) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        p = self._path(self.key_for(meta))
        if not p.exists():
            return None
        with np.load(p) as z:
            self.hits += 1
            return z["dist"], z["idx"]

    def put(self, meta: Dict, dist: np.ndarray, idx: np.ndarray) -> None:
        p = self._path(self.key_for(meta))
        tmp = p.with_suffix(".tmp.npz")
        np.savez(tmp, dist=dist, idx=idx,
                 meta=json.dumps(meta, sort_keys=True))
        tmp.replace(p)          # atomic: a reader never sees a half write

    def exact(self, meta: Dict, queries: np.ndarray, data: np.ndarray,
              k: int, *, chunk: int = 2048
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Cached exact kNN: ``(dist [Q, k] ascending, idx [Q, k])``.

        ``meta`` must uniquely describe ``(queries, data, k)`` — the
        caller passes the corpus/query generating parameters, and ``k``
        is folded in here.
        """
        full_meta = dict(meta, k=int(k))
        cached = self.get(full_meta)
        if cached is not None:
            return cached
        self.misses += 1
        dist, idx = exact_knn(queries, data, k, chunk=chunk)
        dist, idx = np.asarray(dist), np.asarray(idx)
        self.put(full_meta, dist, idx)
        return dist, idx
