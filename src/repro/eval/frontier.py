"""The recall-frontier runner: sweep the fleet and measure accuracy/cost.

One :func:`run_frontier` call sweeps, per dataset and shard count:

* **routing modes** — exhaustive (the lossless ceiling), fixed
  top-``fanout`` signature routing at several fanouts (the baseline
  frontier), and adaptive score-mass routing at several thresholds plus
  the threshold *learned* from audit traces
  (:meth:`~repro.fleet.fleet.IndexFleet.calibrate_routing`);
* **planner spend** — the ``adaptive`` planner against recall-targeted
  variants at several spend factors
  (:func:`repro.core.query.make_recall_target_planner`) and against
  reduced slot budgets (``query_max_slots``);

and scores every cell with tie-aware recall@k, MAP, and the data-touched
costs, stratified over hard / easy query splits
(:func:`repro.eval.datasets.hardness_split`).  The output document (one
JSON artifact, ``artifacts/BENCH_recall_frontier.json``) carries:

* ``cells`` — flat metric rows, compare.py/bench-trend compatible;
* ``frontiers`` — per (dataset, shards, split) the (fraction-scanned,
  recall) curves for fixed vs adaptive routing with step AUC
  (:func:`repro.eval.metrics.frontier_auc`);
* ``routed_gap`` — for each adaptive cell, the fixed-fanout curve's
  recall interpolated at the *same* candidates-scanned cost: the
  apples-to-apples evidence that per-query fan-out moves the frontier
  rather than just sliding along it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.query import register_recall_target
from repro.eval.datasets import (TenantCorpus, hardness_split,
                                 perturbed_queries, tenant_corpus)
from repro.eval.ground_truth import GroundTruthCache
from repro.eval.metrics import (frontier_auc, mean_average_precision,
                                recall_at_k)
from repro.fleet.fleet import FleetConfig, IndexFleet
from repro.utils.config import ClimberConfig

__all__ = ["FrontierSpec", "run_frontier", "build_eval_fleet"]


@dataclass(frozen=True)
class FrontierSpec:
    """Everything that identifies one frontier sweep (seeds included)."""

    datasets: Tuple[str, ...] = ("randomwalk", "seismic")
    shard_counts: Tuple[int, ...] = (1, 4)
    shard_size: int = 1200
    series_len: int = 96
    num_queries: int = 48
    num_calibration: int = 32       # held-out queries for learn_threshold
    k: int = 10
    fanouts: Tuple[int, ...] = (1, 2, 3)
    thresholds: Tuple[float, ...] = (0.3, 0.6, 0.85, 0.95)
    spend_factors: Tuple[float, ...] = (1.0, 2.0, 4.0)
    slot_budgets: Tuple[int, ...] = (4, 16)   # query_max_slots overrides
    target_recall: float = 0.95     # calibrate_routing goal
    affinity: float = 0.6           # tenant motif strength
    noise: float = 0.1              # query perturbation
    seed: int = 0

    def shard_cfg(self) -> ClimberConfig:
        return ClimberConfig(
            series_len=self.series_len,
            paa_segments=max(self.series_len // 8, 1),
            num_pivots=48, prefix_len=6, capacity=128, sample_frac=0.25,
            max_centroids=16, k=self.k, candidate_groups=6,
            adaptive_factor=4)


def build_eval_fleet(corpus: TenantCorpus,
                     spec: FrontierSpec) -> IndexFleet:
    """One sealed shard per corpus tenant; no plan cache (every cell must
    re-plan — the sweep mutates planner registrations and slot budgets)."""
    fcfg = FleetConfig(shard_cfg=spec.shard_cfg(), fanout=2,
                       plan_cache_size=0, seed=spec.seed)
    fleet = IndexFleet(fcfg)
    for i, block in enumerate(corpus.shards):
        fleet.add_shard(f"tenant{i}", block)
    return fleet


def _set_slot_budget(fleet: IndexFleet, budget: Optional[int]) -> None:
    """Apply a ``query_max_slots`` override to every sealed shard in place
    (and invalidate the device placement, which bakes plan widths in)."""
    for h in fleet.shards:
        cfg = h.index.cfg.replace(query_max_slots=budget)
        h.index = dataclasses.replace(h.index, cfg=cfg)
    with fleet._lock:
        fleet._invalidate_placement()


def _splits(exact_dist: np.ndarray, k: int,
            qn: int) -> Dict[str, np.ndarray]:
    hard, easy = hardness_split(exact_dist, k)
    return {"all": np.arange(qn), "hard": hard, "easy": easy}


def _measure(fleet: IndexFleet, queries: np.ndarray, k: int,
             gt_dist: np.ndarray, gt_idx: np.ndarray,
             splits: Dict[str, np.ndarray], identity: Dict,
             **query_kw) -> List[Dict]:
    """Run one fleet.query sweep cell and emit one metric row per split."""
    dist, gid, info = fleet.query(queries, k, **query_kw)
    rows = []
    for split, idx in splits.items():
        if len(idx) == 0:
            continue
        rows.append(dict(
            identity, split=split,
            recall=recall_at_k(gid[idx], gt_idx[idx, :k],
                               approx_dist=dist[idx],
                               exact_dist=gt_dist[idx, :k]),
            map=mean_average_precision(gid[idx], gt_idx[idx, :k]),
            mean_candidates_scanned=float(
                info.candidates_scanned[idx].mean()),
            mean_partitions_touched=float(
                info.partitions_touched[idx].mean()),
            mean_fanout=float(info.routed_mask[idx].sum(axis=1).mean())
            if info.routed_mask.size else 0.0,
        ))
    return rows


def _frontier_points(cells: Sequence[Dict], total: int
                     ) -> List[Tuple[float, float]]:
    return sorted((c["mean_candidates_scanned"] / total, c["recall"])
                  for c in cells)


def _interp_recall(points: Sequence[Tuple[float, float]],
                   cost: float) -> float:
    """Recall of a frontier at ``cost``, linearly interpolated (clamped to
    the endpoints) — the matched-cost baseline for ``routed_gap``."""
    if not points:
        return 0.0
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    return float(np.interp(cost, xs, ys))


def run_frontier(spec: FrontierSpec, *,
                 cache_dir: Optional[Path] = None,
                 progress=None) -> Dict:
    """Execute the full sweep; returns the artifact document (pure data)."""
    say = progress or (lambda *_: None)
    gt_cache = GroundTruthCache(cache_dir) if cache_dir else None
    cells: List[Dict] = []
    frontiers: List[Dict] = []
    routed_gap: List[Dict] = []

    for ds in spec.datasets:
        for shards in spec.shard_counts:
            say(f"{ds} x {shards} shards: corpus + ground truth")
            corpus = tenant_corpus(
                ds, num_shards=shards, shard_size=spec.shard_size,
                series_len=spec.series_len, seed=spec.seed,
                affinity=spec.affinity)
            queries = perturbed_queries(corpus, spec.num_queries,
                                        noise=spec.noise, seed=spec.seed)
            calib_q = perturbed_queries(corpus, spec.num_calibration,
                                        noise=spec.noise,
                                        seed=spec.seed + 1)
            union = corpus.union
            meta = dict(corpus.meta(), num_queries=spec.num_queries,
                        noise=spec.noise, qseed=spec.seed)
            # 2k true neighbours: k for recall, 2k for the hardness ratio
            if gt_cache is not None:
                gt_dist, gt_idx = gt_cache.exact(meta, queries, union,
                                                 2 * spec.k)
            else:
                from repro.baselines.dss import exact_knn
                gt_dist, gt_idx = map(np.asarray, exact_knn(
                    queries, union, 2 * spec.k, chunk=2048))
            splits = _splits(gt_dist, spec.k, len(queries))
            fleet = build_eval_fleet(corpus, spec)
            base = {"dataset": ds, "shards": shards,
                    "num_queries": spec.num_queries, "k": spec.k,
                    "slot_budget": 0, "variant": "adaptive"}

            # -- routing sweep (default budget, adaptive planner) --------
            say(f"{ds} x {shards}: routing sweep")
            exh = _measure(fleet, queries, spec.k, gt_dist, gt_idx, splits,
                           dict(base, routing="exhaustive", param="-"),
                           routing="exhaustive")
            cells += exh
            fixed_cells: Dict[str, List[Dict]] = {s: [] for s in splits}
            adapt_cells: Dict[str, List[Dict]] = {s: [] for s in splits}
            if shards > 1:
                for fo in spec.fanouts:
                    if fo > shards:
                        continue
                    rows = _measure(
                        fleet, queries, spec.k, gt_dist, gt_idx, splits,
                        dict(base, routing="signature",
                             param=f"fanout={fo}"),
                        routing="signature", fanout=fo)
                    cells += rows
                    for r in rows:
                        fixed_cells[r["split"]].append(r)
                # matched-cost baseline needs the ceiling too: top-S ==
                # exhaustive fan-out, at the exhaustive cell's cost
                for r in exh:
                    fixed_cells[r["split"]].append(r)
                for th in spec.thresholds:
                    rows = _measure(
                        fleet, queries, spec.k, gt_dist, gt_idx, splits,
                        dict(base, routing="adaptive", param=f"th={th}"),
                        routing="adaptive", threshold=th)
                    cells += rows
                    for r in rows:
                        adapt_cells[r["split"]].append(r)
                # learned threshold: audit on held-out queries, calibrate
                fleet.audit_routing(calib_q, spec.k, record=True)
                learned = fleet.calibrate_routing(spec.target_recall)
                rows = _measure(
                    fleet, queries, spec.k, gt_dist, gt_idx, splits,
                    dict(base, routing="adaptive",
                         param=f"learned={learned:.2f}"),
                    routing="adaptive")
                cells += rows
                for r in rows:
                    adapt_cells[r["split"]].append(r)

                total = len(union)
                for split in splits:
                    fpts = _frontier_points(fixed_cells[split], total)
                    apts = _frontier_points(adapt_cells[split], total)
                    frontiers.append({
                        "dataset": ds, "shards": shards, "split": split,
                        "fixed": fpts, "adaptive": apts,
                        "fixed_auc": frontier_auc(fpts),
                        "adaptive_auc": frontier_auc(apts)})
                    cells.append({
                        "dataset": ds, "shards": shards, "split": split,
                        "curve": "fixed",
                        "recall_frontier_auc": frontier_auc(fpts)})
                    cells.append({
                        "dataset": ds, "shards": shards, "split": split,
                        "curve": "adaptive",
                        "recall_frontier_auc": frontier_auc(apts)})
                    for c in adapt_cells[split]:
                        cost = c["mean_candidates_scanned"] / total
                        fixed_at = _interp_recall(fpts, cost)
                        routed_gap.append({
                            "dataset": ds, "shards": shards,
                            "split": split, "param": c["param"],
                            "frac_scanned": cost,
                            "adaptive_recall": c["recall"],
                            "fixed_recall_at_cost": fixed_at,
                            "improvement": c["recall"] - fixed_at})

            # -- planner spend sweep (exhaustive routing isolates it) ----
            say(f"{ds} x {shards}: planner spend sweep")
            for spend in spec.spend_factors:
                register_recall_target(spend)
                cells += _measure(
                    fleet, queries, spec.k, gt_dist, gt_idx, splits,
                    dict(base, routing="exhaustive",
                         param=f"spend={spend:g}",
                         variant="recall_target"),
                    routing="exhaustive", variant="recall_target")
            for budget in spec.slot_budgets:
                _set_slot_budget(fleet, budget)
                cells += _measure(
                    fleet, queries, spec.k, gt_dist, gt_idx, splits,
                    dict(base, routing="exhaustive", param="-",
                         slot_budget=budget),
                    routing="exhaustive", variant="adaptive")
            _set_slot_budget(fleet, None)

    doc = {"spec": dataclasses.asdict(spec), "cells": cells,
           "frontiers": frontiers, "routed_gap": routed_gap}
    if gt_cache is not None:
        doc["ground_truth_cache"] = {"hits": gt_cache.hits,
                                     "misses": gt_cache.misses}
    return doc
