"""Recall-targeted planning: "spend slots until predicted recall ≥ X".

The frontier artifact measures, per corpus, how recall grows with the
partitions a query touches.  :class:`RecallCalibration` turns those
measurements into a monotone partitions→recall curve; given a live fleet,
:func:`install_recall_target` reads the *actual* per-query touch
distribution from the ``fleet.partitions_touched`` histogram
(:attr:`IndexFleet.touched_hist`), asks the curve how many partitions the
recall target needs, and registers a
:func:`~repro.core.query.make_recall_target_planner` variant whose spend
factor closes the gap.  Re-installation with a new target just
re-registers the variant and bumps the fleet's placement epoch (cached
plans key on it, so stale plans can't serve).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.query import register_recall_target

__all__ = ["RecallCalibration", "install_recall_target"]


@dataclass(frozen=True)
class RecallCalibration:
    """Monotone partitions-touched → recall curve from measured cells."""

    partitions: Tuple[float, ...]   # ascending mean partitions touched
    recalls: Tuple[float, ...]      # non-decreasing recall envelope

    @classmethod
    def from_cells(cls, cells: Sequence[Dict]) -> "RecallCalibration":
        """Fit from frontier cells (any rows carrying both
        ``mean_partitions_touched`` and ``recall``).  The curve keeps the
        best recall seen at or below each cost — an upper envelope, so
        prediction is optimistic-monotone rather than noisy."""
        pts = sorted((float(c["mean_partitions_touched"]),
                      float(c["recall"])) for c in cells
                     if "mean_partitions_touched" in c and "recall" in c)
        if not pts:
            raise ValueError("no cells with partition/recall measurements")
        parts, recs, best = [], [], 0.0
        for p, r in pts:
            best = max(best, r)
            parts.append(p)
            recs.append(best)
        return cls(partitions=tuple(parts), recalls=tuple(recs))

    def predict(self, partitions: float) -> float:
        """Predicted recall at a partitions-touched budget (clamped)."""
        return float(np.interp(partitions, self.partitions, self.recalls))

    def partitions_for(self, target_recall: float) -> float:
        """Smallest measured partitions budget predicted to reach the
        target (the largest measured budget when nothing does)."""
        for p, r in zip(self.partitions, self.recalls):
            if r >= target_recall:
                return p
        return self.partitions[-1]


def install_recall_target(fleet, target_recall: float,
                          calibration: RecallCalibration, *,
                          name: str = "recall_target",
                          max_spend: float = 8.0) -> float:
    """Register a planner variant sized to hit ``target_recall`` on
    ``fleet``; returns the chosen spend factor.

    The current operating point is the fleet's live per-query
    partitions-touched median (``fleet.touched_hist`` — populated by
    every :meth:`~repro.fleet.fleet.IndexFleet.query` call); when the
    histogram is empty the calibration curve's smallest budget stands in.
    The spend factor is the ratio of the partitions the target needs to
    the partitions currently spent, clamped to ``[1, max_spend]``.
    """
    live_p50 = fleet.touched_hist.quantile(0.5)
    current = live_p50 if live_p50 > 0 else calibration.partitions[0]
    needed = calibration.partitions_for(target_recall)
    spend = min(max(needed / max(current, 1e-9), 1.0), max_spend)
    register_recall_target(spend, name=name)
    with fleet._lock:
        fleet._invalidate_placement()   # cached plans key on the epoch
    return spend
