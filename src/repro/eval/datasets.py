"""Seeded evaluation corpora: tenant-sharded datasets + stratified queries.

Two things distinguish an honest routed-recall evaluation from a toy one:

* **Shards must have structure.**  Slicing one iid dataset into S shards
  puts every query's neighbours uniformly across all shards — no router
  can beat random shard choice and routed recall is capped at
  ``fanout / S`` regardless of algorithm.  Real fleets shard by tenant /
  time range, where a shard's records share provenance.
  :func:`tenant_corpus` reproduces that: each shard mixes a shard-specific
  *motif* (a smooth random series, the "tenant regime") into its records
  at ``affinity`` strength, so nearest neighbours concentrate in the
  owning shard and signature routing has a real signal to learn.

* **Queries must be stratified by difficulty.**  Mean recall over random
  queries hides the tail; the Hydra evaluations split queries into hard /
  easy by how contrasted the true answer is.  :func:`hardness_split` uses
  the ground-truth **contrast ratio** ``d_2k / d_k`` — the gap between
  the k-th neighbour and the next k.  A low ratio means many near-ties
  just outside the answer set: exactly the queries approximate search
  gets wrong first.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paa import znormalize
from repro.data.series import GENERATORS

__all__ = ["TenantCorpus", "tenant_corpus", "perturbed_queries",
           "hardness_split"]


@dataclass(frozen=True)
class TenantCorpus:
    """A sharded evaluation dataset with per-tenant structure."""

    name: str                        # base generator name
    shards: Tuple[np.ndarray, ...]   # per-tenant [n_i, n] float32 blocks
    seed: int
    affinity: float

    @property
    def union(self) -> np.ndarray:
        return np.concatenate(self.shards, axis=0)

    def meta(self) -> Dict:
        """Identity of this corpus — keys the ground-truth cache."""
        return {"name": self.name, "seed": self.seed,
                "affinity": self.affinity,
                "shard_sizes": [int(len(s)) for s in self.shards],
                "series_len": int(self.shards[0].shape[1])}


def _motif(key: jax.Array, length: int) -> jnp.ndarray:
    """One tenant's regime: a smooth (random-walk) signature series."""
    walk = jnp.cumsum(jax.random.normal(key, (length,)), axis=-1)
    return znormalize(walk[None, :])[0]


def tenant_corpus(name: str, *, num_shards: int, shard_size: int,
                  series_len: int, seed: int = 0,
                  affinity: float = 0.8) -> TenantCorpus:
    """Build a per-tenant sharded corpus from base generator ``name``.

    Each shard draws ``shard_size`` series from ``GENERATORS[name]`` under
    its own subkey and mixes in the shard's motif at ``affinity`` (0 = iid
    slicing, the router-hostile degenerate case; 1 = pure motif).  All
    rows are re-z-normalised after mixing, so shards are comparable under
    ED.
    """
    if name not in GENERATORS:
        raise KeyError(f"unknown generator {name!r}; "
                       f"have {sorted(GENERATORS)}")
    root = jax.random.PRNGKey(seed)
    shards: List[np.ndarray] = []
    for i in range(num_shards):
        kd, km = jax.random.split(jax.random.fold_in(root, i))
        base = GENERATORS[name](kd, shard_size, series_len)
        motif = _motif(km, series_len)
        mixed = znormalize((1.0 - affinity) * base
                           + affinity * motif[None, :])
        shards.append(np.asarray(mixed, np.float32))
    return TenantCorpus(name=name, shards=tuple(shards), seed=seed,
                        affinity=affinity)


def perturbed_queries(corpus: TenantCorpus, num_queries: int, *,
                      noise: float = 0.05, seed: int = 0) -> np.ndarray:
    """Queries near — not identical to — corpus rows (paper §VII-A draws
    queries from the dataset; the perturbation keeps the true neighbour
    non-trivial while preserving each query's tenant provenance)."""
    union = corpus.union
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    ki, kn = jax.random.split(key)
    idx = np.asarray(jax.random.choice(ki, union.shape[0],
                                       shape=(num_queries,), replace=False))
    jitter = np.asarray(jax.random.normal(kn, (num_queries,
                                               union.shape[1])))
    q = union[idx] + noise * jitter
    return np.asarray(znormalize(jnp.asarray(q)), np.float32)


def hardness_split(exact_dist: np.ndarray, k: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Split query indices into (hard, easy) halves by answer contrast.

    ``exact_dist`` is the ``[Q, >=2k]`` ascending true-distance matrix.
    Contrast is ``d[2k-1] / d[k-1]`` (≥ 1): small means the true top-k is
    barely separated from the next k — near-ties an approximate search
    drops first.  The lower-contrast half is *hard*.  Deterministic
    (stable argsort on the ratio, ties broken by index).
    """
    exact_dist = np.asarray(exact_dist)
    if exact_dist.shape[1] < 2 * k:
        raise ValueError(f"need >= 2k={2 * k} true distances per query, "
                         f"got {exact_dist.shape[1]}")
    dk = np.maximum(exact_dist[:, k - 1], 1e-12)
    contrast = exact_dist[:, 2 * k - 1] / dk
    order = np.argsort(contrast, kind="stable")
    half = len(order) // 2
    return order[:half], order[half:]
