"""EXPERIMENTS.md table generator — fills the placeholder markers from the
dry-run/perf artifacts.

    PYTHONPATH=src python -m repro.utils.report
"""
from __future__ import annotations

import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
ART = REPO / "artifacts"


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | params | args GiB/dev | temp GiB/dev | "
            "compile s | status |",
            "|---|---|---|---|---|---|---|---|"]
    for f in sorted((ART / "dryrun").glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                        f"| — | — | skipped (long-context rule) |")
        elif d.get("status") == "ok":
            mem = d.get("memory", {})
            npar = d.get("num_params", 0)
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} "
                f"| {npar/1e9:.2f}B "
                f"| {_fmt_bytes(mem.get('argument_bytes', 0))} "
                f"| {_fmt_bytes(mem.get('temp_bytes', 0))} "
                f"| {d.get('compile_s', 0)} | ok |")
        else:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                        f"| — | — | ERROR |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful-FLOPs ratio | roofline frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    levers = {
        ("train", "memory"): "bf16 flash operands; fewer saved f32 copies",
        ("train", "collective"): "lower µ / FSDP gather amortisation",
        ("prefill", "memory"): "chunked (Sarathi) prefill; bf16 operands",
        ("decode", "collective"): "masked cache write (kill DUS reshard)",
        ("decode", "memory"): "kv-head sharding / cache dtype",
    }
    for f in sorted((ART / "dryrun").glob("*_16x16.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok" or "compute_s" not in d:
            continue
        kind = ("decode" if d["shape"] in ("decode_32k", "long_500k")
                else ("prefill" if "prefill" in d["shape"] else "train"))
        lever = levers.get((kind, d["bottleneck"]), "sharding/layout")
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.4f} "
            f"| {d['memory_s']:.4f} | {d['collective_s']:.4f} "
            f"| **{d['bottleneck']}** | {d['useful_flops_ratio']:.2f} "
            f"| {d['roofline_fraction']:.3f} | {lever} |")
    return "\n".join(rows)


def climber_table() -> str:
    rows = ["| step | mesh | compute s | memory s | collective s | "
            "bottleneck | roofline frac | temp GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for f in sorted((ART / "dryrun").glob("climber_*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            continue
        rows.append(
            f"| {d['shape']} | {d['mesh']} | {d['compute_s']:.4f} "
            f"| {d['memory_s']:.4f} | {d['collective_s']:.4f} "
            f"| **{d['bottleneck']}** | {d['roofline_fraction']:.3f} "
            f"| {_fmt_bytes(d['memory']['temp_bytes'])} |")
    return "\n".join(rows)


def perf_table() -> str:
    groups: dict = {}
    for f in sorted((ART / "perf").glob("*.json")) if (ART / "perf").exists() \
            else []:
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            continue
        groups.setdefault((d["arch"], d["shape"]), []).append(d)
    out = []
    for (arch, shape), ds in groups.items():
        out.append(f"**{arch} × {shape}**\n")
        out.append("| variant | compute s | memory s | collective s | "
                   "bound s | bottleneck | frac | temp GiB |")
        out.append("|---|---|---|---|---|---|---|---|")
        for d in ds:
            bound = max(d["compute_s"], d["memory_s"], d["collective_s"])
            out.append(
                f"| {d.get('variant','baseline')} | {d['compute_s']:.4f} "
                f"| {d['memory_s']:.4f} | {d['collective_s']:.4f} "
                f"| {bound:.4f} | {d['bottleneck']} "
                f"| {d['roofline_fraction']:.3f} "
                f"| {_fmt_bytes(d['memory']['temp_bytes'])} |")
        out.append("")
    return "\n".join(out)


def fill(marker: str, content: str, text: str) -> str:
    """Idempotent: replaces everything between <!-- X --> and <!-- /X -->."""
    tag, end = f"<!-- {marker} -->", f"<!-- /{marker} -->"
    if tag not in text or end not in text:
        return text
    head = text[: text.index(tag) + len(tag)]
    tail = text[text.index(end):]
    return head + "\n\n" + content + "\n\n" + tail


def main():
    exp = REPO / "EXPERIMENTS.md"
    text = exp.read_text()
    # strip previously generated tables back to markers
    text = fill("DRYRUN_TABLE", dryrun_table(), text)
    text = fill("ROOFLINE_TABLE", roofline_table(), text)
    text = fill("CLIMBER_TABLE", climber_table(), text)
    text = fill("PERF_LOG", perf_table(), text)
    exp.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
