from repro.utils.config import ClimberConfig, ModelConfig, ShapeConfig, SHAPES, get_shape

__all__ = ["ClimberConfig", "ModelConfig", "ShapeConfig", "SHAPES", "get_shape"]
