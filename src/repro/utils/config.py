"""Configuration system for the CLIMBER framework.

Two families of configs:
  * :class:`ClimberConfig` — the paper's retrieval plane (feature extraction,
    indexing and query parameters; defaults follow Section VII-A of the paper:
    r=200 pivots, prefix m=10, K=500, CLIMBER-kNN-Adaptive-4X).
  * :class:`ModelConfig` — the model plane (the assigned architecture pool).

Plain dataclasses; everything is explicit and serialisable so that configs can
be embedded in checkpoints and dry-run artifacts.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class ClimberConfig:
    """Parameters of CLIMBER-FX / CLIMBER-INX / CLIMBER-kNN."""

    # --- feature extraction (CLIMBER-FX, paper §IV) ---
    series_len: int = 256          # n — raw data-series length
    paa_segments: int = 16         # w — PAA word length
    num_pivots: int = 200          # r — pivots in the system (paper default)
    prefix_len: int = 10           # m — pivot-permutation-prefix length
    decay: str = "exp"             # pivot-weight decay: "exp" | "linear"
    decay_lambda: float = 0.5      # λ for exponential decay (paper Example 1)

    # --- indexing (CLIMBER-INX, paper §V) ---
    capacity: int = 3000           # c — partition capacity constraint (Def. 12)
    sample_frac: float = 0.1       # α — skeleton sample fraction
    centroid_min_od: int = 2       # ε — min OD between accepted centroids (Alg. 2)
    max_centroids: int = 64        # optional stopping condition (Alg. 2)

    # --- query processing (paper §VI) ---
    k: int = 500                   # K — kNN answer size (paper default 500)
    candidate_groups: int = 4      # T — groups retained for tie-breaking
    adaptive_factor: int = 4       # 1 => CLIMBER-kNN; 2/4 => Adaptive-2X/4X
    base_partitions: int = 1       # partitions CLIMBER-kNN may touch
    query_max_slots: Optional[int] = None
                                   # static slot budget for compact_plan
                                   # (None => the lossless per-variant default
                                   # from repro.core.query.default_slot_budget)

    # --- implementation detail (static shapes for XLA) ---
    partition_pad: Optional[int] = None  # physical slot count per partition
                                         # (defaults to capacity at build)

    def __post_init__(self):
        if self.prefix_len > self.num_pivots:
            raise ValueError("prefix_len (m) must be <= num_pivots (r)")
        if self.series_len % self.paa_segments != 0:
            raise ValueError("series_len must be divisible by paa_segments")
        if self.decay not in ("exp", "linear"):
            raise ValueError(f"unknown decay {self.decay!r}")
        if not (0.0 < self.sample_frac <= 1.0):
            raise ValueError("sample_frac must be in (0, 1]")

    @property
    def max_partitions(self) -> int:
        """MaxNumPartitions cap for the adaptive algorithm."""
        return self.base_partitions * self.adaptive_factor

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ClimberConfig":
        return cls(**json.loads(s))

    def replace(self, **kw) -> "ClimberConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture from the public pool.

    ``family`` selects the compute graph:
      dense | moe | ssm | hybrid | encdec | vlm
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // num_heads

    # positional / attention details
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # MLA (minicpm3)
    use_mla: bool = False
    kv_lora_rank: int = 256
    q_lora_rank: int = 768
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # hybrid (zamba2): one shared attention block applied every k layers
    hybrid_attn_every: int = 6

    # enc-dec (whisper)
    num_encoder_layers: int = 0

    # vlm (llama-3.2-vision): cross-attn layer inserted every k layers
    cross_attn_every: int = 0
    num_image_tokens: int = 1024

    # training
    dtype: str = "bfloat16"
    remat: str = "dots"              # "none" | "dots" | "full"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs run the long_500k cell."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str              # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; valid: {[s.name for s in SHAPES]}")
