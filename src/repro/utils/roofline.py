"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch, shape, mesh) cell — all in seconds, per device:

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16 v5e)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = collective_bytes_per_device / link_bw    (~50 GB/s/link ICI)

``compiled.cost_analysis()`` supplies flops and bytes (the partitioned,
per-device module).  Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO text and sum the shaped-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Size of one shaped buffer like ``bf16[8,2048,512]``."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _result_bytes(line: str, op: str) -> int:
    """Bytes of an HLO instruction's result.

    Handles tuple results (async ``-start`` ops carry (operand, result, ...)
    tuples — we take the largest member, the actual payload, to avoid
    double-counting the alias slots).
    """
    rhs = line.split("=", 1)[1] if "=" in line else line
    # everything before the op keyword is the result type annotation
    pos = rhs.find(f" {op}")
    head = rhs[:pos] if pos >= 0 else rhs.split("(", 1)[0]
    sizes = []
    for m in _SHAPE_RE.finditer(head):
        dt, dims = m.groups()
        b = _DTYPE_BYTES.get(dt, 0)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * b)
    if not sizes:
        return 0
    is_start = f"{op}-start(" in rhs
    return max(sizes) if (is_start and len(sizes) > 1) else sum(sizes)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes of every collective in the HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        for op in _COLLECTIVE_OPS:
            # match op name at the call position: "... = TYPE op-name("
            if re.search(rf"\b{op}(?:-start)?\(", rhs):
                # count -start, skip -done (avoid double counting pairs)
                if f"{op}-done(" in rhs:
                    break
                out[op] += _result_bytes(ls, op)
                break
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    model_flops_per_device: float = 0.0
    peak_memory_bytes: float = 0.0
    # decode cells: the useful work is reading weights+cache once per token;
    # utilization is bandwidth-based, not flops-based.
    model_bytes_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Useful-work time / dominant-term time: how close the step is to
        the hardware limit that binds it.  Useful work = model FLOPs for
        compute-shaped steps, or the one mandatory weights+cache read for
        decode-shaped steps — whichever gives the higher (fairer) bound."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = max(self.model_flops_per_device / PEAK_FLOPS,
                       self.model_bytes_per_device / HBM_BW)
        return useful_s / self.bound_s

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_per_device": self.model_flops_per_device,
            "model_bytes_per_device": self.model_bytes_per_device,
            "peak_memory_bytes": self.peak_memory_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(num_params: int, tokens: int, kind: str,
                active_params: Optional[int] = None) -> float:
    """6·N·D for training, 2·N·D for inference (per forward token)."""
    n = active_params if active_params is not None else num_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def analyze(arch: str, shape: str, mesh_name: str, compiled,
            *, model_flops_total: float, num_devices: int) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops_per_device=model_flops_total / num_devices,
        peak_memory_bytes=peak,
    )
