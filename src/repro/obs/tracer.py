"""Low-overhead span tracer — nested wall-time spans in a bounded ring.

A **span** is one named host-side wall-clock interval with parent/child
nesting: the query path opens ``serve.tick → fleet.query → fleet.plan /
fleet.refine / fleet.merge``, ingest opens ``fleet.insert → wal.append /
delta.scatter``, and the background compactor (its own thread) opens
``compact.seal → compact.build / compact.swap``.  Finished spans land in
a bounded ring buffer (old spans fall off; tracing never grows without
bound) and — when the tracer is bound to a
:class:`~repro.obs.registry.MetricsRegistry` — each span's duration is
observed into a ``span.<name>`` histogram, so every span family gets
p50/p95/p99 for free.

Nesting is thread-local: each thread keeps its own open-span stack, so
the compaction worker's spans interleave with the serving thread's spans
in the ring (ordered by end time) without ever corrupting either tree.
A span's ``trace_id`` is the id of its thread's root span, which is what
groups one query tick's tree back together.

Overhead per span: two ``perf_counter`` calls, one dict, one deque
append, one histogram observe — nanoseconds against the
hundreds-of-microseconds stages it wraps (the bench-smoke acceptance
budget is ≤5% on the fleet qps cell; measured well under).

``TRACER`` is the process default, bound to the default registry.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs.registry import REGISTRY, Histogram, MetricsRegistry

__all__ = ["Span", "SpanTracer", "TRACER"]


@dataclass
class Span:
    """One finished (or in-flight) named interval."""

    name: str
    span_id: int
    parent_id: Optional[int]            # None for a root span
    trace_id: int                       # span_id of the thread's root
    start: float                        # perf_counter seconds
    end: float = 0.0
    wall_start: float = 0.0             # epoch seconds (for the event log)
    thread: str = ""
    attrs: Dict[str, object] = field(default_factory=dict)

    @property
    def duration_ms(self) -> float:
        return (self.end - self.start) * 1e3

    def to_dict(self) -> dict:
        """JSON-ready view (one JSONL event-log line)."""
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "trace_id": self.trace_id,
                "ts": round(self.wall_start, 6),
                "duration_ms": round(self.duration_ms, 6),
                "thread": self.thread, "attrs": self.attrs}


class SpanTracer:
    """Context-manager spans, thread-local nesting, bounded ring buffer."""

    def __init__(self, capacity: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.capacity = capacity
        self.registry = registry
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._hists: Dict[str, Histogram] = {}
        self._jsonl = None                   # open file handle or None

    # -- recording --------------------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @contextmanager
    def span(self, name: str, **attrs):
        """Open a nested span; yields the live :class:`Span` (its
        ``duration_ms`` is final after the block exits, so callers can
        reuse the measurement instead of timing twice)."""
        stack = self._stack()
        sid = next(self._ids)
        parent = stack[-1] if stack else None
        sp = Span(name=name, span_id=sid,
                  parent_id=parent.span_id if parent else None,
                  trace_id=parent.trace_id if parent else sid,
                  start=time.perf_counter(), wall_start=time.time(),
                  thread=threading.current_thread().name, attrs=attrs)
        stack.append(sp)
        try:
            yield sp
        finally:
            sp.end = time.perf_counter()
            stack.pop()
            self._finish(sp)

    def _finish(self, sp: Span) -> None:
        with self._lock:
            self._ring.append(sp)
            jsonl = self._jsonl
        if self.registry is not None:
            h = self._hists.get(sp.name)
            if h is None:
                h = self._hists[sp.name] = \
                    self.registry.histogram(f"span.{sp.name}")
            h.observe(sp.duration_ms)
        if jsonl is not None:
            line = json.dumps(sp.to_dict(), sort_keys=True)
            with self._lock:
                if self._jsonl is not None:
                    self._jsonl.write(line + "\n")
                    self._jsonl.flush()

    # -- reading ----------------------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest-finished first."""
        with self._lock:
            return list(self._ring)

    def roots(self) -> List[Span]:
        return [s for s in self.spans() if s.parent_id is None]

    def tree(self, trace_id: int) -> Optional[dict]:
        """One trace as a nested dict: ``{"name", "duration_ms", "attrs",
        "children": […]}`` — children ordered by start time.  None when
        the trace (or its root) has fallen off the ring."""
        spans = [s for s in self.spans() if s.trace_id == trace_id]
        by_parent: Dict[Optional[int], List[Span]] = {}
        for s in spans:
            by_parent.setdefault(s.parent_id, []).append(s)

        def build(sp: Span) -> dict:
            kids = sorted(by_parent.get(sp.span_id, ()),
                          key=lambda s: s.start)
            return {"name": sp.name,
                    "duration_ms": round(sp.duration_ms, 6),
                    "attrs": sp.attrs,
                    "children": [build(k) for k in kids]}

        root = [s for s in spans if s.span_id == trace_id]
        return build(root[0]) if root else None

    def last_trace(self, name: Optional[str] = None) -> Optional[dict]:
        """The most recent complete trace (optionally: whose root span is
        named ``name``) as a nested tree."""
        for root in reversed(self.roots()):
            if name is None or root.name == name:
                return self.tree(root.trace_id)
        return None

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    # -- structured event log --------------------------------------------
    def attach_jsonl(self, path) -> None:
        """Append every finished span to ``path`` as one JSON line each
        (the structured event log exporters tail)."""
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
            self._jsonl = open(path, "a", encoding="utf-8")

    def detach_jsonl(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


#: The process-wide default tracer, bound to the default registry (every
#: span family gets a ``span.<name>`` latency histogram automatically).
TRACER = SpanTracer(registry=REGISTRY)
