"""Low-overhead span tracer — nested wall-time spans in a bounded ring.

A **span** is one named host-side wall-clock interval with parent/child
nesting: the query path opens ``serve.tick → fleet.query → fleet.plan /
fleet.refine / fleet.merge``, ingest opens ``fleet.insert → wal.append /
delta.scatter``, and the background compactor (its own thread) opens
``compact.seal → compact.build / compact.swap``.  Finished spans land in
a bounded ring buffer (old spans fall off; tracing never grows without
bound) and — when the tracer is bound to a
:class:`~repro.obs.registry.MetricsRegistry` — each span's duration is
observed into a ``span.<name>`` histogram, so every span family gets
p50/p95/p99 for free.

Nesting is thread-local: each thread keeps its own open-span stack, so
the compaction worker's spans interleave with the serving thread's spans
in the ring (ordered by end time) without ever corrupting either tree.
A span's ``trace_id`` is the id of its thread's root span, which is what
groups one query tick's tree back together.

**Trace-context propagation** — a trace can cross a thread or a process
boundary explicitly:

  * :meth:`SpanTracer.current_context` exports the innermost open span
    as a :class:`TraceContext` (``trace_id`` + ``span_id``) — the handoff
    token a thread captures before enqueueing work for another;
  * :meth:`SpanTracer.adopt` installs a received context on the current
    thread, so spans opened inside the block join the *remote* trace
    (their ``trace_id`` is the adopted one, their parent the adopting
    span id) instead of rooting a fresh local trace;
  * :meth:`SpanTracer.mint_trace_id` draws a random 63-bit trace id for
    the *origin* of a cross-process trace (a client about to stamp a
    request), so ids minted in different processes never collide the way
    the per-process span-id counter would.

The serving path uses exactly this: the network client mints a trace id
around its RTT span, ships it on ``QueryRequest.trace_id``, and the
server adopts it at admission and again on the executor thread — so one
trace links ``net.rtt → net.admit → serve.tick → fleet.query →
per-shard refine/merge`` across threads and across the socket.

Overhead per span: two ``perf_counter`` calls, one dict, one deque
append, one histogram observe — nanoseconds against the
hundreds-of-microseconds stages it wraps (the bench-smoke acceptance
budget is ≤5% on the fleet qps cell; measured well under).

``TRACER`` is the process default, bound to the default registry.
"""
from __future__ import annotations

import itertools
import json
import random
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.obs.registry import REGISTRY, Histogram, MetricsRegistry

__all__ = ["Span", "SpanTracer", "TraceContext", "TRACER"]


@dataclass(frozen=True)
class TraceContext:
    """The portable half of an open span: what crosses a boundary.

    ``trace_id`` groups the distributed trace; ``span_id`` is the span
    the receiver should parent under (0 = root of the remote trace, e.g.
    a client-minted context with no local span yet).  Both are plain ints
    so the pair rides any wire field or queue item unchanged.
    """

    trace_id: int
    span_id: int = 0


@dataclass
class Span:
    """One finished (or in-flight) named interval."""

    name: str
    span_id: int
    parent_id: Optional[int]            # None for a root span
    trace_id: int                       # span_id of the thread's root
    start: float                        # perf_counter seconds
    end: float = 0.0
    wall_start: float = 0.0             # epoch seconds (for the event log)
    thread: str = ""
    attrs: Dict[str, object] = field(default_factory=dict)

    @property
    def duration_ms(self) -> float:
        return (self.end - self.start) * 1e3

    def to_dict(self) -> dict:
        """JSON-ready view (one JSONL event-log line)."""
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "trace_id": self.trace_id,
                "ts": round(self.wall_start, 6),
                "duration_ms": round(self.duration_ms, 6),
                "thread": self.thread, "attrs": self.attrs}


class _Anchor:
    """A context adopted onto a thread's stack — parents like a span but
    is never recorded (the real parent lives on another thread/process)."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: int, span_id: int):
        self.trace_id = trace_id
        self.span_id = span_id


class SpanTracer:
    """Context-manager spans, thread-local nesting, bounded ring buffer."""

    def __init__(self, capacity: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.capacity = capacity
        self.registry = registry
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._hists: Dict[str, Histogram] = {}
        self._jsonl = None                   # open file handle or None
        self._listeners: List[Callable[[Span], None]] = []
        # ring evictions are silent by design; the counter is not — it is
        # what tells an operator the ring is undersized for the load
        self._dropped = registry.counter("obs.spans_dropped") \
            if registry is not None else None

    # -- recording --------------------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @contextmanager
    def span(self, name: str, **attrs):
        """Open a nested span; yields the live :class:`Span` (its
        ``duration_ms`` is final after the block exits, so callers can
        reuse the measurement instead of timing twice)."""
        stack = self._stack()
        sid = next(self._ids)
        parent = stack[-1] if stack else None
        sp = Span(name=name, span_id=sid,
                  parent_id=(parent.span_id or None) if parent else None,
                  trace_id=parent.trace_id if parent else sid,
                  start=time.perf_counter(), wall_start=time.time(),
                  thread=threading.current_thread().name, attrs=attrs)
        stack.append(sp)
        try:
            yield sp
        finally:
            sp.end = time.perf_counter()
            stack.pop()
            self._finish(sp)

    # -- trace-context propagation ----------------------------------------
    @staticmethod
    def mint_trace_id() -> int:
        """A random 63-bit trace id for the origin of a cross-process
        trace.  Span-id counters are per-process (two processes both count
        1, 2, 3…), so the id that *groups* a distributed trace must be
        drawn from a space where independent mints don't collide."""
        return random.getrandbits(63) | 1          # never 0 ("no trace")

    def current_context(self) -> Optional[TraceContext]:
        """Export the innermost open span (or adopted context) of this
        thread as a :class:`TraceContext`; None when nothing is open."""
        stack = self._stack()
        if not stack:
            return None
        top = stack[-1]
        return TraceContext(trace_id=top.trace_id, span_id=top.span_id)

    @contextmanager
    def adopt(self, ctx, span_id: int = 0):
        """Join a received trace on the current thread.

        ``ctx`` is a :class:`TraceContext` (or a bare ``trace_id`` int,
        with ``span_id`` as the parent span).  Spans opened inside the
        block carry the adopted ``trace_id`` and parent under the adopted
        ``span_id`` — exactly as if the remote parent were open on this
        thread.  ``ctx=None`` (or ``trace_id=0``) is a no-op, so call
        sites can adopt unconditionally.
        """
        if isinstance(ctx, TraceContext):
            trace_id, span_id = ctx.trace_id, ctx.span_id
        else:
            trace_id = int(ctx) if ctx is not None else 0
        if not trace_id:
            yield
            return
        stack = self._stack()
        stack.append(_Anchor(trace_id, span_id))
        try:
            yield
        finally:
            stack.pop()

    # -- capacity / listeners ---------------------------------------------
    def set_capacity(self, capacity: int) -> None:
        """Resize the ring in place, keeping the newest spans (the net
        server applies ``ServingConfig.trace_ring`` through this)."""
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        with self._lock:
            if capacity == self.capacity:
                return
            self._ring = deque(self._ring, maxlen=capacity)
            self.capacity = capacity

    def add_listener(self, fn: Callable[[Span], None]) -> None:
        """Call ``fn(span)`` after every span finishes (the flight
        recorder's tap).  Listeners run on the finishing thread, outside
        the ring lock; exceptions propagate to the span's opener."""
        with self._lock:
            self._listeners.append(fn)

    def remove_listener(self, fn: Callable[[Span], None]) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _finish(self, sp: Span) -> None:
        with self._lock:
            dropped = len(self._ring) == self.capacity
            self._ring.append(sp)
            jsonl = self._jsonl
            listeners = list(self._listeners)
        if dropped and self._dropped is not None:
            self._dropped.inc()
        if self.registry is not None:
            h = self._hists.get(sp.name)
            if h is None:
                h = self._hists[sp.name] = \
                    self.registry.histogram(f"span.{sp.name}")
            h.observe(sp.duration_ms)
        if jsonl is not None:
            line = json.dumps(sp.to_dict(), sort_keys=True)
            with self._lock:
                if self._jsonl is not None:
                    self._jsonl.write(line + "\n")
                    self._jsonl.flush()
        for fn in listeners:
            fn(sp)

    # -- reading ----------------------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest-finished first."""
        with self._lock:
            return list(self._ring)

    def roots(self) -> List[Span]:
        return [s for s in self.spans() if s.parent_id is None]

    def trace(self, trace_id: int) -> List[Span]:
        """Every ring span of one trace, oldest-finished first — the flat
        view the flight recorder and the admin TRACES reply export (a
        distributed trace adopted from another process has no local root,
        so the flat list is the always-correct form)."""
        return [s for s in self.spans() if s.trace_id == trace_id]

    def tree(self, trace_id: int) -> Optional[dict]:
        """One trace as a nested dict: ``{"name", "duration_ms", "attrs",
        "children": […]}`` — children ordered by start time.  None when
        the trace (or its root) has fallen off the ring.  For a trace
        adopted from another process (no local span is the trace root)
        the earliest locally-parentless span anchors the tree."""
        spans = self.trace(trace_id)
        by_parent: Dict[Optional[int], List[Span]] = {}
        for s in spans:
            by_parent.setdefault(s.parent_id, []).append(s)

        def build(sp: Span) -> dict:
            kids = sorted(by_parent.get(sp.span_id, ()),
                          key=lambda s: s.start)
            return {"name": sp.name,
                    "duration_ms": round(sp.duration_ms, 6),
                    "attrs": sp.attrs,
                    "children": [build(k) for k in kids]}

        root = [s for s in spans if s.span_id == trace_id]
        if not root:        # adopted trace: anchor on an orphan span
            local = {s.span_id for s in spans}
            orphans = [s for s in spans
                       if s.parent_id is None or s.parent_id not in local]
            root = sorted(orphans, key=lambda s: s.start)[:1]
        return build(root[0]) if root else None

    def last_trace(self, name: Optional[str] = None) -> Optional[dict]:
        """The most recent complete trace (optionally: whose root span is
        named ``name``) as a nested tree."""
        for root in reversed(self.roots()):
            if name is None or root.name == name:
                return self.tree(root.trace_id)
        return None

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    # -- structured event log --------------------------------------------
    def attach_jsonl(self, path) -> None:
        """Append every finished span to ``path`` as one JSON line each
        (the structured event log exporters tail)."""
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
            self._jsonl = open(path, "a", encoding="utf-8")

    def detach_jsonl(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


#: The process-wide default tracer, bound to the default registry (every
#: span family gets a ``span.<name>`` latency histogram automatically).
TRACER = SpanTracer(registry=REGISTRY)
