"""Unified observability plane — registry, span tracer, exporters.

One process-wide :class:`MetricsRegistry` (counters, gauges, log-bucketed
histograms with exact-count p50/p95/p99), one :class:`SpanTracer`
(context-manager spans with parent nesting in a bounded ring, plus
cross-thread / cross-process trace propagation via
:class:`TraceContext`), the tail-sampling :class:`FlightRecorder` that
keeps full span trees for slow or failed requests, the online
:class:`RecallSentinel` that audits live routed queries against
exhaustive ground truth, and the exporters that read everything back out
(Prometheus text, JSONL event log, stable JSON snapshot).  The serving
planes record into the module-level defaults ``REGISTRY`` / ``TRACER`` /
``FLIGHT``; see docs/OBSERVABILITY.md for the span taxonomy and operator
recipes.
"""
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                REGISTRY)
from repro.obs.tracer import Span, SpanTracer, TraceContext, TRACER
from repro.obs.flight import FlightRecorder, FLIGHT
from repro.obs.sentinel import RecallSentinel
from repro.obs.export import snapshot, spans_jsonl, to_prometheus
from repro.obs.profile import device_trace, trace_annotation

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "Span", "SpanTracer", "TraceContext", "TRACER",
           "FlightRecorder", "FLIGHT", "RecallSentinel",
           "snapshot", "spans_jsonl", "to_prometheus",
           "device_trace", "trace_annotation"]
