"""Exporters — Prometheus text exposition, JSONL event log, JSON snapshot.

Three stable output formats over one :class:`~repro.obs.registry.
MetricsRegistry` (and optionally a :class:`~repro.obs.tracer.SpanTracer`):

  * :func:`to_prometheus` — the text exposition format scrapers ingest:
    counters as ``<name>_total``, gauges plain, histograms as summaries
    (``{quantile="0.5"}`` series plus ``_count`` / ``_sum``).  Metric
    names are prefixed ``repro_`` and sanitized (dots → underscores);
  * :func:`spans_jsonl` — finished spans as one JSON object per line
    (the structured event log; ``SpanTracer.attach_jsonl`` streams the
    same format continuously);
  * :func:`snapshot` — one stable JSON document (sorted keys, rounded
    floats) for benchmark artifacts and golden tests.

Doctest — the golden Prometheus format::

    >>> from repro.obs.registry import MetricsRegistry
    >>> reg = MetricsRegistry()
    >>> reg.counter("demo.requests").inc(3)
    >>> reg.gauge("demo.queue_depth", loop="engine0").set(2.5)
    >>> print(to_prometheus(reg))
    # TYPE repro_demo_queue_depth gauge
    repro_demo_queue_depth{loop="engine0"} 2.5
    # TYPE repro_demo_requests_total counter
    repro_demo_requests_total 3
    <BLANKLINE>

Histograms expose exact counts and exact-rank quantiles::

    >>> for v in (1.0, 2.0, 3.0, 4.0):
    ...     reg.histogram("demo.latency_ms").observe(v)
    >>> page = to_prometheus(reg)
    >>> '# TYPE repro_demo_latency_ms summary' in page
    True
    >>> 'repro_demo_latency_ms_count 4' in page
    True
    >>> 'repro_demo_latency_ms_sum 10' in page
    True

And the JSON snapshot is stable (sorted keys) run over run::

    >>> snap = snapshot(reg)
    >>> sorted(snap) == ['counters', 'gauges', 'histograms']
    True
    >>> snap["counters"]["repro_demo_requests_total"]
    3
"""
from __future__ import annotations

import json
import re
from typing import Iterable, Optional

from repro.obs.registry import MetricsRegistry, REGISTRY
from repro.obs.tracer import Span

__all__ = ["to_prometheus", "spans_jsonl", "snapshot", "prom_name"]

_SAN = re.compile(r"[^a-zA-Z0-9_:]")
QUANTILES = (0.5, 0.95, 0.99)


def prom_name(name: str, prefix: str = "repro") -> str:
    """Sanitize a dotted metric name into a Prometheus identifier.

    >>> prom_name("serve.latency_ms")
    'repro_serve_latency_ms'
    """
    return _SAN.sub("_", f"{prefix}_{name}" if prefix else name)


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _labels(labels: dict, extra: Optional[dict] = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def to_prometheus(registry: MetricsRegistry = REGISTRY,
                  prefix: str = "repro") -> str:
    """Render the registry as one Prometheus text-exposition page."""
    lines = []
    seen_types = set()

    def typeline(pname: str, kind: str) -> None:
        if pname not in seen_types:
            seen_types.add(pname)
            lines.append(f"# TYPE {pname} {kind}")

    for name, labels, metric in registry.metrics():
        if metric.kind == "counter":
            pname = prom_name(name, prefix) + "_total"
            typeline(pname, "counter")
            lines.append(f"{pname}{_labels(labels)} {_fmt(metric.value)}")
        elif metric.kind == "gauge":
            pname = prom_name(name, prefix)
            typeline(pname, "gauge")
            lines.append(f"{pname}{_labels(labels)} {_fmt(metric.value)}")
        else:                                   # histogram → summary
            pname = prom_name(name, prefix)
            typeline(pname, "summary")
            for q in QUANTILES:
                lines.append(f"{pname}{_labels(labels, {'quantile': q})} "
                             f"{_fmt(metric.quantile(q))}")
            lines.append(f"{pname}_count{_labels(labels)} {metric.count}")
            lines.append(f"{pname}_sum{_labels(labels)} {_fmt(metric.sum)}")
    for name, labels, value in registry.collected():
        pname = prom_name(name, prefix)
        typeline(pname, "gauge")
        lines.append(f"{pname}{_labels(labels)} {_fmt(value)}")
    return "\n".join(lines) + "\n"


def spans_jsonl(spans: Iterable[Span]) -> str:
    """Finished spans as JSONL (one sorted-key JSON object per line)."""
    return "\n".join(json.dumps(s.to_dict(), sort_keys=True)
                     for s in spans) + "\n"


def snapshot(registry: MetricsRegistry = REGISTRY, *, tracer=None,
             prefix: str = "repro") -> dict:
    """One stable JSON document: metrics (+ optional recent span roots).

    Counter slots use the Prometheus naming (``_total`` suffix) so the
    two exporters agree on identity; floats round to 6 places so the
    document is byte-stable across equal states.
    """
    raw = registry.snapshot()

    def rename(slot: str, suffix: str = "") -> str:
        name, brace, labels = slot.partition("{")
        return prom_name(name, prefix) + suffix + brace + labels

    def rnd(v):
        return round(v, 6) if isinstance(v, float) else v

    out = {
        "counters": {rename(k, "_total"): rnd(v)
                     for k, v in raw["counters"].items()},
        "gauges": {rename(k): rnd(v) for k, v in raw["gauges"].items()},
        "histograms": {rename(k): {kk: rnd(vv) for kk, vv in h.items()}
                       for k, h in raw["histograms"].items()},
    }
    if tracer is not None:
        out["traces"] = [t for t in
                         (tracer.tree(r.trace_id) for r in tracer.roots())
                         if t is not None]
    return out
