"""Online recall sentinel — accuracy watched in production, off-path.

Offline evaluation (``repro.eval``) answers "what recall does this
routing config achieve on a benchmark corpus"; nothing so far answers
"what recall is the fleet achieving on the traffic it is serving *right
now*".  The sentinel closes that loop:

  1. **shadow-sample**: :meth:`RecallSentinel.observe` is called from
     ``IndexFleet.query`` with the batch it just answered; a dedicated
     RNG samples ``sample_rate`` of the queries and copies (query,
     served answer) into a bounded pending deque.  The serve path does
     nothing else — no re-execution, no extra device work — so served
     answers are **bit-identical** with sampling on or off (enforced by
     test).
  2. **re-execute exhaustively, off-path**: :meth:`drain` (run from the
     fleet engine's maintenance tick, or continuously via
     :meth:`start` on a worker thread) re-answers each sample with
     ``fleet.scan_exact`` — the lossless single-refine ground truth —
     and scores the *served* answer against it with the same tie-aware
     ``recall_at_k`` the offline harness uses.
  3. **feed back**: the running mean lands on the ``fleet.online_recall``
     gauge (Prometheus: ``repro_fleet_online_recall``), and each audit
     appends an ``audit_routing(record=True)``-style ``(scores,
     true_hits)`` trace to ``fleet.routing_traces`` — so
     ``calibrate_routing()`` can periodically re-learn the adaptive
     threshold from *production* traffic (``recalibrate_every``).

Samples whose fleet contents changed between serve and audit (inserts
landed in between) are discarded rather than scored against ground truth
the served answer never saw.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, Optional

import numpy as np

from repro.obs.registry import REGISTRY, MetricsRegistry

__all__ = ["RecallSentinel", "SentinelSample"]


class SentinelSample:
    """One shadow-sampled query: what was served, frozen at serve time."""

    __slots__ = ("query", "k", "dist", "gid", "next_gid", "ts")

    def __init__(self, query, k, dist, gid, next_gid):
        self.query = query
        self.k = k
        self.dist = dist
        self.gid = gid
        self.next_gid = next_gid     # fleet content version at serve time
        self.ts = time.time()


class RecallSentinel:
    """Shadow-sampling recall monitor over one :class:`IndexFleet`.

    Args:
      fleet: the fleet to watch; the sentinel installs itself as
        ``fleet.sentinel`` (the ``IndexFleet.query`` hook point).
      sample_rate: fraction of served queries shadow-sampled (drawn from
        the sentinel's own RNG — the serve path's randomness, if any, is
        untouched).
      max_pending: bound on queries sampled but not yet audited; beyond
        it the oldest samples are dropped (sampling must never become
        backpressure).
      recalibrate_every: run ``fleet.calibrate_routing(target_recall)``
        after every N audited queries (0 = never — traces still
        accumulate for an explicit call).
      target_recall: the recall target handed to ``calibrate_routing``.
      seed: sampling RNG seed.
      registry: metrics registry (None = process default) for the
        ``fleet.online_recall`` gauge and sample/audit counters.
    """

    def __init__(self, fleet, *, sample_rate: float = 0.02,
                 max_pending: int = 256, recalibrate_every: int = 0,
                 target_recall: float = 0.95, seed: int = 0,
                 registry: Optional[MetricsRegistry] = REGISTRY):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], "
                             f"got {sample_rate}")
        self.fleet = fleet
        self.sample_rate = float(sample_rate)
        self.recalibrate_every = int(recalibrate_every)
        self.target_recall = float(target_recall)
        self._rng = np.random.default_rng(seed)
        self._pending: deque = deque(maxlen=int(max_pending))
        self._lock = threading.Lock()
        self._recall_sum = 0.0
        self._audits = 0
        self._since_recalibrate = 0
        self.last_threshold: Optional[float] = None
        label = getattr(fleet, "obs_label", "fleet")
        if registry is not None:
            self._gauge = registry.gauge("fleet.online_recall", fleet=label)
            self._samples_ctr = registry.counter("sentinel.samples",
                                                 fleet=label)
            self._audits_ctr = registry.counter("sentinel.audits",
                                                fleet=label)
        else:
            self._gauge = self._samples_ctr = self._audits_ctr = None
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        fleet.sentinel = self

    # -- serve-path hook (must stay cheap and side-effect-free) ------------
    def observe(self, queries: np.ndarray, k: int, dist: np.ndarray,
                gid: np.ndarray) -> None:
        """Shadow-sample one answered batch.  Called by
        ``IndexFleet.query`` after the answer is final; only copies —
        the arrays handed back to the caller are never touched."""
        if self.sample_rate <= 0.0 or len(queries) == 0:
            return
        picks = np.nonzero(self._rng.random(len(queries))
                           < self.sample_rate)[0]
        if not len(picks):
            return
        next_gid = self.fleet._next_gid
        with self._lock:
            for i in picks:
                self._pending.append(SentinelSample(
                    np.array(queries[i]), k, np.array(dist[i]),
                    np.array(gid[i]), next_gid))
        if self._samples_ctr is not None:
            self._samples_ctr.inc(len(picks))

    # -- off-path auditing -------------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def drain(self, max_audits: int = 0) -> int:
        """Audit up to ``max_audits`` pending samples (0 = all).

        Returns the number audited.  Safe to call from the maintenance
        tick or a worker thread; never from inside ``fleet.query``.
        """
        done = 0
        while max_audits <= 0 or done < max_audits:
            with self._lock:
                if not self._pending:
                    break
                sample = self._pending.popleft()
            if self._audit_one(sample):
                done += 1
        return done

    def _audit_one(self, sample: SentinelSample) -> bool:
        fleet = self.fleet
        if fleet._next_gid != sample.next_gid:
            return False     # contents moved since serve time: stale truth
        from repro.eval.metrics import recall_at_k   # lazy: avoids cycle
        from repro.obs import TRACER
        with TRACER.span("sentinel.audit", k=sample.k):
            exact_d, exact_g = fleet.scan_exact(sample.query[None],
                                                sample.k)
            recall = recall_at_k(sample.gid[None], exact_g, sample.k,
                                 approx_dist=sample.dist[None],
                                 exact_dist=exact_d)
            self._record_routing_trace(sample.query, exact_g[0])
        with self._lock:
            self._recall_sum += recall
            self._audits += 1
            audits = self._audits
            mean = self._recall_sum / audits
            self._since_recalibrate += 1
            recal = self.recalibrate_every and \
                self._since_recalibrate >= self.recalibrate_every
            if recal:
                self._since_recalibrate = 0
        if self._gauge is not None:
            self._gauge.set(mean)
        if self._audits_ctr is not None:
            self._audits_ctr.inc()
        if recal and fleet.router is not None and fleet.routing_traces:
            self.last_threshold = \
                fleet.calibrate_routing(self.target_recall)
        return True

    def _record_routing_trace(self, query: np.ndarray,
                              exact_gid: np.ndarray) -> None:
        """One ``(router scores, per-shard true-hit counts)`` pair, the
        exact shape ``audit_routing(record=True)`` appends — production
        fuel for ``calibrate_routing``."""
        fleet = self.fleet
        router = fleet.router
        if router is None or not router.num_shards:
            return
        with fleet._lock:
            gid_sets = [s.global_ids for s in fleet.shards]
        scores = router.score(query[None])[0]
        valid = exact_gid[exact_gid >= 0]
        hits = np.array([int(np.isin(valid, g).sum()) for g in gid_sets],
                        np.int64)
        fleet.routing_traces.append((scores.copy(), hits))
        del fleet.routing_traces[:-fleet.MAX_ROUTING_TRACES]

    # -- worker thread -----------------------------------------------------
    def start(self, interval_s: float = 0.05) -> None:
        """Continuously drain on a daemon worker thread (the alternative
        to riding the engine's maintenance tick)."""
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()

        def _run():
            while not self._stop.is_set():
                if not self.drain(max_audits=8):
                    self._stop.wait(interval_s)

        self._worker = threading.Thread(target=_run,
                                        name="recall-sentinel", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=10)
            self._worker = None

    # -- reading -----------------------------------------------------------
    @property
    def online_recall(self) -> float:
        """Running mean recall over everything audited (1.0 before any
        audit — no evidence of loss yet)."""
        with self._lock:
            return self._recall_sum / self._audits if self._audits else 1.0

    def snapshot(self) -> dict:
        with self._lock:
            return {"online_recall": self._recall_sum / self._audits
                    if self._audits else 1.0,
                    "audits": self._audits,
                    "pending": len(self._pending),
                    "sample_rate": self.sample_rate,
                    "last_threshold": self.last_threshold}
