"""Opt-in device profiling — ``jax.profiler`` trace capture.

The registry/tracer pair measures *host-side* wall time; what the device
actually did inside the fused shard_map lives in the XLA trace.  The
fused stages are wrapped in ``jax.named_scope`` (``climber.featurize`` /
``climber.plan`` / ``climber.refine`` / ``climber.merge`` — see
``repro.fleet.placement``) and the host-side dispatches carry
``jax.profiler.TraceAnnotation`` markers, so a captured trace lines the
two views up.

Capture is strictly opt-in (profiling is not free):

    with engine.capture_device_trace("/tmp/trace"):
        engine.run(queries)

then open the directory with TensorBoard's profile plugin or
``xprof``.  See docs/OBSERVABILITY.md for the full how-to.
"""
from __future__ import annotations

from contextlib import contextmanager

__all__ = ["device_trace", "trace_annotation"]


@contextmanager
def device_trace(log_dir):
    """Capture a ``jax.profiler`` trace of the enclosed block into
    ``log_dir`` (created if missing).  Reentrant use raises — jax allows
    one active trace per process."""
    import jax
    jax.profiler.start_trace(str(log_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` context manager (host-side
    marker that shows up on captured device traces)."""
    import jax
    return jax.profiler.TraceAnnotation(name)
