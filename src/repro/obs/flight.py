"""Flight recorder — tail-sampled full span trees for the requests that
matter, in a bounded ring with JSONL export.

The span ring (:class:`~repro.obs.tracer.SpanTracer`) answers "what does
a typical request look like"; after a p99 spike the question is the
opposite — *what did the slow one do*.  Keeping every span tree is
unaffordable, so the recorder **tail-samples**: it taps every finished
span through a tracer listener, buffers them per ``trace_id``, and when a
*trigger* span completes (``serve.tick`` by default — the span that ends
a request's execution) it decides once whether the whole trace is worth
keeping:

  * the trigger's duration exceeds an explicit ``threshold_ms``, or —
    when no threshold is configured — the recorder's own running
    ``quantile`` of trigger durations (after ``min_samples`` warmup), or
  * the trace carries a typed error noted via :meth:`note_error`
    (``RETRY_LATER`` refusals, executor ``INTERNAL`` faults, …) — error
    notes also retain on the *admission* span so requests refused before
    ever reaching a tick still leave a readable trace.

Retained traces land in a bounded ring (oldest evicted) as full span
lists with the retention reason, exportable as JSONL (:meth:`jsonl`) or
over the admin plane's TRACES message.  ``FLIGHT`` is the process
default, tapping the default ``TRACER``.

Memory is bounded everywhere: at most ``max_open_traces`` in-progress
buffers of ``max_spans_per_trace`` spans each, plus ``capacity``
retained records.
"""
from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

from repro.obs.registry import REGISTRY, Histogram, MetricsRegistry
from repro.obs.tracer import Span, SpanTracer, TRACER

__all__ = ["FlightRecorder", "FLIGHT"]


class FlightRecorder:
    """Tail sampling over one tracer's finished spans.

    Args:
      tracer: the :class:`SpanTracer` to tap (attaches a listener).
      capacity: retained-trace ring size (oldest evicted).
      threshold_ms: explicit latency gate on the trigger span; None (the
        default) gates on the running ``quantile`` instead.
      quantile: tail fraction to keep when no threshold is set (0.99
        keeps roughly the slowest 1% of ticks).
      min_samples: trigger completions before the quantile gate arms —
        an empty histogram's quantile is 0 and would retain everything.
      triggers: span names whose completion closes a trace and runs the
        latency decision (default ``("serve.tick",)``).
      error_triggers: span names that retain a trace when it has a noted
        error even though no latency trigger ran — the admission span
        (so refusals like RETRY_LATER / QUOTA_EXCEEDED / BAD_REQUEST are
        recorded without ever reaching a tick) and the executor's
        ``net.fail`` marker (the tick's own spans close while the
        exception unwinds, before the server can note the error).
      registry: counts ``flight.retained`` / ``flight.dropped`` (None =
        the process default registry).
    """

    def __init__(self, tracer: SpanTracer, *, capacity: int = 64,
                 threshold_ms: Optional[float] = None,
                 quantile: float = 0.99, min_samples: int = 32,
                 triggers: Tuple[str, ...] = ("serve.tick",),
                 error_triggers: Tuple[str, ...] = ("net.admit",
                                                    "net.fail"),
                 max_open_traces: int = 256,
                 max_spans_per_trace: int = 512,
                 registry: Optional[MetricsRegistry] = REGISTRY):
        self.tracer = tracer
        self.capacity = int(capacity)
        self.threshold_ms = threshold_ms
        self.quantile = float(quantile)
        self.min_samples = int(min_samples)
        self.triggers = tuple(triggers)
        self.error_triggers = tuple(error_triggers)
        self.max_open_traces = int(max_open_traces)
        self.max_spans_per_trace = int(max_spans_per_trace)
        self._lock = threading.Lock()
        self._open: "OrderedDict[int, List[Span]]" = OrderedDict()
        self._errors: Dict[int, str] = {}
        self._records: deque = deque(maxlen=self.capacity)
        # private distribution of trigger durations — deliberately not a
        # registry metric: the quantile gate must not be reset by
        # benchmark reset_metrics() calls mid-flight
        self._lat = Histogram()
        self._retained = registry.counter("flight.retained") \
            if registry is not None else None
        self._dropped = registry.counter("flight.dropped") \
            if registry is not None else None
        tracer.add_listener(self._on_span)

    def close(self) -> None:
        """Detach from the tracer (tests building private recorders)."""
        self.tracer.remove_listener(self._on_span)

    # -- the tap -----------------------------------------------------------
    def note_error(self, trace_id: int, code: str) -> None:
        """Mark a trace as ending in a typed error; whichever trigger (or
        error-trigger) span of it finishes next retains the whole trace."""
        if not trace_id:
            return
        with self._lock:
            self._errors[trace_id] = code
            # bound like _open: a noted error whose trace never finishes
            # a trigger span must not leak
            while len(self._errors) > self.max_open_traces:
                self._errors.pop(next(iter(self._errors)))

    def _on_span(self, sp: Span) -> None:
        with self._lock:
            buf = self._open.get(sp.trace_id)
            if buf is None:
                buf = self._open[sp.trace_id] = []
                while len(self._open) > self.max_open_traces:
                    self._open.popitem(last=False)   # evict oldest trace
            if len(buf) < self.max_spans_per_trace:
                buf.append(sp)
            is_trigger = sp.name in self.triggers
            err = self._errors.get(sp.trace_id)
            if not is_trigger and not (err and sp.name
                                       in self.error_triggers):
                return
            reason = None
            if err is not None:
                reason = f"error:{err}"
            elif is_trigger:
                dur = sp.duration_ms
                self._lat.observe(dur)
                if self.threshold_ms is not None:
                    if dur >= self.threshold_ms:
                        reason = f"latency>{self.threshold_ms:g}ms"
                elif self._lat.count >= self.min_samples and \
                        dur >= self._lat.quantile(self.quantile) > 0.0:
                    reason = f"latency>p{self.quantile * 100:g}"
            spans = self._open.pop(sp.trace_id, [])
            self._errors.pop(sp.trace_id, None)
            if reason is None:
                if self._dropped is not None:
                    self._dropped.inc()
                return
            self._records.append({
                "trace_id": sp.trace_id, "reason": reason,
                "trigger": sp.name,
                "duration_ms": round(sp.duration_ms, 6),
                "ts": round(time.time(), 6),
                "spans": [s.to_dict()
                          for s in sorted(spans, key=lambda s: s.start)]})
        if self._retained is not None:
            self._retained.inc()

    # -- reading -----------------------------------------------------------
    def records(self, limit: int = 0) -> List[dict]:
        """Retained traces, oldest first (``limit`` keeps the newest N)."""
        with self._lock:
            recs = list(self._records)
        return recs[-limit:] if limit else recs

    def jsonl(self, limit: int = 0) -> str:
        """One retained trace per line — the slow-query log."""
        return "\n".join(json.dumps(r, sort_keys=True)
                         for r in self.records(limit)) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self._open.clear()
            self._errors.clear()
            self._lat.reset()


#: Process default: taps ``TRACER``, keeps the p99 tail of ``serve.tick``
#: plus every trace that ends in a typed error.
FLIGHT = FlightRecorder(TRACER)
