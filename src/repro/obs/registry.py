"""Process-wide metrics registry — counters, gauges, log-bucketed histograms.

The serving planes (``repro.serve``, ``repro.fleet``) used to report only
means: ``EngineStats.queries_per_sec`` and three hand-timed ``stage_ms``
buckets.  Tail behaviour — the p99 a query sees while a background
compaction rebuilds the delta, or while the router mis-fans a hot tenant —
was invisible.  This module is the one process-wide sink every plane
records into:

  * :class:`Counter` — monotonically increasing totals (queries served,
    WAL bytes appended);
  * :class:`Gauge` — last-write-wins levels (queue depth, delta
    occupancy);
  * :class:`Histogram` — **log-bucketed** latency distributions with
    *exact-count* quantiles: every observation lands in a geometric
    bucket (default growth 5% per bucket), bucket counts are exact
    integers, and ``quantile(q)`` walks the cumulative counts to the
    exact rank — only the *value* is quantized, to at most half a bucket
    width (≈2.5% relative), never the rank.  Observed min/max are kept
    exactly, so the extreme quantiles clamp to real observations.

Everything is thread-safe: background compaction workers, the serving
loop, and exporter scrapes may interleave freely (each metric carries its
own lock; the registry lock only guards get-or-create and collector
registration).

Metrics are keyed by ``(name, labels)`` — ``registry.histogram(
"serve.latency_ms", loop="fleetengine0")`` — so per-engine / per-fleet
series coexist in one registry.  ``get-or-create`` semantics: asking for
the same key returns the same object, so call sites don't coordinate.

Pull-based sources register a **collector**: a zero-arg callable
returning ``{name: value}`` gauges at scrape time (or None to be
dropped).  ``EngineStats`` / ``FleetStats`` stay plain dataclasses — their
owners register weakref'd collectors exposing every scalar of
``snapshot()``, so the existing dict contract is untouched while the
exporters see the same numbers.

``REGISTRY`` is the process default; tests build private instances.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY"]


class Counter:
    """Monotonic counter.  ``inc`` is thread-safe; ``value`` is exact."""

    kind = "counter"
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up; inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-write-wins level (queue depth, occupancy)."""

    kind = "gauge"
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Log-bucketed histogram with exact-count quantiles.

    Buckets are geometric: bucket ``i`` covers ``[lo·g^i, lo·g^(i+1))``
    with growth factor ``g`` (default 1.05 → ≤2.5% relative error at the
    geometric bucket midpoint).  Values below ``lo`` (including ≤0) land
    in an underflow bucket represented by the exact observed minimum;
    values ≥ ``hi`` land in an overflow bucket represented by the exact
    maximum.  ``quantile`` uses the same rank convention as
    ``numpy.percentile`` (linear rank ``q·(n−1)``) over the exact bucket
    counts, then returns the bucket's geometric midpoint clamped to the
    exact observed ``[min, max]``.

    The default range ``[1e-3, 1e7]`` spans 1 µs to ~3 hours when
    observations are milliseconds — every latency this repo measures.

    >>> h = Histogram()
    >>> for v in (1.0, 2.0, 3.0, 4.0, 100.0):
    ...     h.observe(v)
    >>> h.count, h.min, h.max
    (5, 1.0, 100.0)
    >>> h.quantile(0.0) == 1.0 and h.quantile(1.0) == 100.0
    True
    >>> abs(h.quantile(0.5) - 3.0) / 3.0 < 0.025   # ≤ half a bucket off
    True
    """

    kind = "histogram"
    __slots__ = ("lo", "hi", "growth", "_log_g", "_nb", "_counts", "_lock",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, lo: float = 1e-3, hi: float = 1e7,
                 growth: float = 1.05):
        if not (0 < lo < hi) or growth <= 1.0:
            raise ValueError(f"bad histogram shape lo={lo} hi={hi} "
                             f"growth={growth}")
        self.lo, self.hi, self.growth = lo, hi, growth
        self._log_g = math.log(growth)
        self._nb = int(math.ceil(math.log(hi / lo) / self._log_g))
        # [underflow] + nb log buckets + [overflow]
        self._counts = [0] * (self._nb + 2)
        self._lock = threading.Lock()
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        if v != v:                      # NaN: refuse silently-poisoned tails
            return
        if v < self.lo:
            idx = 0
        elif v >= self.hi:
            idx = self._nb + 1
        else:
            idx = 1 + min(int(math.log(v / self.lo) / self._log_g),
                          self._nb - 1)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def _bucket_value(self, idx: int) -> float:
        if idx == 0:
            return self._min
        if idx == self._nb + 1:
            return self._max
        return self.lo * self.growth ** (idx - 0.5)    # geometric midpoint

    def quantile(self, q: float) -> float:
        """Exact-rank quantile over the bucket counts (0 when empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile wants q in [0, 1], got {q}")
        with self._lock:
            n = self._count
            if not n:
                return 0.0
            rank = q * (n - 1)
            if rank <= 0:               # extremes are tracked exactly
                return float(self._min)
            if rank >= n - 1:
                return float(self._max)
            cum = 0
            for idx, c in enumerate(self._counts):
                cum += c
                if cum > rank:
                    return float(min(max(self._bucket_value(idx),
                                         self._min), self._max))
            return float(self._max)

    def percentiles(self) -> Dict[str, float]:
        """The operator trio: ``{"p50": …, "p95": …, "p99": …}``."""
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (self._nb + 2)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf


LabelKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, str]) -> LabelKey:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Name+labels → metric, with get-or-create semantics.

    One instance (:data:`REGISTRY`) is the process default every serving
    plane records into; exporters (``repro.obs.export``) read it back out.

    >>> reg = MetricsRegistry()
    >>> reg.counter("demo.requests", loop="e0").inc(2)
    >>> reg.counter("demo.requests", loop="e0").value   # same object back
    2
    >>> reg.gauge("demo.requests", loop="e0")   # same key, different kind
    Traceback (most recent call last):
        ...
    TypeError: metric 'demo.requests'{'loop': 'e0'} already registered \
as Counter, not Gauge
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[LabelKey, object] = {}
        self._collectors: List[Callable[[], Optional[Dict[str, float]]]] = []

    def _get_or_create(self, cls, name: str, labels: Dict[str, str],
                       *args, **kw):
        key = _key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(*args, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r}{dict(key[1])} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str, *, lo: float = 1e-3, hi: float = 1e7,
                  growth: float = 1.05, **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels, lo, hi, growth)

    def add_collector(
            self, fn: Callable[[], Optional[Dict[str, float]]],
            **labels) -> None:
        """Register a pull-based gauge source.

        ``fn()`` is called at scrape time and returns ``{name: value}``
        (exported as gauges under ``labels``) — or None, which
        unregisters it (the weakref idiom: closures over dead objects
        return None and disappear).
        """
        with self._lock:
            self._collectors.append((fn, dict(labels)))

    def metrics(self) -> Iterator[Tuple[str, Dict[str, str], object]]:
        """Stable-ordered ``(name, labels, metric)`` triples."""
        with self._lock:
            items = sorted(self._metrics.items())
        for (name, labels), metric in items:
            yield name, dict(labels), metric

    def collected(self) -> Iterator[Tuple[str, Dict[str, str], float]]:
        """Evaluate every collector; drop the ones reporting None."""
        with self._lock:
            collectors = list(self._collectors)
        dead = []
        for fn, labels in collectors:
            vals = fn()
            if vals is None:
                dead.append(fn)
                continue
            for name in sorted(vals):
                yield name, labels, float(vals[name])
        if dead:
            with self._lock:
                self._collectors = [(f, l) for f, l in self._collectors
                                    if f not in dead]

    def snapshot(self) -> dict:
        """Stable JSON-ready view: every metric + collected gauges."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}

        def slot(name, labels):
            if not labels:
                return name
            inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            return f"{name}{{{inner}}}"

        for name, labels, metric in self.metrics():
            if metric.kind == "counter":
                out["counters"][slot(name, labels)] = metric.value
            elif metric.kind == "gauge":
                out["gauges"][slot(name, labels)] = metric.value
            else:
                h: Histogram = metric
                out["histograms"][slot(name, labels)] = {
                    "count": h.count, "sum": h.sum,
                    "min": h.min, "max": h.max, **h.percentiles()}
        for name, labels, value in self.collected():
            out["gauges"].setdefault(slot(name, labels), value)
        return out

    def reset(self) -> None:
        """Drop every metric and collector (tests)."""
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()


#: The process-wide default registry (serving planes record here).
REGISTRY = MetricsRegistry()
