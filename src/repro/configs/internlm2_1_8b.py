"""internlm2-1.8b [dense]: 24L d=2048 16H (GQA kv=8) ff=8192 vocab=92544.
GQA [arXiv:2403.17297; hf]."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92544,
        head_dim=128, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=256, head_dim=16)
