"""Assigned-architecture registry: ``get_config(arch_id, smoke=False)``.

Full configs are exercised only via the dry-run (ShapeDtypeStruct lowering);
smoke configs are reduced same-family variants for CPU tests.
"""
from __future__ import annotations

from typing import Dict

from repro.utils.config import ModelConfig

from repro.configs import (starcoder2_15b, internlm2_1_8b, minicpm3_4b,
                           mistral_large_123b, whisper_large_v3, zamba2_2_7b,
                           llama32_vision_90b, olmoe_1b_7b, qwen2_moe_a2_7b,
                           mamba2_780m)

_MODULES = {
    "starcoder2-15b": starcoder2_15b,
    "internlm2-1.8b": internlm2_1_8b,
    "minicpm3-4b": minicpm3_4b,
    "mistral-large-123b": mistral_large_123b,
    "whisper-large-v3": whisper_large_v3,
    "zamba2-2.7b": zamba2_2_7b,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "mamba2-780m": mamba2_780m,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; valid: {list(_MODULES)}")
    mod = _MODULES[arch]
    return mod.smoke_config() if smoke else mod.config()
