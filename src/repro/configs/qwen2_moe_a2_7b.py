"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (GQA kv=16) ff=1408/expert
vocab=151936; 60 routed top-4 + 4 shared experts (shared ff = 5632)
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
        head_dim=128, num_experts=60, experts_per_token=4,
        num_shared_experts=4, shared_expert_d_ff=5632)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=256, head_dim=16,
        num_experts=6, experts_per_token=2, num_shared_experts=2,
        shared_expert_d_ff=128)
