"""whisper-large-v3 [audio/enc-dec]: 32L d=1280 20H ff=5120 vocab=51866.
Conv frontend is a STUB (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356; unverified].  Positional stub: RoPE instead of Whisper's
sinusoidal/learned-absolute embeddings (recorded in DESIGN.md)."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec", num_layers=32, d_model=1280,
        num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
        head_dim=64, num_encoder_layers=32)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="encdec", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        head_dim=16, num_encoder_layers=2)
