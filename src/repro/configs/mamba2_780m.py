"""mamba2-780m [ssm]: 48L d=1536 (attention-free) vocab=50280
ssm_state=128 — SSD / state-space duality [arXiv:2405.21060; unverified]."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
        num_heads=24, num_kv_heads=24, d_ff=0, vocab_size=50280,
        head_dim=64, ssm_state=128, ssm_head_dim=64, ssm_expand=2)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256, head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16)
