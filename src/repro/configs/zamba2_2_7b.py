"""zamba2-2.7b [hybrid]: 54L d=2560 32H (GQA kv=32) ff=10240 ssm_state=64.
Mamba2 backbone + one shared attention block applied every 6 layers
[arXiv:2411.15242; hf].  Simplification vs released weights: the shared
block sees the hidden stream only (no concat with the embedding stream);
recorded in DESIGN.md."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
        num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
        head_dim=80, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
        hybrid_attn_every=6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, hybrid_attn_every=2,
        ssm_chunk=16)
