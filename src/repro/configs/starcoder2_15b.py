"""starcoder2-15b [dense]: 40L d=6144 48H (GQA kv=4) ff=24576 vocab=49152.
GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.utils.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense", num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=4, d_ff=24576, vocab_size=49152,
        head_dim=128, rope_theta=100_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        rope_theta=100_000.0)
