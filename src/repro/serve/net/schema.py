"""Message schema — the :mod:`repro.serve.api` dataclasses on the wire.

Each message type maps one api dataclass to a flat npz field dict and
back.  The mapping is explicit per type (no reflection, no pickle): a
field the decoder does not expect is ignored, a missing field raises a
typed ``BAD_PAYLOAD`` :class:`~repro.serve.net.codec.FrameError` — so a
*minor* additive schema change is forward-compatible while structural
changes bump :data:`~repro.serve.api.WIRE_VERSION`.

Scalars travel as 0-d arrays (``np.asarray(3)``), strings as 0-d unicode
arrays; ``_scalar``/``_text`` undo that on decode.  Query series are
cast to float32 on encode — the engine's native dtype — so client and
server never disagree on precision.
"""
from __future__ import annotations

import enum
from typing import Tuple, Union

import numpy as np

from repro.serve import api
from repro.serve.net import codec

__all__ = ["MsgType", "Message", "encode_message", "decode_message"]


class MsgType(enum.IntEnum):
    HELLO = 1         # client → server: wire version + client name
    SERVER_INFO = 2   # server → client: api.ServerInfo handshake card
    QUERY = 3         # client → server: api.QueryRequest
    RESULT = 4        # server → client: api.QueryResult
    ERROR = 5         # server → client: api.ErrorReply
    BYE = 6           # client → server: drain + close this connection
    # admin plane (PR 10): request/reply share the kind; the client sends
    # an (empty or small) dict, the server replies with the payload dict
    METRICS = 7       # ↔ the Prometheus text-exposition page
    HEALTH = 8        # ↔ readiness: drain state, queue depth, compaction
    TRACES = 9        # ↔ the flight recorder's retained slow traces


Message = Union[api.QueryRequest, api.QueryResult, api.ErrorReply,
                api.ServerInfo, dict]


def _scalar(fields, key, cast, default=None):
    if key not in fields:
        if default is not None:
            return default
        raise codec.FrameError("BAD_PAYLOAD", f"missing field {key!r}")
    return cast(fields[key].item())


def _text(fields, key, default=""):
    if key not in fields:
        return default
    return str(fields[key].item())


# -- per-type encoders -----------------------------------------------------

def _enc_hello(msg: dict) -> bytes:
    return codec.encode_payload({
        "wire_version": np.asarray(api.WIRE_VERSION, np.int32),
        "client": np.asarray(str(msg.get("client", ""))),
    })


def _enc_query(msg: api.QueryRequest) -> bytes:
    # trace_id / parent_span_id are additive (PR 10): an older decoder
    # ignores the extra fields, an older encoder's frames decode with the
    # 0 = "no trace" default — WIRE_VERSION stays 1
    return codec.encode_payload({
        "request_id": np.asarray(msg.request_id, np.int64),
        "series": np.asarray(msg.series, np.float32),
        "k": np.asarray(msg.k, np.int32),
        "tenant": np.asarray(msg.tenant),
        "trace_id": np.asarray(msg.trace_id, np.uint64),
        "parent_span_id": np.asarray(msg.parent_span_id, np.uint64),
    })


def _enc_result(msg: api.QueryResult) -> bytes:
    return codec.encode_payload({
        "request_id": np.asarray(msg.request_id, np.int64),
        "dist": np.asarray(msg.dist, np.float32),
        "gid": np.asarray(msg.gid, np.int32),
        "partitions_touched": np.asarray(msg.partitions_touched, np.int64),
        "candidates_scanned": np.asarray(msg.candidates_scanned, np.int64),
        "latency_ms": np.asarray(msg.latency_ms, np.float64),
        "batch_fill": np.asarray(msg.batch_fill, np.float64),
        "trace_id": np.asarray(msg.trace_id, np.uint64),
        "parent_span_id": np.asarray(msg.parent_span_id, np.uint64),
    })


def _enc_error(msg: api.ErrorReply) -> bytes:
    return codec.encode_payload({
        "request_id": np.asarray(msg.request_id, np.int64),
        "code": np.asarray(msg.code),
        "message": np.asarray(msg.message),
        "retry_after_ms": np.asarray(msg.retry_after_ms, np.float64),
    })


def _enc_info(msg: api.ServerInfo) -> bytes:
    return codec.encode_payload({
        "series_len": np.asarray(msg.series_len, np.int32),
        "k_max": np.asarray(msg.k_max, np.int32),
        "batch_size": np.asarray(msg.batch_size, np.int32),
        "wire_version": np.asarray(msg.wire_version, np.int32),
        "engine": np.asarray(msg.engine),
        "variant": np.asarray(msg.variant),
        "routing": np.asarray(msg.routing),
        "shards": np.asarray(msg.shards, np.int32),
        "max_pending": np.asarray(msg.max_pending, np.int32),
        "tenant_quota": np.asarray(msg.tenant_quota, np.int32),
    })


# -- admin plane (dict payloads both directions) ---------------------------
#
# A client's admin *request* is a small dict ({} or {"limit": n}); the
# server's *reply* reuses the same MsgType with the payload filled in.
# Every reply field decodes with a default, so the admin plane follows the
# same additive-evolution rule as QUERY/RESULT.

# readiness scalars a HEALTH reply carries (all encoded int64)
_HEALTH_FIELDS = ("ready", "draining", "pending", "queue_depth",
                  "exec_depth", "shards", "delta_occupancy",
                  "compaction_in_flight", "spans_dropped")


def _enc_metrics(msg: dict) -> bytes:
    return codec.encode_payload({
        "page": np.asarray(str(msg.get("page", "")))})


def _dec_metrics(fields) -> dict:
    return {"page": _text(fields, "page")}


def _enc_health(msg: dict) -> bytes:
    return codec.encode_payload({
        key: np.asarray(int(msg.get(key, 0)), np.int64)
        for key in _HEALTH_FIELDS})


def _dec_health(fields) -> dict:
    return {key: _scalar(fields, key, int, 0) for key in _HEALTH_FIELDS}


def _enc_traces(msg: dict) -> bytes:
    return codec.encode_payload({
        "limit": np.asarray(int(msg.get("limit", 0)), np.int64),
        "count": np.asarray(int(msg.get("count", 0)), np.int64),
        "traces_jsonl": np.asarray(str(msg.get("traces_jsonl", ""))),
    })


def _dec_traces(fields) -> dict:
    return {"limit": _scalar(fields, "limit", int, 0),
            "count": _scalar(fields, "count", int, 0),
            "traces_jsonl": _text(fields, "traces_jsonl")}


# -- per-type decoders -----------------------------------------------------

def _dec_hello(fields) -> dict:
    return {"wire_version": _scalar(fields, "wire_version", int),
            "client": _text(fields, "client")}


def _dec_query(fields) -> api.QueryRequest:
    if "series" not in fields:
        raise codec.FrameError("BAD_PAYLOAD", "missing field 'series'")
    return api.QueryRequest(
        series=np.asarray(fields["series"], np.float32),
        k=_scalar(fields, "k", int, 0),
        tenant=_text(fields, "tenant"),
        request_id=_scalar(fields, "request_id", int, 0),
        trace_id=_scalar(fields, "trace_id", int, 0),
        parent_span_id=_scalar(fields, "parent_span_id", int, 0))


def _dec_result(fields) -> api.QueryResult:
    for key in ("dist", "gid"):
        if key not in fields:
            raise codec.FrameError("BAD_PAYLOAD", f"missing field {key!r}")
    return api.QueryResult(
        request_id=_scalar(fields, "request_id", int, 0),
        dist=np.asarray(fields["dist"], np.float32),
        gid=np.asarray(fields["gid"], np.int32),
        partitions_touched=_scalar(fields, "partitions_touched", int, 0),
        candidates_scanned=_scalar(fields, "candidates_scanned", int, 0),
        latency_ms=_scalar(fields, "latency_ms", float, 0.0),
        batch_fill=_scalar(fields, "batch_fill", float, 0.0),
        trace_id=_scalar(fields, "trace_id", int, 0),
        parent_span_id=_scalar(fields, "parent_span_id", int, 0))


def _dec_error(fields) -> api.ErrorReply:
    code = _text(fields, "code", "INTERNAL")
    if code not in api.ERROR_CODES:
        raise codec.FrameError("BAD_PAYLOAD", f"unknown error code {code!r}")
    return api.ErrorReply(
        request_id=_scalar(fields, "request_id", int, 0),
        code=code,
        message=_text(fields, "message"),
        retry_after_ms=_scalar(fields, "retry_after_ms", float, 0.0))


def _dec_info(fields) -> api.ServerInfo:
    return api.ServerInfo(
        series_len=_scalar(fields, "series_len", int),
        k_max=_scalar(fields, "k_max", int),
        batch_size=_scalar(fields, "batch_size", int),
        wire_version=_scalar(fields, "wire_version", int,
                             api.WIRE_VERSION),
        engine=_text(fields, "engine"),
        variant=_text(fields, "variant"),
        routing=_text(fields, "routing"),
        shards=_scalar(fields, "shards", int, 0),
        max_pending=_scalar(fields, "max_pending", int, 0),
        tenant_quota=_scalar(fields, "tenant_quota", int, 0))


_ENCODERS = {
    MsgType.HELLO: _enc_hello,
    MsgType.SERVER_INFO: _enc_info,
    MsgType.QUERY: _enc_query,
    MsgType.RESULT: _enc_result,
    MsgType.ERROR: _enc_error,
    MsgType.BYE: lambda msg: codec.encode_payload({}),
    MsgType.METRICS: _enc_metrics,
    MsgType.HEALTH: _enc_health,
    MsgType.TRACES: _enc_traces,
}

_DECODERS = {
    MsgType.HELLO: _dec_hello,
    MsgType.SERVER_INFO: _dec_info,
    MsgType.QUERY: _dec_query,
    MsgType.RESULT: _dec_result,
    MsgType.ERROR: _dec_error,
    MsgType.BYE: lambda fields: {},
    MsgType.METRICS: _dec_metrics,
    MsgType.HEALTH: _dec_health,
    MsgType.TRACES: _dec_traces,
}


def encode_message(msg_type: MsgType, msg: Message) -> bytes:
    """One api dataclass (or handshake dict) → one complete frame."""
    return codec.encode_frame(int(msg_type), _ENCODERS[MsgType(msg_type)](msg))


def decode_message(msg_type: int, payload: bytes) -> Tuple[MsgType, Message]:
    """One received frame body → ``(MsgType, api dataclass | dict)``."""
    try:
        mtype = MsgType(msg_type)
    except ValueError:
        raise codec.FrameError("BAD_PAYLOAD",
                               f"unknown message type {msg_type}")
    return mtype, _DECODERS[mtype](codec.decode_payload(payload))
