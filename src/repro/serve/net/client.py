"""Client library for the CLIMBER++ network serving plane.

Two clients over the same frames:

  * :class:`ClimberClient` — blocking socket client.  ``query()`` is one
    round trip; ``query_batch()`` pipelines a whole list before reading
    any reply, which is how a single connection keeps the server's
    double-buffered admission full.  Observes every round trip into the
    ``net.rtt_ms`` histogram so client-perceived tails sit next to the
    server's ``serve.latency_ms`` in the same registry.
  * :class:`AsyncClimberClient` — asyncio client multiplexing concurrent
    ``query()`` awaitables over one connection by ``request_id``.

Typed refusals surface as exceptions: :class:`RetryLater` (backpressure
and quota — carries ``retry_after_ms``) and :class:`ServerError`
(everything else, with the wire ``code``).  Both carry the decoded
:class:`~repro.serve.api.ErrorReply`.
"""
from __future__ import annotations

import asyncio
import json
import socket
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import REGISTRY, TRACER
from repro.serve import api
from repro.serve.net import codec, schema

__all__ = ["ServerError", "RetryLater", "ClimberClient",
           "AsyncClimberClient"]


class ServerError(RuntimeError):
    """The server answered with a typed :class:`~repro.serve.api.ErrorReply`."""

    def __init__(self, reply: api.ErrorReply):
        super().__init__(f"{reply.code}: {reply.message}")
        self.reply = reply
        self.code = reply.code


class RetryLater(ServerError):
    """Backpressure / quota refusal; honor :attr:`retry_after_ms`."""

    @property
    def retry_after_ms(self) -> float:
        return self.reply.retry_after_ms


def _raise_for(reply: api.ErrorReply) -> None:
    if reply.code in ("RETRY_LATER", "QUOTA_EXCEEDED"):
        raise RetryLater(reply)
    raise ServerError(reply)


class ClimberClient:
    """Blocking client; usable as a context manager."""

    def __init__(self, host: str, port: int, *, tenant: str = "",
                 client_name: str = "climber-client",
                 timeout: float = 30.0):
        self.tenant = tenant
        self._client_name = client_name
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._next_rid = 0
        self.rtt_hist = REGISTRY.histogram("net.rtt_ms", client=client_name)
        self.info = self._handshake(client_name)

    def _handshake(self, client_name: str) -> api.ServerInfo:
        self._send(schema.MsgType.HELLO, {"client": client_name})
        mtype, msg = self._recv()
        if mtype == schema.MsgType.ERROR:
            _raise_for(msg)
        if mtype != schema.MsgType.SERVER_INFO:
            raise codec.FrameError(
                "BAD_PAYLOAD", f"expected SERVER_INFO, got {mtype.name}")
        return msg

    def _send(self, mtype: schema.MsgType, msg) -> None:
        self._sock.sendall(schema.encode_message(mtype, msg))

    def _recv(self):
        msg_type, payload = codec.read_frame_sync(self._sock)
        return schema.decode_message(msg_type, payload)

    def query(self, series: np.ndarray, k: int = 0, *,
              tenant: Optional[str] = None) -> api.QueryResult:
        """One kNN round trip.  Raises :class:`RetryLater` on
        backpressure/quota and :class:`ServerError` on other refusals."""
        return self.query_batch([series], k, tenant=tenant)[0]

    def query_batch(self, series_list: Sequence[np.ndarray], k: int = 0, *,
                    tenant: Optional[str] = None) -> List[api.QueryResult]:
        """Pipeline: send every request, then collect every reply.

        Replies are matched by ``request_id`` (the server answers in
        batch-completion order, not send order).  The first typed error
        raises after all replies are drained, so the stream stays in
        sync for the next call.

        The whole pipelined exchange runs under one ``net.rtt`` span
        with a client-minted ``trace_id`` that rides every request, so
        the server's admission/tick/fleet spans and the client's RTT
        span form ONE distributed trace.
        """
        tenant = self.tenant if tenant is None else tenant
        rids = []
        trace_id = TRACER.mint_trace_id()
        t0 = time.perf_counter()
        with TRACER.adopt(trace_id), \
                TRACER.span("net.rtt", client=self._client_name,
                            requests=len(series_list)) as rtt_span:
            for series in series_list:
                rid = self._next_rid
                self._next_rid += 1
                rids.append(rid)
                self._send(schema.MsgType.QUERY, api.QueryRequest(
                    series=np.asarray(series, np.float32), k=k,
                    tenant=tenant, request_id=rid,
                    trace_id=trace_id,
                    parent_span_id=rtt_span.span_id))
            replies: Dict[int, object] = {}
            while len(replies) < len(rids):
                mtype, msg = self._recv()
                if mtype not in (schema.MsgType.RESULT,
                                 schema.MsgType.ERROR):
                    raise codec.FrameError(
                        "BAD_PAYLOAD",
                        f"unexpected {mtype.name} from server")
                replies[msg.request_id] = msg
        rtt_ms = (time.perf_counter() - t0) * 1e3
        self.rtt_hist.observe(rtt_ms / max(1, len(rids)))
        for rid in rids:
            if isinstance(replies[rid], api.ErrorReply):
                _raise_for(replies[rid])
        return [replies[rid] for rid in rids]

    # -- admin plane -------------------------------------------------------
    def _admin(self, mtype: schema.MsgType, msg: dict) -> dict:
        """One admin round trip (call between query batches — the
        blocking client is sequential, so no replies can interleave)."""
        self._send(mtype, msg)
        got_type, got = self._recv()
        if got_type == schema.MsgType.ERROR:
            _raise_for(got)
        if got_type != mtype:
            raise codec.FrameError(
                "BAD_PAYLOAD", f"expected {mtype.name}, got {got_type.name}")
        return got

    def metrics(self) -> str:
        """The server's Prometheus text-exposition page, over the same
        socket queries ride (no separate scrape endpoint to deploy)."""
        return self._admin(schema.MsgType.METRICS, {})["page"]

    def health(self) -> dict:
        """Readiness card: ``ready`` / ``draining``, queue + executor
        depth, shard count, delta occupancy, compaction in flight,
        spans dropped (see ``ClimberServer.health``)."""
        return self._admin(schema.MsgType.HEALTH, {})

    def traces(self, limit: int = 0) -> List[dict]:
        """Recent tail-sampled slow/error traces from the server's
        flight recorder, newest last (``limit`` keeps the newest N)."""
        reply = self._admin(schema.MsgType.TRACES, {"limit": limit})
        text = reply["traces_jsonl"].strip()
        return [json.loads(line) for line in text.splitlines() if line]

    def close(self) -> None:
        try:
            self._send(schema.MsgType.BYE, {})
            self._sock.shutdown(socket.SHUT_WR)
        except OSError:
            pass
        finally:
            self._sock.close()

    def __enter__(self) -> "ClimberClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class AsyncClimberClient:
    """Asyncio client: concurrent ``query()`` calls share one connection.

    Each in-flight request parks a future keyed by ``request_id``; one
    reader task resolves them as RESULT/ERROR frames arrive, so any
    number of tasks can await queries concurrently — the client-side
    mirror of the server's double-buffered admission.
    """

    def __init__(self, *, tenant: str = "",
                 client_name: str = "climber-async-client"):
        self.tenant = tenant
        self._client_name = client_name
        self._reader = None
        self._writer = None
        self._futures: Dict[int, asyncio.Future] = {}
        self._next_rid = 0
        self._reader_task = None
        self.info: Optional[api.ServerInfo] = None
        self.rtt_hist = REGISTRY.histogram("net.rtt_ms", client=client_name)

    @classmethod
    async def connect(cls, host: str, port: int, *, tenant: str = "",
                      client_name: str = "climber-async-client"
                      ) -> "AsyncClimberClient":
        self = cls(tenant=tenant, client_name=client_name)
        self._reader, self._writer = await asyncio.open_connection(host, port)
        self._writer.write(schema.encode_message(
            schema.MsgType.HELLO, {"client": client_name}))
        await self._writer.drain()
        msg_type, payload = await codec.read_frame(self._reader)
        mtype, msg = schema.decode_message(msg_type, payload)
        if mtype == schema.MsgType.ERROR:
            _raise_for(msg)
        if mtype != schema.MsgType.SERVER_INFO:
            raise codec.FrameError(
                "BAD_PAYLOAD", f"expected SERVER_INFO, got {mtype.name}")
        self.info = msg
        self._reader_task = asyncio.ensure_future(self._read_loop())
        return self

    async def _read_loop(self) -> None:
        try:
            while True:
                msg_type, payload = await codec.read_frame(self._reader)
                mtype, msg = schema.decode_message(msg_type, payload)
                fut = self._futures.pop(getattr(msg, "request_id", -1), None)
                if fut is not None and not fut.done():
                    fut.set_result(msg)
        except (asyncio.IncompleteReadError, ConnectionError,
                asyncio.CancelledError):
            for fut in self._futures.values():
                if not fut.done():
                    fut.set_exception(ConnectionError("connection closed"))
            self._futures.clear()

    async def query(self, series: np.ndarray, k: int = 0, *,
                    tenant: Optional[str] = None) -> api.QueryResult:
        rid = self._next_rid
        self._next_rid += 1
        fut = asyncio.get_event_loop().create_future()
        self._futures[rid] = fut
        trace_id = TRACER.mint_trace_id()
        t0 = time.perf_counter()
        # the span covers only the send — the await yields the event loop
        # to other tasks, so a span across it would nest their traces
        with TRACER.adopt(trace_id), \
                TRACER.span("net.rtt", client=self._client_name,
                            requests=1) as rtt_span:
            self._writer.write(schema.encode_message(
                schema.MsgType.QUERY, api.QueryRequest(
                    series=np.asarray(series, np.float32), k=k,
                    tenant=self.tenant if tenant is None else tenant,
                    request_id=rid,
                    trace_id=trace_id,
                    parent_span_id=rtt_span.span_id)))
            await self._writer.drain()
        msg = await fut
        self.rtt_hist.observe((time.perf_counter() - t0) * 1e3)
        if isinstance(msg, api.ErrorReply):
            _raise_for(msg)
        return msg

    async def close(self) -> None:
        if self._writer is not None:
            try:
                self._writer.write(schema.encode_message(
                    schema.MsgType.BYE, {}))
                await self._writer.drain()
            except (ConnectionError, OSError):
                pass
            self._writer.close()
        if self._reader_task is not None:
            self._reader_task.cancel()
            try:
                await self._reader_task
            except asyncio.CancelledError:
                pass
