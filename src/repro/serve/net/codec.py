"""Frame codec — length-prefixed, versioned, checksummed, pickle-free.

One frame on the wire is::

    +--------+---------+----------+----------+-------------+-------+
    | magic  | version | msg_type | reserved | payload_len | crc32 |
    |  u16   |   u16   |   u16    |   u16    |     u32     |  u32  |
    +--------+---------+----------+----------+-------------+-------+
    |                payload_len bytes of npz payload              |
    +--------------------------------------------------------------+

big-endian, 16-byte header (:data:`HEADER`).  The payload is a
``numpy.savez`` archive (``allow_pickle=False`` both ways — a hostile
peer can send bytes, never objects); scalars ride as 0-d arrays, strings
as 0-d unicode arrays.  ``crc32`` covers the payload only, so a flipped
bit anywhere in the body is caught before ``np.load`` ever parses it.

Every way the bytes can be wrong maps to a typed :class:`FrameError`
(``BAD_MAGIC`` / ``VERSION_MISMATCH`` / ``BAD_CRC`` / ``TRUNCATED`` /
``BAD_PAYLOAD`` / ``TOO_LARGE``) — the server answers decode failures
with a typed :class:`~repro.serve.api.ErrorReply` instead of dying, and
a client can distinguish "retry" from "speak a newer protocol".
"""
from __future__ import annotations

import io
import struct
import zlib
from typing import Dict, Tuple

import numpy as np

from repro.serve.api import WIRE_VERSION

__all__ = ["MAGIC", "HEADER", "HEADER_LEN", "MAX_PAYLOAD", "FrameError",
           "encode_payload", "decode_payload", "encode_frame",
           "decode_header", "read_frame", "read_frame_sync"]

MAGIC = 0xC11B                      # "CLIMBer" — rejects non-protocol bytes
HEADER = struct.Struct(">HHHHII")   # magic, version, msg_type, reserved,
HEADER_LEN = HEADER.size            # payload_len, crc32  (= 16 bytes)
MAX_PAYLOAD = 64 * 1024 * 1024      # refuse absurd length prefixes early


class FrameError(ValueError):
    """A frame failed to decode; ``code`` says how.

    Codes: ``BAD_MAGIC``, ``VERSION_MISMATCH``, ``BAD_CRC``, ``TRUNCATED``,
    ``BAD_PAYLOAD``, ``TOO_LARGE``.  ``VERSION_MISMATCH`` carries the
    peer's version in :attr:`peer_version`.
    """

    def __init__(self, code: str, message: str, peer_version: int = -1):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.peer_version = peer_version


def encode_payload(fields: Dict[str, object]) -> bytes:
    """npz-encode a flat dict of arrays / scalars / strings."""
    arrays = {}
    for key, val in fields.items():
        arr = np.asarray(val)
        if arr.dtype == object:
            raise TypeError(f"field {key!r} is not npz-encodable "
                            f"({type(val).__name__})")
        arrays[key] = arr
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def decode_payload(payload: bytes) -> Dict[str, np.ndarray]:
    """Decode an npz payload back to a dict of arrays (never objects)."""
    try:
        with np.load(io.BytesIO(payload), allow_pickle=False) as npz:
            return {key: npz[key] for key in npz.files}
    except Exception as exc:                      # zipfile/np parse errors
        raise FrameError("BAD_PAYLOAD", f"payload did not decode: {exc}")


def encode_frame(msg_type: int, payload: bytes,
                 version: int = WIRE_VERSION) -> bytes:
    if len(payload) > MAX_PAYLOAD:
        raise FrameError("TOO_LARGE",
                         f"payload {len(payload)}B > {MAX_PAYLOAD}B")
    header = HEADER.pack(MAGIC, version, msg_type, 0, len(payload),
                         zlib.crc32(payload) & 0xFFFFFFFF)
    return header + payload


def decode_header(header: bytes) -> Tuple[int, int, int]:
    """Validate a header; returns (msg_type, payload_len, crc32)."""
    if len(header) < HEADER_LEN:
        raise FrameError("TRUNCATED",
                         f"header {len(header)}B < {HEADER_LEN}B")
    magic, version, msg_type, _, length, crc = HEADER.unpack(
        header[:HEADER_LEN])
    if magic != MAGIC:
        raise FrameError("BAD_MAGIC", f"magic {magic:#06x} != {MAGIC:#06x}")
    if version != WIRE_VERSION:
        raise FrameError("VERSION_MISMATCH",
                         f"peer wire version {version} != {WIRE_VERSION}",
                         peer_version=version)
    if length > MAX_PAYLOAD:
        raise FrameError("TOO_LARGE", f"payload {length}B > {MAX_PAYLOAD}B")
    return msg_type, length, crc


def _check_crc(payload: bytes, crc: int) -> None:
    got = zlib.crc32(payload) & 0xFFFFFFFF
    if got != crc:
        raise FrameError("BAD_CRC", f"payload crc {got:#010x} != {crc:#010x}")


async def read_frame(reader) -> Tuple[int, bytes]:
    """Read one frame from an ``asyncio.StreamReader``.

    Returns ``(msg_type, payload)``; raises :class:`FrameError` on any
    malformed byte and ``ConnectionError``/``IncompleteReadError`` when
    the peer hangs up mid-frame.
    """
    header = await reader.readexactly(HEADER_LEN)
    msg_type, length, crc = decode_header(header)
    payload = await reader.readexactly(length) if length else b""
    _check_crc(payload, crc)
    return msg_type, payload


def read_frame_sync(sock) -> Tuple[int, bytes]:
    """Blocking :func:`read_frame` over a plain socket (client side)."""
    header = _recv_exactly(sock, HEADER_LEN)
    msg_type, length, crc = decode_header(header)
    payload = _recv_exactly(sock, length) if length else b""
    _check_crc(payload, crc)
    return msg_type, payload


def _recv_exactly(sock, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise ConnectionError(
                f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)
