"""ClimberServer — asyncio TCP front for a BatchedServingLoop.

The serving path that used to be one blocking Python call is split into
two planes that overlap:

  * the **asyncio event loop** (host plane) accepts connections, decodes
    frames, validates requests (shape / k / quota) and *assembles* the
    next fixed-shape batch — featurize-ready, zero-padded — into the
    building buffer;
  * the **executor thread** (device plane) pops assembled batches off a
    bounded queue and runs ``engine.execute_prepared`` (featurize →
    descend → plan → refine on device).

Because assembly happens on the event loop while ``execute_prepared``
blocks only the executor thread, batch N+1 is admitted, validated and
padded while tick N is still on the device — the classic double buffer.
``admission_depth`` bounds how many assembled batches may wait; when the
buffers are full (or ``max_pending`` requests are in flight) the server
answers with a typed ``RETRY_LATER`` instead of queueing unboundedly,
and per-tenant quotas (optionally tightened for tenants hogging the
fleet's per-shard load) answer ``QUOTA_EXCEEDED``.

Every reply a connection receives is one of the
:mod:`repro.serve.api` dataclasses over the :mod:`~repro.serve.net.codec`
frame format — the server never sends an unframed byte and never dies on
a malformed one.
"""
from __future__ import annotations

import asyncio
import queue
import threading
from typing import List, Optional

import numpy as np

from repro.obs import REGISTRY, TRACER
from repro.obs.export import to_prometheus
from repro.obs.flight import FLIGHT
from repro.serve import api
from repro.serve.knn_engine import BatchedServingLoop, QueryTicket
from repro.serve.net import codec, schema

__all__ = ["ClimberServer", "serve_in_thread"]


class _Connection:
    """Per-connection state: outbox queue + obs counters."""

    __slots__ = ("cid", "writer", "outbox", "pending", "closing", "alive",
                 "frames_in", "frames_out")

    def __init__(self, cid: int, writer):
        self.cid = cid
        self.writer = writer
        self.outbox: asyncio.Queue = asyncio.Queue()
        self.pending = 0          # admitted, answer not yet queued
        self.closing = False      # BYE received: close once drained
        self.alive = True
        label = f"c{cid}"
        self.frames_in = REGISTRY.counter("net.frames_in", conn=label)
        self.frames_out = REGISTRY.counter("net.frames_out", conn=label)

    def post(self, mtype: schema.MsgType, msg) -> None:
        if self.alive:
            self.outbox.put_nowait((mtype, msg))


class ClimberServer:
    """Typed TCP serving plane over one engine's admission path.

    Args:
      engine: a :class:`~repro.serve.ClimberEngine` or
        :class:`~repro.fleet.FleetEngine` (anything speaking the
        ``BatchedServingLoop`` ticket protocol).
      host / port: bind address; ``port=0`` picks a free port
        (read :attr:`port` after :meth:`start`).
      config: admission knobs (``admission_depth`` / ``max_pending`` /
        ``tenant_quota`` / ``hot_tenant_share`` / ``flush_interval_ms``)
        from one :class:`~repro.serve.api.ServingConfig`; None reuses
        the engine's config.
    """

    def __init__(self, engine: BatchedServingLoop, host: str = "127.0.0.1",
                 port: int = 0, *,
                 config: Optional[api.ServingConfig] = None):
        self.engine = engine
        self.host = host
        self._requested_port = port
        self.config = config if config is not None \
            else getattr(engine, "config", api.ServingConfig())
        self.port: Optional[int] = None
        if self.config.trace_ring:
            TRACER.set_capacity(self.config.trace_ring)
        # tail-sampled slow/error traces served over the TRACES admin kind
        self.flight = FLIGHT

        # double buffer: building batch (event loop) + bounded exec queue
        self._building: List[QueryTicket] = []
        self._exec_queue: "queue.Queue" = queue.Queue(
            maxsize=max(1, self.config.admission_depth))
        self._executing = False      # exec thread is inside a device tick
        self._pending = 0            # admitted tickets not yet answered
        self._draining = False
        self.overlap_admissions = 0  # admits that happened during a tick

        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._exec_thread: Optional[threading.Thread] = None
        self._flush_task = None
        self._conns: dict = {}
        self._next_cid = 0

        self._n_conns = REGISTRY.counter("net.connections")
        self._n_queries = REGISTRY.counter("net.queries")
        self._n_rejected = REGISTRY.counter("net.rejected")
        self._n_overlap = REGISTRY.counter("net.overlap_admissions")

    # -- lifecycle --------------------------------------------------------

    async def start(self) -> None:
        """Bind, start the executor thread and the flush timer."""
        self._loop = asyncio.get_running_loop()
        self._exec_thread = threading.Thread(
            target=self._exec_loop, name="climber-server-exec", daemon=True)
        self._exec_thread.start()
        self._server = await asyncio.start_server(
            self._handle_connection, self.host, self._requested_port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._flush_task = asyncio.ensure_future(self._flush_timer())

    async def stop(self) -> None:
        """Graceful shutdown: drain every in-flight request, then close.

        New admissions are refused with ``SHUTTING_DOWN`` the moment this
        is called; requests already admitted are executed and answered
        before the sockets close.
        """
        self._draining = True
        if self._server is not None:
            self._server.close()           # no new connections
        # drain: flush the partial batch, wait for the exec queue + tick
        while self._pending > 0:
            self._try_flush()
            await asyncio.sleep(0.002)
        self._exec_queue.put(None)         # executor sentinel
        if self._flush_task is not None:
            self._flush_task.cancel()
        for conn in list(self._conns.values()):
            conn.outbox.put_nowait(None)   # writer sentinel
        if self._server is not None:
            await self._server.wait_closed()
        if self._exec_thread is not None:
            await self._loop.run_in_executor(None, self._exec_thread.join)

    async def serve_forever(self) -> None:
        await self.start()
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # -- admission (event loop side) --------------------------------------

    def _effective_quota(self, tenant: str) -> int:
        quota = self.config.tenant_quota
        if not quota:
            return 0
        share = self.config.hot_tenant_share
        if share < 1.0 and hasattr(self.engine, "tenant_load") \
                and self.engine.tenant_load(tenant) > share:
            return max(1, quota // 2)
        return quota

    def _admit(self, req: api.QueryRequest, conn: _Connection) -> None:
        """Validate + quota-check + append to the building batch.

        Every refusal posts a typed ErrorReply; success posts nothing
        (the answer arrives when the batch executes).  The admission
        decision runs under a ``net.admit`` span adopted into the
        request's client-minted trace, so a refusal is a one-span trace
        and an admit links the client's RTT span to the tick that will
        execute it."""
        with TRACER.adopt(req.trace_id, req.parent_span_id), \
                TRACER.span("net.admit",
                            conn=f"c{getattr(conn, 'cid', '?')}",
                            tenant=req.tenant):
            self._admit_inner(req, conn)

    def _admit_inner(self, req: api.QueryRequest,
                     conn: _Connection) -> None:
        if self._draining:
            self._reject(conn, req, "SHUTTING_DOWN", "server draining")
            return
        if self._pending >= self.config.max_pending or \
                len(self._building) >= self.engine.batch_size:
            # both buffers full — typed backpressure with a retry hint
            # scaled to the engine's mean tick time so far
            stats = self.engine.stats
            hint = max(1.0, stats.total_s / stats.ticks * 1e3
                       if stats.ticks else 1.0)
            self._reject(conn, req, "RETRY_LATER",
                         "admission buffers full", retry_after_ms=hint)
            return
        quota = self._effective_quota(req.tenant)
        if quota and self.engine.tenant_inflight(req.tenant) >= quota:
            self._reject(conn, req, "QUOTA_EXCEEDED",
                         f"tenant {req.tenant!r} at quota {quota}",
                         retry_after_ms=1.0)
            return
        try:
            ticket = self.engine.make_ticket(req)
        except ValueError as exc:
            self._reject(conn, req, "BAD_REQUEST", str(exc))
            return
        ticket.conn = conn
        conn.pending += 1
        self._pending += 1
        self._n_queries.inc()
        if self._executing:
            # the device is mid-tick N: this request lands in batch N+1 —
            # the overlap the double buffer exists for
            self.overlap_admissions += 1
            self._n_overlap.inc()
        self._building.append(ticket)
        if len(self._building) >= self.engine.batch_size:
            self._try_flush()

    def _reject(self, conn: _Connection, req: api.QueryRequest, code: str,
                message: str, retry_after_ms: float = 0.0) -> None:
        self._n_rejected.inc()
        # noted before the enclosing net.admit span finishes, so the
        # flight recorder retains the refused request's trace
        self.flight.note_error(req.trace_id, code)
        conn.post(schema.MsgType.ERROR,
                  api.ErrorReply(request_id=req.request_id, code=code,
                                 message=message,
                                 retry_after_ms=retry_after_ms))

    def _try_flush(self) -> None:
        """Hand the building batch to the executor if a buffer is free."""
        if not self._building or self._exec_queue.full():
            return
        tickets, self._building = self._building, []
        qbatch = self.engine.prepare_batch(tickets)
        self._exec_queue.put_nowait((qbatch, tickets))

    async def _flush_timer(self) -> None:
        """Flush partial batches so a trickle never waits for a full one."""
        interval = max(0.0005, self.config.flush_interval_ms / 1e3)
        while True:
            await asyncio.sleep(interval)
            self._try_flush()

    # -- execution (executor thread side) ---------------------------------

    def _exec_loop(self) -> None:
        while True:
            item = self._exec_queue.get()
            if item is None:
                return
            qbatch, tickets = item
            self._executing = True
            try:
                self.engine.execute_prepared(qbatch, tickets)
            except Exception as exc:   # typed INTERNAL, never a dead server
                self.engine.fail_tickets(
                    tickets, api.ErrorReply(
                        request_id=0, code="INTERNAL",
                        message=f"{type(exc).__name__}: {exc}"))
                # the tick's spans already closed when the exception
                # unwound, so note the error and finish a tiny net.fail
                # error-trigger span per trace to retain the evidence
                for t in tickets:
                    if t.trace is not None and t.trace.trace_id:
                        self.flight.note_error(t.trace.trace_id,
                                               "INTERNAL")
                        with TRACER.adopt(t.trace), \
                                TRACER.span("net.fail", code="INTERNAL"):
                            pass
            finally:
                self._executing = False
            self._loop.call_soon_threadsafe(self._deliver, tickets)

    def _deliver(self, tickets: List[QueryTicket]) -> None:
        """Back on the event loop: route each answered ticket out."""
        for ticket in tickets:
            self._pending -= 1
            conn = ticket.conn
            if conn is None or not conn.alive:
                continue
            conn.pending -= 1
            if isinstance(ticket.result, api.QueryResult):
                conn.post(schema.MsgType.RESULT, ticket.result)
            elif isinstance(ticket.result, api.ErrorReply):
                conn.post(schema.MsgType.ERROR, ticket.result)
            if conn.closing and conn.pending == 0:
                conn.outbox.put_nowait(None)
        self._try_flush()   # a buffer just freed: push a held batch

    # -- connection handling ----------------------------------------------

    def server_info(self) -> api.ServerInfo:
        engine = self.engine
        fleet = getattr(engine, "fleet", None)
        return api.ServerInfo(
            series_len=engine.series_len, k_max=engine.k,
            batch_size=engine.batch_size,
            engine="fleet" if fleet is not None else "climber",
            variant=getattr(engine, "variant", ""),
            routing=getattr(engine, "routing", ""),
            shards=len(fleet.shards) if fleet is not None else 0,
            max_pending=self.config.max_pending,
            tenant_quota=self.config.tenant_quota)

    def health(self) -> dict:
        """The HEALTH admin reply: readiness + load + lifecycle state.

        ``ready`` is "this server will admit a query right now": not
        draining.  The depth fields expose how full the double buffer is
        (``queue_depth`` = building batch, ``exec_depth`` = assembled
        batches waiting for the device); ``compaction_in_flight`` says a
        background INX rebuild is running (expect a latency shoulder);
        ``spans_dropped`` rising between scrapes means the trace ring is
        undersized for the load (raise ``ServingConfig.trace_ring``).
        """
        engine = self.engine
        fleet = getattr(engine, "fleet", None)
        dropped = TRACER._dropped
        return {
            "ready": int(not self._draining),
            "draining": int(self._draining),
            "pending": self._pending,
            "queue_depth": len(self._building),
            "exec_depth": self._exec_queue.qsize(),
            "shards": len(fleet.shards) if fleet is not None else 0,
            "delta_occupancy": fleet.delta.occupancy
            if fleet is not None else 0,
            "compaction_in_flight": int(
                fleet is not None and fleet._seal_ticket is not None),
            "spans_dropped": int(dropped.value)
            if dropped is not None else 0,
        }

    def _answer_admin(self, mtype: schema.MsgType, msg: dict,
                      conn: _Connection) -> None:
        """Admin plane: reply in the same MsgType over the same socket."""
        if mtype == schema.MsgType.METRICS:
            conn.post(mtype, {"page": to_prometheus(REGISTRY)})
        elif mtype == schema.MsgType.HEALTH:
            conn.post(mtype, self.health())
        else:                                   # TRACES
            limit = int(msg.get("limit", 0))
            records = self.flight.records(limit)
            conn.post(mtype, {"limit": limit, "count": len(records),
                              "traces_jsonl": self.flight.jsonl(limit)})

    async def _handle_connection(self, reader, writer) -> None:
        cid = self._next_cid
        self._next_cid += 1
        conn = _Connection(cid, writer)
        self._conns[cid] = conn
        self._n_conns.inc()
        writer_task = asyncio.ensure_future(self._write_loop(conn))
        try:
            with TRACER.span("net.connection", conn=f"c{cid}"):
                await self._read_loop(reader, conn)
        except (asyncio.IncompleteReadError, ConnectionError):
            pass                            # peer hung up
        except codec.FrameError as exc:
            # malformed bytes: answer typed, then close — a corrupt
            # length prefix desyncs the stream, so no resync attempt
            code = "VERSION_MISMATCH" if exc.code == "VERSION_MISMATCH" \
                else "BAD_FRAME"
            conn.post(schema.MsgType.ERROR,
                      api.ErrorReply(request_id=0, code=code,
                                     message=str(exc)))
        finally:
            conn.closing = True
            if conn.pending == 0:
                conn.outbox.put_nowait(None)
            await writer_task
            conn.alive = False
            self._conns.pop(cid, None)
            writer.close()

    async def _read_loop(self, reader, conn: _Connection) -> None:
        # handshake: HELLO in, SERVER_INFO out
        msg_type, payload = await codec.read_frame(reader)
        conn.frames_in.inc()
        mtype, _hello = schema.decode_message(msg_type, payload)
        if mtype != schema.MsgType.HELLO:
            raise codec.FrameError(
                "BAD_PAYLOAD", f"expected HELLO, got {mtype.name}")
        conn.post(schema.MsgType.SERVER_INFO, self.server_info())
        while True:
            msg_type, payload = await codec.read_frame(reader)
            conn.frames_in.inc()
            mtype, msg = schema.decode_message(msg_type, payload)
            if mtype == schema.MsgType.BYE:
                return
            if mtype in (schema.MsgType.METRICS, schema.MsgType.HEALTH,
                         schema.MsgType.TRACES):
                self._answer_admin(mtype, msg, conn)
                continue
            if mtype != schema.MsgType.QUERY:
                raise codec.FrameError(
                    "BAD_PAYLOAD", f"unexpected {mtype.name} from client")
            self._admit(msg, conn)

    async def _write_loop(self, conn: _Connection) -> None:
        while True:
            item = await conn.outbox.get()
            if item is None:
                break
            mtype, msg = item
            try:
                conn.writer.write(schema.encode_message(mtype, msg))
                await conn.writer.drain()
                conn.frames_out.inc()
            except (ConnectionError, OSError):
                conn.alive = False
                return


def serve_in_thread(engine: BatchedServingLoop, host: str = "127.0.0.1",
                    port: int = 0, *,
                    config: Optional[api.ServingConfig] = None):
    """Run a :class:`ClimberServer` on a daemon thread's event loop.

    Returns ``(server, stop)`` once the port is bound — ``server.port``
    is live — where ``stop()`` drains gracefully and joins the thread.
    The in-process path tests and benchmarks use this to get a real
    socket without giving up the calling thread.
    """
    server = ClimberServer(engine, host, port, config=config)
    started = threading.Event()
    loop_box: dict = {}

    def _run():
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        loop_box["loop"] = loop
        loop.run_until_complete(server.start())
        started.set()
        try:
            loop.run_forever()
        finally:
            loop.close()

    thread = threading.Thread(target=_run, name="climber-server", daemon=True)
    thread.start()
    if not started.wait(timeout=30):
        raise RuntimeError("server failed to start within 30s")
    loop = loop_box["loop"]

    def stop():
        fut = asyncio.run_coroutine_threadsafe(server.stop(), loop)
        fut.result(timeout=60)
        # one extra loop turn so transport-close callbacks run before the
        # loop itself shuts down (else their GC warns "loop is closed")
        asyncio.run_coroutine_threadsafe(asyncio.sleep(0.02), loop).result()
        loop.call_soon_threadsafe(loop.stop)
        thread.join(timeout=10)

    return server, stop
