from repro.serve.net.client import (AsyncClimberClient, ClimberClient,
                                    RetryLater, ServerError)
from repro.serve.net.codec import (FrameError, decode_payload, encode_frame,
                                   encode_payload, read_frame,
                                   read_frame_sync)
from repro.serve.net.schema import MsgType, decode_message, encode_message
from repro.serve.net.server import ClimberServer, serve_in_thread
