"""Batched serving engine: continuous prefill + decode over request slots.

A miniature vLLM-shaped loop adapted to static shapes:
  * fixed number of slots (the serving batch), each slot holds one sequence;
  * new requests prefill into a free slot's cache region;
  * every engine tick decodes one token for all live slots;
  * finished slots (EOS or max_len) are freed and refilled.

Static-shape adaptation (recorded in DESIGN.md): slot caches are a single
[B_slots, ...] cache tree at max_len; per-slot lengths are data, not shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, eos_id: int = -1):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        cfg = model.cfg
        enc_len = max_len if cfg.family == "encdec" else 0
        img_len = cfg.num_image_tokens if cfg.family == "vlm" else 0
        self.cache = init_cache(cfg, slots, max_len, enc_len=enc_len,
                                img_len=img_len)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_len = np.zeros(slots, dtype=np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: decode_step(model, p, c, t))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- slot management -------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (one at a time; a real
        engine batches prefills — this keeps the single-slot cache insert
        simple and exact)."""
        for i in range(self.slots):
            if self.slot_req[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            s = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            cfg = self.model.cfg
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros((1, s, cfg.d_model), jnp.bfloat16)
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (1, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
            logits, cache1 = prefill(self.model, self.params, batch,
                                     max_len=self.max_len, kv_chunk=64)
            # write slot i of the engine cache from the single-row cache
            def put(full, one):
                if one.ndim == 0:
                    return full
                # batch dim position differs per cache entry; match by shape
                for axis in range(one.ndim):
                    if one.shape[axis] == 1 and full.shape[axis] == self.slots:
                        idx = [slice(None)] * one.ndim
                        idx[axis] = i
                        return full.at[tuple(idx)].set(one[tuple(
                            [slice(None)] * axis + [0]
                            + [slice(None)] * (one.ndim - axis - 1))])
                return full
            self.cache = jax.tree_util.tree_map(put, self.cache, cache1)
            self.cache["len"] = jnp.int32(0)   # per-slot lens tracked below
            nxt = int(jnp.argmax(logits[0, -1]))
            req.generated.append(nxt)
            self.slot_req[i] = req
            self.slot_len[i] = s

    def _tick_tokens(self) -> jnp.ndarray:
        toks = np.zeros((self.slots, 1), dtype=np.int32)
        for i, req in enumerate(self.slot_req):
            if req is not None and req.generated:
                toks[i, 0] = req.generated[-1]
        return jnp.asarray(toks)

    def step(self) -> None:
        """One engine tick: admit, decode one token for every live slot."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return
        # decode with cache_len = max live length (validity masks keep
        # shorter slots correct: their pad positions were zero-filled and
        # masked by position <= len)
        self.cache["len"] = jnp.int32(int(self.slot_len[live].max()))
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._tick_tokens())
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i in live:
            req = self.slot_req[i]
            req.generated.append(int(nxt[i]))
            self.slot_len[i] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or int(nxt[i]) == self.eos_id
                    or self.slot_len[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None

    def run_until_drained(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                return
            self.step()
