"""Typed serving API — the one request/response contract for every entry.

Until this module existed each entry point had its own implicit calling
convention: ``ClimberEngine.submit`` took a *mutable* ``QueryRequest`` it
wrote the answer back into, ``run`` took ``(queries, k)`` tuples and
returned parallel arrays, and the fleet threaded dict-shaped metrics
alongside.  A network serving plane cannot ship "a Python object the
server mutates" over a socket, so the contract is made explicit here:

  * :class:`QueryRequest`  — one immutable kNN question (series, k,
    tenant, correlation id);
  * :class:`QueryResult`   — one immutable answer (dist/gid + per-query
    execution metrics);
  * :class:`ErrorReply`    — every way the server can say no, typed
    (validation, backpressure, quota, version skew, shutdown);
  * :class:`ServerInfo`    — the handshake card a server deals a client
    (static shapes, limits, wire version);
  * :class:`ServingConfig` — every engine/server construction knob in one
    documented dataclass shared by :class:`~repro.serve.ClimberEngine`,
    :class:`~repro.fleet.FleetEngine`, and
    :class:`~repro.serve.net.ClimberServer`.

The same four dataclasses are used in-process (``submit_request`` /
``QueryTicket.result``) and on the wire (``repro.serve.net.schema`` maps
them to npz-encoded frames), so the process boundary never invents a
second schema — the multi-host fleet can reuse this contract verbatim.

The old mutable-``QueryRequest`` path keeps working through a thin
adapter in ``BatchedServingLoop.submit`` that emits a one-time
``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["WIRE_VERSION", "ERROR_CODES", "QueryRequest", "QueryResult",
           "ErrorReply", "ServerInfo", "ServingConfig", "resolve_config"]

# Bumped whenever a frame header or payload field changes incompatibly.
# Client and server exchange it in HELLO / SERVER_INFO and the codec
# rejects mismatched frames with a typed VERSION_MISMATCH error — never by
# misreading bytes.
WIRE_VERSION = 1

# Every refusal the serving plane can express (ErrorReply.code):
#   BAD_REQUEST      — request malformed (series shape, k > k_max, …)
#   BAD_FRAME        — bytes did not decode (magic/CRC/payload)
#   VERSION_MISMATCH — peer speaks a different WIRE_VERSION
#   RETRY_LATER      — admission backpressure: both double buffers full;
#                      retry after ``retry_after_ms``
#   QUOTA_EXCEEDED   — the tenant is at its in-flight admission quota
#   SHUTTING_DOWN    — server draining; no new admissions
#   INTERNAL         — the executor raised; request not served
ERROR_CODES = ("BAD_REQUEST", "BAD_FRAME", "VERSION_MISMATCH",
               "RETRY_LATER", "QUOTA_EXCEEDED", "SHUTTING_DOWN", "INTERNAL")


@dataclasses.dataclass(frozen=True, eq=False)
class QueryRequest:
    """One immutable kNN question.

    ``eq=False`` on purpose: the ndarray field makes structural equality
    ambiguous — compare ``series`` explicitly where it matters.
    """

    series: np.ndarray        # [series_len] float32 raw query series
    k: int = 0                # answer size; 0 = the server/engine default
    tenant: str = ""          # admission-quota identity (fleet shard key)
    request_id: int = 0       # caller-chosen correlation id (echoed back)
    # -- trace context (additive, wire-optional: 0 = absent) --------------
    trace_id: int = 0         # distributed trace this request belongs to
    parent_span_id: int = 0   # caller's span to parent server spans under


@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """One immutable answer, metrics riding along."""

    request_id: int
    dist: np.ndarray          # [k] ascending squared ED (PAD_DIST pad)
    gid: np.ndarray           # [k] record ids (-1 pad)
    partitions_touched: int = 0
    candidates_scanned: int = 0
    latency_ms: float = 0.0   # server-side arrival → answer wall time
    batch_fill: float = 0.0   # live fraction of the tick that served it
    # -- trace context (additive, wire-optional: 0 = absent) --------------
    trace_id: int = 0         # echo of the request's trace id
    parent_span_id: int = 0   # server span that produced this answer


@dataclasses.dataclass(frozen=True)
class ErrorReply:
    """A typed refusal (see :data:`ERROR_CODES`)."""

    request_id: int
    code: str
    message: str = ""
    retry_after_ms: float = 0.0   # backpressure hint (RETRY_LATER / quota)

    def __post_init__(self):
        if self.code not in ERROR_CODES:
            raise ValueError(f"unknown error code {self.code!r}; "
                             f"expected one of {ERROR_CODES}")


@dataclasses.dataclass(frozen=True)
class ServerInfo:
    """The handshake card: what this server statically is.

    Sent in reply to HELLO so a client can validate requests locally
    (series length, k ceiling) before paying a round trip.
    """

    series_len: int           # required query shape [series_len]
    k_max: int                # static answer-size ceiling
    batch_size: int           # admission batch shape (informational)
    wire_version: int = WIRE_VERSION
    engine: str = ""          # "climber" | "fleet"
    variant: str = ""         # planner variant the engine runs
    routing: str = ""         # fleet routing mode ("" for single-index)
    shards: int = 0           # sealed shard count at handshake time
    max_pending: int = 0      # admission backpressure bound
    tenant_quota: int = 0     # per-tenant in-flight quota (0 = unlimited)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Every engine/server constructor knob, consolidated and documented.

    One frozen dataclass shared by :class:`~repro.serve.ClimberEngine`
    (which reads the batch/plan fields), :class:`~repro.fleet.FleetEngine`
    (adds the routing/maintenance fields) and
    :class:`~repro.serve.net.ClimberServer` (adds the admission fields).
    Engines still accept the individual keyword arguments — those are
    folded into a config — but a config built once can be handed to all
    three layers.

    Batch / planning (ClimberEngine + FleetEngine):

      batch_size        rows per tick — the one static batch shape that
                        jits (fewer live requests are zero-padded).
      k                 default answer size; 0 = the index config's ``k``.
      variant           registered planner name ("knn" | "adaptive" |
                        "od_smallest" | "exhaustive" | user-registered).
      use_kernel        refine backend: True = streaming fused Pallas
                        kernel, False = dense jnp oracle, None = backend
                        default (fused on accelerators, dense on CPU).
      max_slots         static slot budget for plan compaction; None = the
                        lossless ``default_slot_budget`` (or the index
                        config's ``query_max_slots`` override).
      plan_cache_size   LRU capacity of the per-query plan cache
                        (0 = off; planning then runs every tick).

    Fleet routing / upkeep (FleetEngine):

      routing           "signature" (top-``fanout`` router fan-out),
                        "adaptive" (per-query score-mass fan-out, learned
                        or configured threshold), or "exhaustive"
                        (lossless fallback).
      fanout            shards the router selects per query (the per-query
                        cap under "adaptive" routing); None = the fleet
                        config's default.
      placement         sealed-shard execution: "host", "mesh", or None
                        for the fleet default (mesh when one is attached).
      maintenance_every run lifecycle maintenance after every Nth queue
                        tick (0 = only when called explicitly).
      merge_policy      the LSM :class:`~repro.fleet.lifecycle.merge.
                        MergePolicy` maintenance applies (None = fleet /
                        policy defaults).  Engine-local — never shipped
                        over the wire.

    Network admission (ClimberServer):

      admission_depth   assembled batches the executor queue holds — the
                        double buffer.  2 means the host assembles batch
                        N+1 (and N+2) while the device executes batch N;
                        when all buffers are full new requests get a typed
                        RETRY_LATER reply.
      max_pending       total requests admitted but unanswered (building
                        batch + queued batches + executing batch) before
                        backpressure kicks in.
      tenant_quota      per-tenant in-flight admission cap (0 = off);
                        rejected with QUOTA_EXCEEDED.
      hot_tenant_share  fleet-load guard on top of ``tenant_quota``: when
                        a tenant's share of the fleet's per-shard query
                        load (``FleetStats.per_shard_queries``) exceeds
                        this fraction, its effective quota halves.  1.0
                        disables the guard.
      flush_interval_ms a partially filled admission batch is flushed to
                        the executor after this long, so a trickle of
                        requests never waits for a full batch.

    Observability (any engine; see docs/OBSERVABILITY.md):

      trace_ring        span-ring capacity applied to the process tracer
                        at engine construction (0 = leave the tracer's
                        current capacity — default 4096 — unchanged).
                        Evictions under load are counted on the
                        ``repro_obs_spans_dropped_total`` page metric.
      sentinel_rate     fraction of served queries the online recall
                        sentinel shadow-samples (FleetEngine only; 0 =
                        sentinel off).  Audits run off-path on the
                        maintenance hook; the running mean lands on the
                        ``fleet.online_recall`` gauge.
      sentinel_recalibrate_every
                        re-learn the adaptive-routing threshold from the
                        sentinel's production traces after every N
                        audited queries (0 = record traces only).
    """

    # batch / planning
    batch_size: int = 8
    k: int = 0
    variant: str = "adaptive"
    use_kernel: Optional[bool] = None
    max_slots: Optional[int] = None
    plan_cache_size: int = 256
    # fleet routing / upkeep
    routing: str = "signature"
    fanout: Optional[int] = None
    placement: Optional[str] = None
    maintenance_every: int = 0
    merge_policy: Optional[object] = None
    # network admission
    admission_depth: int = 2
    max_pending: int = 64
    tenant_quota: int = 0
    hot_tenant_share: float = 1.0
    flush_interval_ms: float = 2.0
    # observability
    trace_ring: int = 0
    sentinel_rate: float = 0.0
    sentinel_recalibrate_every: int = 0

    def replace(self, **kw) -> "ServingConfig":
        return dataclasses.replace(self, **kw)


def resolve_config(config: Optional[ServingConfig], kwargs: dict,
                   allowed: Tuple[str, ...]) -> ServingConfig:
    """Fold legacy keyword arguments into one :class:`ServingConfig`.

    ``config`` and individual kwargs are mutually exclusive (no silent
    precedence rules); unknown kwargs fail like a normal bad keyword.
    """
    unknown = [k for k in kwargs if k not in allowed]
    if unknown:
        raise TypeError(f"unexpected keyword argument(s) {unknown}; "
                        f"this engine accepts {sorted(allowed)}")
    if config is not None:
        if kwargs:
            raise TypeError(
                f"pass either config= or individual keyword arguments, "
                f"not both (got config and {sorted(kwargs)})")
        return config
    return ServingConfig(**kwargs)
