from repro.serve import api
from repro.serve.api import ErrorReply, QueryResult, ServerInfo, ServingConfig
from repro.serve.engine import Engine, Request
from repro.serve.knn_engine import (BatchedServingLoop, ClimberEngine,
                                    EngineStats, QueryMetrics, QueryRequest,
                                    QueryTicket)
