from repro.serve.engine import Engine, Request
from repro.serve.knn_engine import (ClimberEngine, EngineStats, QueryMetrics,
                                    QueryRequest)
