from repro.serve.engine import Engine, Request
from repro.serve.knn_engine import (BatchedServingLoop, ClimberEngine,
                                    EngineStats, QueryMetrics, QueryRequest)
