"""ClimberEngine — batched kNN serving over the CLIMBER index.

The retrieval-plane sibling of the slot-based LLM ``Engine``
(repro.serve.engine): requests are admitted into fixed-shape query batches
so the whole plan→refine pipeline jits once per batch size and every tick
serves a full batch.  One code path covers all execution backends — the
engine resolves its planner by name from the registry
(``repro.core.query``), compacts every plan to the static slot budget, and
executes refine through ``dispatch_refine``, which picks dense /
Pallas-kernel / shard_map-sharded execution from the engine's ``mesh``.

Static-shape adaptation: a tick always runs ``batch_size`` query rows; when
fewer requests are waiting the tail rows are zero-padded and their outputs
dropped.  Planning and refine are row-independent (per-row top_k /
arg-reductions only), so a query's (dist, gid) is bit-identical whichever
batch it rides in — ``run`` on a big batch equals per-query ``knn_query``.

Per-query metrics (partitions touched, candidates scanned, latency,
batch fill) ride on every completed request; ``EngineStats`` aggregates
them into the queries/sec numbers the benchmarks report.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import ClimberIndex
from repro.core.query import candidates_scanned, default_slot_budget, \
    get_planner, plan as plan_queries
from repro.core.refine import dispatch_refine


@dataclasses.dataclass
class QueryRequest:
    """One kNN request: a raw series in, (dist, gid) + metrics out."""

    rid: int
    series: np.ndarray                       # [n] raw query series
    k: int = 0                               # 0 => engine default
    dist: Optional[np.ndarray] = None        # [k] ascending ED
    gid: Optional[np.ndarray] = None         # [k] record ids (−1 pad)
    metrics: Optional["QueryMetrics"] = None
    done: bool = False


@dataclasses.dataclass(frozen=True)
class QueryMetrics:
    partitions_touched: int    # distinct partitions the plan selected
    candidates_scanned: int    # records resident in those partitions
    latency_s: float           # wall time of the tick that served it
    batch_fill: float          # live fraction of that tick's batch


@dataclasses.dataclass
class EngineStats:
    """Aggregate over everything the engine has served."""

    queries: int = 0
    ticks: int = 0
    total_s: float = 0.0
    partitions_touched: float = 0.0          # running sums (means below)
    candidates_scanned: float = 0.0

    def observe(self, batch_metrics: List[QueryMetrics]) -> None:
        self.ticks += 1
        for m in batch_metrics:
            self.queries += 1
            self.partitions_touched += m.partitions_touched
            self.candidates_scanned += m.candidates_scanned
        if batch_metrics:
            self.total_s += batch_metrics[0].latency_s

    @property
    def queries_per_sec(self) -> float:
        return self.queries / self.total_s if self.total_s else 0.0

    @property
    def mean_partitions_touched(self) -> float:
        return self.partitions_touched / self.queries if self.queries else 0.0

    @property
    def mean_candidates_scanned(self) -> float:
        return self.candidates_scanned / self.queries if self.queries else 0.0


class ClimberEngine:
    """Batched, sharded, kernel-first kNN serving loop.

    Args:
      index: a built ClimberIndex.  With ``mesh`` given, the store is laid
        out over the mesh's data axis at construction (ragged partition
        counts are padded), so every tick runs the shard_map refine.
      batch_size: rows per tick — the one static batch shape that jits.
      variant: registered planner name ("knn" | "adaptive" | "od_smallest"
        or anything added via ``register_planner``).
      k: default answer size (0 => ``cfg.k``).
      use_kernel: route the refine distance loop through the Pallas kernel.
      max_slots: static slot budget for plan compaction (None => the
        lossless ``default_slot_budget`` unless ``cfg.query_max_slots``
        overrides it; stays None — i.e. no compaction — for
        user-registered variants with no knowable lossless bound).

    The configuration (variant, k, backend, budget, store layout) is baked
    into the compiled pipeline at construction; mutating these attributes
    afterwards has no effect on the cached trace — build a new engine
    instead.
    """

    def __init__(self, index: ClimberIndex, *, batch_size: int = 8,
                 variant: str = "adaptive", k: int = 0,
                 use_kernel: bool = False, mesh=None,
                 data_axis: str = "data",
                 max_slots: Optional[int] = None):
        get_planner(variant)                 # fail fast on unknown variants
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.index = index
        self.batch_size = batch_size
        self.variant = variant
        self.k = k or index.cfg.k
        self.use_kernel = use_kernel
        self.mesh = mesh
        self.data_axis = data_axis
        if max_slots is None:
            max_slots = index.cfg.query_max_slots
        if max_slots is None:
            max_slots = default_slot_budget(index, variant)
        self.max_slots = max_slots

        self.store = index.store
        if mesh is not None and mesh.shape[data_axis] > 1:
            from repro.distributed.store import shard_store
            self.store = shard_store(index.store, mesh, data_axis=data_axis)

        self.queue: List[QueryRequest] = []
        self.stats = EngineStats()
        self._exec = jax.jit(self._pipeline)

    # -- the one fused pipeline (plan → compact → dispatch refine) --------
    def _pipeline(self, queries: jnp.ndarray):
        index = self.index
        p4r, _ = index.featurize(queries)
        qp = plan_queries(index, p4r, variant=self.variant,
                          max_slots=self.max_slots)
        dist, gid = dispatch_refine(
            self.store, queries, qp.sel_part, qp.sel_lo, qp.sel_hi, self.k,
            mesh=self.mesh, data_axis=self.data_axis,
            use_kernel=self.use_kernel)
        return dist, gid, qp.partitions_touched(), \
            candidates_scanned(qp, self.store)

    def _execute(self, qbatch: np.ndarray):
        """One fixed-shape tick.  Returns host arrays + wall seconds."""
        t0 = time.perf_counter()
        dist, gid, touched, scanned = self._exec(jnp.asarray(qbatch))
        jax.block_until_ready(gid)
        dt = time.perf_counter() - t0
        return (np.asarray(dist), np.asarray(gid), np.asarray(touched),
                np.asarray(scanned), dt)

    # -- request-queue serving -------------------------------------------
    def submit(self, req: QueryRequest) -> None:
        """Enqueue a request (rejects malformed ones before they can
        poison a whole batch)."""
        n = self.index.cfg.series_len
        series = np.asarray(req.series, dtype=np.float32)
        if series.shape != (n,):
            raise ValueError(f"request {req.rid}: series shape "
                             f"{series.shape} != ({n},)")
        if req.k > self.k:
            raise ValueError(f"request {req.rid}: k={req.k} exceeds the "
                             f"engine's static answer size k={self.k}")
        req.series = series
        self.queue.append(req)

    def step(self) -> int:
        """Serve one batch from the queue; returns #requests completed."""
        if not self.queue:
            return 0
        live = self.queue[:min(self.batch_size, len(self.queue))]
        n = self.index.cfg.series_len
        qbatch = np.zeros((self.batch_size, n), dtype=np.float32)
        for i, req in enumerate(live):
            qbatch[i] = req.series
        # pop only after the tick succeeds: a device error leaves the
        # queue intact instead of dropping in-flight requests
        dist, gid, touched, scanned, dt = self._execute(qbatch)
        del self.queue[:len(live)]

        fill = len(live) / self.batch_size
        metrics = []
        for i, req in enumerate(live):
            kq = req.k or self.k
            req.dist, req.gid = dist[i, :kq], gid[i, :kq]
            req.metrics = QueryMetrics(
                partitions_touched=int(touched[i]),
                candidates_scanned=int(scanned[i]),
                latency_s=dt, batch_fill=fill)
            req.done = True
            metrics.append(req.metrics)
        self.stats.observe(metrics)
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step():
                return

    # -- direct batch API -------------------------------------------------
    def run(self, queries, k: int = 0
            ) -> Tuple[np.ndarray, np.ndarray, List[QueryMetrics]]:
        """Serve ``[Q, n]`` queries through fixed-shape ticks.

        Returns ``(dist [Q, k], gid [Q, k], metrics per query)``; results
        are bit-identical to per-query :func:`repro.core.knn_query` with
        the engine's variant/backend (planning and refine are
        row-independent, so batching and tail padding don't change them).
        """
        queries = np.asarray(queries, dtype=np.float32)
        kq = k or self.k
        if kq > self.k:
            raise ValueError(f"k={kq} exceeds the engine's static answer "
                             f"size k={self.k}; build the engine with a "
                             f"larger k")
        qn = queries.shape[0]
        if qn == 0:
            return (np.zeros((0, kq), np.float32),
                    np.full((0, kq), -1, np.int32), [])
        dists, gids, metrics = [], [], []
        for lo in range(0, qn, self.batch_size):
            chunk = queries[lo:lo + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, chunk.shape[1]), np.float32)])
            dist, gid, touched, scanned, dt = self._execute(chunk)
            nlive = min(self.batch_size, qn - lo)
            dists.append(dist[:nlive, :kq])
            gids.append(gid[:nlive, :kq])
            batch_metrics = [
                QueryMetrics(partitions_touched=int(touched[i]),
                             candidates_scanned=int(scanned[i]),
                             latency_s=dt,
                             batch_fill=nlive / self.batch_size)
                for i in range(nlive)]
            metrics.extend(batch_metrics)
            self.stats.observe(batch_metrics)
        return np.concatenate(dists), np.concatenate(gids), metrics
