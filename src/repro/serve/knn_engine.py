"""ClimberEngine — batched kNN serving over the CLIMBER index.

The retrieval-plane sibling of the slot-based LLM ``Engine``
(repro.serve.engine): requests are admitted into fixed-shape query batches
so the whole plan→refine pipeline jits once per batch size and every tick
serves a full batch.  One code path covers all execution backends — the
engine resolves its planner by name from the registry
(``repro.core.query``), compacts every plan to the static slot budget, and
executes refine through ``dispatch_refine``, which picks dense /
Pallas-kernel / shard_map-sharded execution from the engine's ``mesh``.

Static-shape adaptation: a tick always runs ``batch_size`` query rows; when
fewer requests are waiting the tail rows are zero-padded and their outputs
dropped.  Planning and refine are row-independent (per-row top_k /
arg-reductions only), so a query's (dist, gid) is bit-identical whichever
batch it rides in — ``run`` on a big batch equals per-query ``knn_query``.

Query plan cache: a plan depends only on the query's P4→ rank signature
(and the frozen index), so the engine memoizes compacted plan rows in a
:class:`PlanCache` LRU keyed on the signature prefix.  The pipeline is
staged as three jits — featurize → plan → refine — and a tick whose live
rows all hit the cache skips the planning stage (assignment-distance
matmuls + trie descent) entirely; any miss re-plans the whole fixed-shape
batch and refreshes every row's cache entry.  Cached rows are exactly a
prior plan stage's output, so caching never changes results.
``EngineStats`` counts per-row hits/misses.  The fleet reuses the same
:class:`PlanCache` for its device plans, prefixing every key with a
*placement epoch* that increments when the sealed shard set changes — the
single-index engine's index is frozen, so its epoch is implicitly 0.

The admission machinery (request queue, fixed-shape ticks, per-query
metrics) lives in :class:`BatchedServingLoop` so other executors — e.g. the
fleet engine (``repro.fleet``) — serve through the identical loop.

Per-query metrics (partitions touched, candidates scanned, latency,
batch fill) ride on every completed request; ``EngineStats`` aggregates
them into the queries/sec numbers the benchmarks report.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import warnings
import weakref
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import ClimberIndex
from repro.core.query import candidates_scanned, default_slot_budget, \
    get_planner, plan as plan_queries
from repro.core.refine import dispatch_refine, resolve_use_kernel
from repro.obs import REGISTRY, TRACER
from repro.obs.tracer import TraceContext
from repro.serve import api

# distinguishes each serving loop's metric series in the process registry
_LOOP_SEQ = itertools.count()

# the mutable-QueryRequest adapter warns once per process, not per call
_LEGACY_SUBMIT_WARNED = False


class PlanCache:
    """Epoch-aware LRU of per-query plan rows.

    Keys are arbitrary hashables: :class:`ClimberEngine` keys rows on the
    query's rank-signature bytes (its index is frozen — epoch implicitly
    0); the fleet (``repro.fleet``) keys on ``(placement epoch, planner
    variant, raw query bytes)``, so bumping the epoch orphans every entry
    planned against a retired shard layout without an explicit flush —
    stale entries simply age out of the LRU.  ``hits`` / ``misses`` are
    lifetime counters; callers diff them around a lookup burst to
    attribute per-call stats.
    """

    __slots__ = ("size", "hits", "misses", "_rows")

    def __init__(self, size: int):
        self.size = int(size)
        self.hits = 0
        self.misses = 0
        self._rows: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, key):
        """The cached row (refreshing LRU order) or None; counts the
        lookup as a hit or a miss."""
        row = self._rows.get(key)
        if row is None:
            self.misses += 1
            return None
        self.hits += 1
        self._rows.move_to_end(key)
        return row

    def put(self, key, row) -> None:
        """Insert or refresh a row, evicting LRU entries over capacity."""
        if self.size <= 0:
            return
        self._rows[key] = row
        self._rows.move_to_end(key)
        while len(self._rows) > self.size:
            self._rows.popitem(last=False)

    def clear(self) -> None:
        self._rows.clear()


@dataclasses.dataclass
class QueryRequest:
    """One kNN request: a raw series in, (dist, gid) + metrics out.

    .. deprecated::
        This is the *mutable* legacy request the engine writes answers
        back into.  New code should submit the frozen
        :class:`repro.serve.api.QueryRequest` via
        :meth:`BatchedServingLoop.submit_request` and read the immutable
        :class:`repro.serve.api.QueryResult` off the returned
        :class:`QueryTicket`.  ``submit`` keeps accepting this class
        through a thin adapter (one-time ``DeprecationWarning``).
    """

    rid: int
    series: np.ndarray                       # [n] raw query series
    k: int = 0                               # 0 => engine default
    dist: Optional[np.ndarray] = None        # [k] ascending ED
    gid: Optional[np.ndarray] = None         # [k] record ids (−1 pad)
    metrics: Optional["QueryMetrics"] = None
    done: bool = False
    submitted_at: Optional[float] = None     # perf_counter at admission


class QueryTicket:
    """One in-flight admission: a frozen :class:`repro.serve.api.
    QueryRequest` paired with its eventual outcome.

    ``result`` becomes an :class:`repro.serve.api.QueryResult` on
    success or an :class:`repro.serve.api.ErrorReply` on failure; ``done``
    flips atomically last.  Tickets are what the queue, the network
    server's admission buffers, and the executor hand around — the frozen
    request is never mutated.
    """

    __slots__ = ("request", "series", "result", "done", "submitted_at",
                 "legacy", "conn", "trace")

    def __init__(self, request: api.QueryRequest, series: np.ndarray,
                 submitted_at: Optional[float] = None):
        self.request = request
        self.series = series               # validated float32 [n] view
        self.result = None                 # QueryResult | ErrorReply
        self.done = False
        self.submitted_at = submitted_at \
            if submitted_at is not None else time.perf_counter()
        self.legacy: Optional[QueryRequest] = None   # write-back adapter
        self.conn = None                   # net server's delivery handle
        self.trace: Optional[TraceContext] = None    # admitting context

    @property
    def ok(self) -> bool:
        return self.done and isinstance(self.result, api.QueryResult)


@dataclasses.dataclass(frozen=True)
class QueryMetrics:
    partitions_touched: int    # distinct partitions the plan selected
    candidates_scanned: int    # records resident in those partitions
    latency_s: float           # wall time of the tick that served it
    batch_fill: float          # live fraction of that tick's batch


@dataclasses.dataclass
class EngineStats:
    """Aggregate over everything the engine has served."""

    queries: int = 0
    ticks: int = 0
    total_s: float = 0.0
    partitions_touched: float = 0.0          # running sums (means below)
    candidates_scanned: float = 0.0
    plan_cache_hits: int = 0                 # per-row signature-cache hits
    plan_cache_misses: int = 0

    def observe(self, batch_metrics: List[QueryMetrics]) -> None:
        self.ticks += 1
        for m in batch_metrics:
            self.queries += 1
            self.partitions_touched += m.partitions_touched
            self.candidates_scanned += m.candidates_scanned
        if batch_metrics:
            self.total_s += batch_metrics[0].latency_s

    @property
    def queries_per_sec(self) -> float:
        return self.queries / self.total_s if self.total_s else 0.0

    @property
    def mean_partitions_touched(self) -> float:
        return self.partitions_touched / self.queries if self.queries else 0.0

    @property
    def mean_candidates_scanned(self) -> float:
        return self.candidates_scanned / self.queries if self.queries else 0.0

    @property
    def plan_cache_hit_rate(self) -> float:
        n = self.plan_cache_hits + self.plan_cache_misses
        return self.plan_cache_hits / n if n else 0.0

    def snapshot(self) -> dict:
        """Counters + derived rates as one plain dict (for benchmark
        artifacts and operator output, mirroring ``FleetStats.snapshot``)."""
        d = dataclasses.asdict(self)
        d["queries_per_sec"] = self.queries_per_sec
        d["mean_partitions_touched"] = self.mean_partitions_touched
        d["mean_candidates_scanned"] = self.mean_candidates_scanned
        d["plan_cache_hit_rate"] = self.plan_cache_hit_rate
        return d


class BatchedServingLoop:
    """Fixed-shape batch admission shared by every serving executor.

    Subclasses implement :meth:`_execute`, which serves one zero-padded
    ``[batch_size, series_len]`` tick and returns host arrays
    ``(dist, gid, partitions_touched, candidates_scanned, seconds)``.
    """

    def __init__(self, *, series_len: int, batch_size: int, k: int):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.series_len = series_len
        self.batch_size = batch_size
        self.k = k
        self.queue: List[QueryTicket] = []
        self.stats = EngineStats()
        # per-tenant in-flight admissions (the net server's quota hook);
        # finish/fail run on the executor thread, so counts take a lock
        self._tenant_lock = threading.Lock()
        self._tenant_inflight: Dict[str, int] = {}
        # registry wiring: per-instance label so concurrent loops (and
        # benchmark cells building fresh engines) keep distinct series
        self.obs_label = f"{type(self).__name__.lower()}{next(_LOOP_SEQ)}"
        self.latency_hist = REGISTRY.histogram("serve.latency_ms",
                                               loop=self.obs_label)
        self.queue_gauge = REGISTRY.gauge("serve.queue_depth",
                                          loop=self.obs_label)
        # pull-based stats exposure: the collector holds only a weakref,
        # so EngineStats keeps its exact dataclass shape (snapshot() keys
        # are asserted by tier-1 tests) and dead loops unregister alone
        ref = weakref.ref(self)

        def _collect():
            loop = ref()
            if loop is None:
                return None
            s = loop.stats
            return {"serve.queries": s.queries, "serve.ticks": s.ticks,
                    "serve.queries_per_sec": s.queries_per_sec,
                    "serve.plan_cache_hit_rate": s.plan_cache_hit_rate}

        REGISTRY.add_collector(_collect, loop=self.obs_label)

    def reset_metrics(self) -> None:
        """Zero this loop's aggregate stats and latency histogram (the
        benchmarks call it between warmup and the timed window)."""
        self.stats = EngineStats()
        self.latency_hist.reset()

    def capture_device_trace(self, log_dir):
        """Opt-in ``jax.profiler`` capture of everything this loop runs
        inside the block (see :func:`repro.obs.profile.device_trace`)."""
        from repro.obs import device_trace
        return device_trace(log_dir)

    def _execute(self, qbatch: np.ndarray, nlive: int):
        raise NotImplementedError

    def _after_tick(self) -> None:
        """Hook run after each completed queue tick (between batches — off
        the per-query latency path).  Executors with background upkeep
        override it: the fleet engine runs its lifecycle maintenance here
        (compaction triggers, shard merge/retirement)."""

    # -- typed admission ---------------------------------------------------
    def validate_series(self, series, rid: int = 0) -> np.ndarray:
        """The admission contract: a ``[series_len]`` float32 row or a
        ValueError (so one bad series can't poison a whole batch)."""
        series = np.asarray(series, dtype=np.float32)
        if series.shape != (self.series_len,):
            raise ValueError(f"request {rid}: series shape "
                             f"{series.shape} != ({self.series_len},)")
        return series

    def validate_k(self, k: int, rid: int = 0) -> None:
        if k > self.k:
            raise ValueError(f"request {rid}: k={k} exceeds the "
                             f"engine's static answer size k={self.k}")

    def make_ticket(self, req: api.QueryRequest) -> QueryTicket:
        """Validate a frozen request into an in-flight ticket (counted
        against its tenant's quota) without enqueueing it — the net
        server's admission buffers own ticket placement themselves."""
        series = self.validate_series(req.series, req.request_id)
        self.validate_k(req.k, req.request_id)
        ticket = QueryTicket(req, series)
        # trace handoff: a wire-carried context wins (cross-process); an
        # in-process caller's open span is captured otherwise, so the
        # executor thread's tick can adopt the *admitting* context either
        # way — the tick span then joins the request's trace, not a fresh
        # executor-thread-rooted one
        if req.trace_id:
            ticket.trace = TraceContext(req.trace_id, req.parent_span_id)
        else:
            ticket.trace = TRACER.current_context()
        with self._tenant_lock:
            self._tenant_inflight[req.tenant] = \
                self._tenant_inflight.get(req.tenant, 0) + 1
        return ticket

    def tenant_inflight(self, tenant: str) -> int:
        """Admitted-but-unanswered requests of one tenant (quota hook)."""
        with self._tenant_lock:
            return self._tenant_inflight.get(tenant, 0)

    def _release_tenant(self, ticket: QueryTicket) -> None:
        with self._tenant_lock:
            t = ticket.request.tenant
            n = self._tenant_inflight.get(t, 0) - 1
            if n > 0:
                self._tenant_inflight[t] = n
            else:
                self._tenant_inflight.pop(t, None)

    # -- request-queue serving -------------------------------------------
    def submit_request(self, req: api.QueryRequest) -> QueryTicket:
        """Enqueue a frozen :class:`repro.serve.api.QueryRequest`; the
        returned ticket carries the :class:`repro.serve.api.QueryResult`
        once a tick serves it."""
        ticket = self.make_ticket(req)
        self.queue.append(ticket)
        self.queue_gauge.set(len(self.queue))
        return ticket

    def submit(self, req: QueryRequest) -> QueryTicket:
        """Legacy adapter: enqueue a *mutable* :class:`QueryRequest`.

        Deprecated (one-time warning): wraps the request into the typed
        path and writes ``dist`` / ``gid`` / ``metrics`` / ``done`` back
        into the caller's object when the tick completes, so existing
        call sites keep working unchanged.
        """
        global _LEGACY_SUBMIT_WARNED
        if not _LEGACY_SUBMIT_WARNED:
            _LEGACY_SUBMIT_WARNED = True
            warnings.warn(
                "submit() with the mutable repro.serve.QueryRequest is "
                "deprecated; use submit_request(repro.serve.api."
                "QueryRequest) and read the ticket's QueryResult",
                DeprecationWarning, stacklevel=2)
        series = self.validate_series(req.series, req.rid)
        self.validate_k(req.k, req.rid)
        req.series = series
        if req.submitted_at is None:
            req.submitted_at = time.perf_counter()
        ticket = QueryTicket(
            api.QueryRequest(series=series, k=req.k,
                             request_id=req.rid),
            series, submitted_at=req.submitted_at)
        ticket.legacy = req
        ticket.trace = TRACER.current_context()
        with self._tenant_lock:
            self._tenant_inflight[""] = self._tenant_inflight.get("", 0) + 1
        self.queue.append(ticket)
        self.queue_gauge.set(len(self.queue))
        return ticket

    def prepare_batch(self, tickets: List[QueryTicket]) -> np.ndarray:
        """Assemble validated tickets into the one fixed batch shape —
        featurize-ready, zero-padded — the executor jits against.  This
        is the host half of double buffering: the net server assembles
        batch N+1 here while the executor thread runs batch N."""
        if len(tickets) > self.batch_size:
            raise ValueError(f"{len(tickets)} tickets exceed "
                             f"batch_size={self.batch_size}")
        qbatch = np.zeros((self.batch_size, self.series_len),
                          dtype=np.float32)
        for i, t in enumerate(tickets):
            qbatch[i] = t.series
        return qbatch

    @staticmethod
    def _batch_context(tickets: List[QueryTicket]):
        """The trace context one tick adopts: the first admitted ticket's.

        A batch can mix requests from several traces; the tick span joins
        the first one (its ``traces`` attr counts the distinct ids so the
        others remain discoverable) — every ticket's own result still
        echoes its *own* trace id.
        """
        ids = {t.trace.trace_id for t in tickets if t.trace is not None}
        for t in tickets:
            if t.trace is not None:
                return t.trace, len(ids)
        return None, 0

    def execute_prepared(self, qbatch: np.ndarray,
                         tickets: List[QueryTicket]) -> int:
        """Run one pre-assembled tick and complete its tickets.

        The device half of double buffering: safe to call from a
        dedicated executor thread while the event loop keeps admitting
        into the next batch.  Raises whatever ``_execute`` raises — the
        caller decides whether to fail the tickets
        (:meth:`fail_tickets`) or retry.

        The tick span (and everything under it, including the
        maintenance hook and any compaction it triggers) adopts the
        admitting requests' trace context, so executor-thread spans stay
        in the request's trace instead of rooting their own.
        """
        ctx, ntraces = self._batch_context(tickets)
        with TRACER.adopt(ctx):
            with TRACER.span("serve.tick", loop=self.obs_label,
                             live=len(tickets), traces=ntraces) as tick:
                dist, gid, touched, scanned, dt = \
                    self._execute(qbatch, len(tickets))
            self._finish_batch(tickets, dist, gid, touched, scanned, dt,
                               tick_span=tick)
            self._after_tick()
        return len(tickets)

    def fail_tickets(self, tickets: List[QueryTicket],
                     error: api.ErrorReply) -> None:
        """Resolve tickets with a typed refusal (executor fault paths)."""
        for t in tickets:
            t.result = dataclasses.replace(
                error, request_id=t.request.request_id)
            self._release_tenant(t)
            if t.legacy is not None:
                t.legacy.done = True
            t.done = True

    def _finish_batch(self, tickets: List[QueryTicket], dist, gid,
                      touched, scanned, dt: float,
                      tick_span=None) -> None:
        """Complete tickets from one executed tick: typed results, the
        legacy write-back adapter, latency histogram, aggregate stats.
        ``tick_span`` (the finished ``serve.tick``) stamps each result's
        trace echo so a remote client can link answer to server tick."""
        done_at = time.perf_counter()
        fill = len(tickets) / self.batch_size
        metrics = []
        for i, t in enumerate(tickets):
            req = t.request
            kq = req.k or self.k
            qm = QueryMetrics(partitions_touched=int(touched[i]),
                              candidates_scanned=int(scanned[i]),
                              latency_s=dt, batch_fill=fill)
            # arrival-to-answer: queue wait + every tick that ran first
            arrived = t.submitted_at if t.submitted_at is not None \
                else done_at - dt
            latency_ms = (done_at - arrived) * 1e3
            t.result = api.QueryResult(
                request_id=req.request_id,
                dist=dist[i, :kq], gid=gid[i, :kq],
                partitions_touched=qm.partitions_touched,
                candidates_scanned=qm.candidates_scanned,
                latency_ms=latency_ms, batch_fill=fill,
                trace_id=t.trace.trace_id if t.trace is not None else 0,
                parent_span_id=tick_span.span_id
                if tick_span is not None else 0)
            if t.legacy is not None:      # thin adapter: mutate in place
                t.legacy.dist, t.legacy.gid = dist[i, :kq], gid[i, :kq]
                t.legacy.metrics = qm
                t.legacy.done = True
            self._release_tenant(t)
            t.done = True
            metrics.append(qm)
            self.latency_hist.observe(latency_ms)
        self.stats.observe(metrics)

    def step(self) -> int:
        """Serve one batch from the queue; returns #requests completed."""
        if not self.queue:
            return 0
        live = self.queue[:min(self.batch_size, len(self.queue))]
        qbatch = self.prepare_batch(live)
        ctx, ntraces = self._batch_context(live)
        # pop only after the tick succeeds: a device error leaves the
        # queue intact instead of dropping in-flight requests
        with TRACER.adopt(ctx):
            with TRACER.span("serve.tick", loop=self.obs_label,
                             live=len(live), traces=ntraces) as tick:
                dist, gid, touched, scanned, dt = \
                    self._execute(qbatch, len(live))
            del self.queue[:len(live)]
            self.queue_gauge.set(len(self.queue))
            self._finish_batch(live, dist, gid, touched, scanned, dt,
                               tick_span=tick)
            self._after_tick()
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step():
                return

    # -- direct batch API -------------------------------------------------
    def run(self, queries, k: int = 0
            ) -> Tuple[np.ndarray, np.ndarray, List[QueryMetrics]]:
        """Serve ``[Q, n]`` queries through fixed-shape ticks.

        Returns ``(dist [Q, k], gid [Q, k], metrics per query)``; results
        are bit-identical to per-query :func:`repro.core.knn_query` with
        the engine's variant/backend (planning and refine are
        row-independent, so batching and tail padding don't change them).
        """
        queries = np.asarray(queries, dtype=np.float32)
        kq = k or self.k
        if kq > self.k:
            raise ValueError(f"k={kq} exceeds the engine's static answer "
                             f"size k={self.k}; build the engine with a "
                             f"larger k")
        qn = queries.shape[0]
        if qn == 0:
            return (np.zeros((0, kq), np.float32),
                    np.full((0, kq), -1, np.int32), [])
        dists, gids, metrics = [], [], []
        for lo in range(0, qn, self.batch_size):
            chunk = queries[lo:lo + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            nlive = chunk.shape[0]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, chunk.shape[1]), np.float32)])
            with TRACER.span("serve.tick", loop=self.obs_label,
                             live=nlive):
                dist, gid, touched, scanned, dt = \
                    self._execute(chunk, nlive)
            for _ in range(nlive):           # direct API: no queue wait
                self.latency_hist.observe(dt * 1e3)
            dists.append(dist[:nlive, :kq])
            gids.append(gid[:nlive, :kq])
            batch_metrics = [
                QueryMetrics(partitions_touched=int(touched[i]),
                             candidates_scanned=int(scanned[i]),
                             latency_s=dt,
                             batch_fill=nlive / self.batch_size)
                for i in range(nlive)]
            metrics.extend(batch_metrics)
            self.stats.observe(batch_metrics)
        return np.concatenate(dists), np.concatenate(gids), metrics


class ClimberEngine(BatchedServingLoop):
    """Batched, sharded, kernel-first kNN serving loop.

    Args:
      index: a built ClimberIndex.  With ``mesh`` given, the store is laid
        out over the mesh's data axis at construction (ragged partition
        counts are padded), so every tick runs the shard_map refine.
      batch_size: rows per tick — the one static batch shape that jits.
      variant: registered planner name ("knn" | "adaptive" | "od_smallest" |
        "exhaustive" or anything added via ``register_planner``).
      k: default answer size (0 => ``cfg.k``).
      use_kernel: refine implementation — True the streaming fused Pallas
        kernel (masked distance + top-k in one pass), False the dense jnp
        oracle, None (default) the backend default: fused on accelerator
        backends, dense on CPU.
      max_slots: static slot budget for plan compaction (None => the
        lossless ``default_slot_budget`` unless ``cfg.query_max_slots``
        overrides it; stays None — i.e. no compaction — for
        user-registered variants with no knowable lossless bound).
      plan_cache_size: LRU capacity of the signature→plan cache (0 turns
        memoization off; the planning stage then runs every tick).

    All of the above may instead arrive bundled in one
    :class:`repro.serve.api.ServingConfig` via ``config=`` (exclusive
    with the individual kwargs) — the same object the fleet engine and
    the network server consume.  ``mesh`` / ``data_axis`` stay separate:
    they are runtime resources, not serializable configuration.

    The configuration (variant, k, backend, budget, store layout) is baked
    into the compiled pipeline at construction; mutating these attributes
    afterwards has no effect on the cached trace — build a new engine
    instead.
    """

    _CONFIG_KEYS = ("batch_size", "variant", "k", "use_kernel",
                    "max_slots", "plan_cache_size", "trace_ring")

    def __init__(self, index: ClimberIndex, *,
                 config: Optional[api.ServingConfig] = None,
                 mesh=None, data_axis: str = "data", **kwargs):
        cfg = api.resolve_config(config, kwargs, self._CONFIG_KEYS)
        self.config = cfg
        get_planner(cfg.variant)             # fail fast on unknown variants
        if cfg.trace_ring:                   # size the span ring for the
            TRACER.set_capacity(cfg.trace_ring)   # expected serving load
        super().__init__(series_len=index.cfg.series_len,
                         batch_size=cfg.batch_size, k=cfg.k or index.cfg.k)
        self.index = index
        self.variant = cfg.variant
        self.use_kernel = resolve_use_kernel(cfg.use_kernel)
        self.mesh = mesh
        self.data_axis = data_axis
        max_slots = cfg.max_slots
        if max_slots is None:
            max_slots = index.cfg.query_max_slots
        if max_slots is None:
            max_slots = default_slot_budget(index, cfg.variant)
        self.max_slots = max_slots

        self.store = index.store
        if mesh is not None and mesh.shape[data_axis] > 1:
            from repro.distributed.store import shard_store
            self.store = shard_store(index.store, mesh, data_axis=data_axis)

        self.plan_cache_size = cfg.plan_cache_size
        # signature bytes → (sel_part, sel_lo, sel_hi, touched, scanned) rows
        self._plan_cache = PlanCache(cfg.plan_cache_size)

        self._featurize = jax.jit(lambda q: self.index.featurize(q)[0])
        self._plan = jax.jit(self._plan_fn)
        self._refine = jax.jit(self._refine_fn)

    # -- the staged pipeline (featurize → plan → dispatch refine) ---------
    def _plan_fn(self, p4r: jnp.ndarray):
        qp = plan_queries(self.index, p4r, variant=self.variant,
                          max_slots=self.max_slots)
        return (qp.sel_part, qp.sel_lo, qp.sel_hi, qp.partitions_touched(),
                candidates_scanned(qp, self.store))

    def _refine_fn(self, queries, sel_part, sel_lo, sel_hi):
        return dispatch_refine(
            self.store, queries, sel_part, sel_lo, sel_hi, self.k,
            mesh=self.mesh, data_axis=self.data_axis,
            use_kernel=self.use_kernel)

    def _plan_batch(self, p4r: jnp.ndarray, nlive: int):
        """Plan a tick's batch through the signature LRU.

        All live rows cached → assemble the plan on the host and skip the
        planning jit; otherwise plan the whole fixed-shape batch (static
        shapes) and refresh every live row's entry.
        """
        if not self.plan_cache_size:
            return self._plan(p4r)
        cache = self._plan_cache
        p4_host = np.asarray(p4r)            # one transfer for all rows
        keys = [p4_host[i].tobytes() for i in range(nlive)]
        h0, m0 = cache.hits, cache.misses
        rows = [cache.get(kk) for kk in keys]
        self.stats.plan_cache_hits += cache.hits - h0
        self.stats.plan_cache_misses += cache.misses - m0
        if nlive and all(r is not None for r in rows):
            bs = self.batch_size
            mp = rows[0][0].shape[-1]
            sel_part = np.full((bs, mp), -1, np.int32)
            sel_lo = np.zeros((bs, mp), np.int32)
            sel_hi = np.zeros((bs, mp), np.int32)
            touched = np.zeros(bs, np.int32)
            scanned = np.zeros(bs, np.int32)
            for i, r in enumerate(rows):
                sel_part[i], sel_lo[i], sel_hi[i], touched[i], scanned[i] = r
            return (jnp.asarray(sel_part), jnp.asarray(sel_lo),
                    jnp.asarray(sel_hi), touched, scanned)
        out = self._plan(p4r)
        sp, lo, hi, touched, scanned = (np.asarray(x) for x in out)
        for i, kk in enumerate(keys):
            cache.put(kk, (sp[i], lo[i], hi[i], touched[i], scanned[i]))
        return out

    def _execute(self, qbatch: np.ndarray, nlive: int):
        """One fixed-shape tick.  Returns host arrays + wall seconds."""
        t0 = time.perf_counter()
        qb = jnp.asarray(qbatch)
        with TRACER.span("query.featurize"):
            p4r = self._featurize(qb)
        with TRACER.span("query.plan", variant=self.variant):
            sel_part, sel_lo, sel_hi, touched, scanned = \
                self._plan_batch(p4r, nlive)
        with TRACER.span("query.refine"):
            dist, gid = self._refine(qb, jnp.asarray(sel_part),
                                     jnp.asarray(sel_lo),
                                     jnp.asarray(sel_hi))
            jax.block_until_ready(gid)
        dt = time.perf_counter() - t0
        return (np.asarray(dist), np.asarray(gid), np.asarray(touched),
                np.asarray(scanned), dt)
