"""Fleet lifecycle plane — durability, background compaction, shard aging.

The in-memory :class:`repro.fleet.IndexFleet` is a process-lifetime object;
this package is what makes it survive and stay healthy over time:

  * :mod:`~repro.fleet.lifecycle.wal` — a binary write-ahead log that
    ``IndexFleet.insert`` appends to *before* the delta scatter, so a
    restart replays every acknowledged insert batch-for-batch;
  * :mod:`~repro.fleet.lifecycle.snapshot` — sealed-shard snapshots
    (store arrays + trie skeleton + pivots + global ids as npz + JSON
    manifest, atomic tmp-dir rename) and the fleet-level
    ``save``/``open`` manifest;
  * :mod:`~repro.fleet.lifecycle.compactor` — background compaction: the
    INX rebuild runs on a worker thread over a frozen delta copy while
    queries keep hitting the old delta, then the sealed shard swaps in
    atomically and the frozen WAL segments are truncated;
  * :mod:`~repro.fleet.lifecycle.merge` — the LSM analogy: a policy that
    merges small adjacent sealed shards and retires shards past a time
    horizon, driven by ``IndexFleet.maintenance()`` /
    ``FleetEngine.maintenance()`` ticks.

The crash contract is gid-based, not ordering-based: a WAL frame whose
global ids are already covered by a sealed shard is skipped at replay, so
every kill point between WAL append → delta scatter → compact swap → WAL
truncate replays to a fleet whose answers are bit-identical to the
uninterrupted run (``tests/test_fleet_lifecycle.py``).
"""
from repro.fleet.lifecycle.compactor import CompactionTicket
from repro.fleet.lifecycle.merge import MergePolicy
from repro.fleet.lifecycle.snapshot import load_shard, save_shard
from repro.fleet.lifecycle.wal import WriteAheadLog

__all__ = ["WriteAheadLog", "CompactionTicket", "MergePolicy",
           "save_shard", "load_shard"]
