"""Binary write-ahead log for the fleet's streaming delta.

``IndexFleet.insert`` appends each batch here *before* the delta scatter,
so the log is always a superset of what the in-memory delta holds and a
restart can replay the exact insert sequence (same batches, same order —
which reproduces the delta's rebuild history bit-for-bit, since delta
rebuilds are keyed on occupancy at rebuild time).

Layout: one directory of numbered **segment** files.  The active segment
(highest id) receives appends; when the delta is frozen for compaction the
log ``roll()``s — the frozen segments then correspond exactly to the frozen
delta contents and are ``drop()``ped once the sealed shard is durable.  The
segment ↔ delta correspondence is what makes WAL truncation a pure space
reclaim: correctness never depends on it, because replay skips frames whose
global ids a sealed shard already covers.

Frame format (little-endian), append-only within a segment::

    segment  := SEG_MAGIC (8 bytes) frame*
    frame    := FRAME_MAGIC u32 | rows u32 | series_len u32 | crc32 u32
                | gids  int32[rows]
                | data  float32[rows * series_len]

``crc32`` covers the gid and data payload.  A crash mid-append leaves a
torn tail frame; replay detects it (short read / bad magic / bad crc) and
stops at the last complete frame — exactly the set of inserts that were
acknowledged durably.  Torn tails are only legal in the *last* segment;
anywhere else the log is corrupt and replay raises.
"""
from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

SEG_MAGIC = b"CLWAL001"
FRAME_MAGIC = 0x464C4157          # "WALF"
_HEADER = struct.Struct("<IIII")  # magic, rows, series_len, crc32


class WalCorruptError(RuntimeError):
    """A non-tail segment holds a torn or corrupt frame."""


def fsync_dir(path) -> None:
    """fsync a directory so entry creates/renames survive power loss.

    Per-file fsync alone does not persist the *dirent*; without this a
    freshly rolled segment (or a just-published snapshot dir) can vanish
    on power failure even though its bytes were synced.  Best-effort:
    some filesystems refuse O_RDONLY fsync on directories.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def encode_frame(gids: np.ndarray, batch: np.ndarray) -> bytes:
    """One insert batch as a self-checking binary frame."""
    gids = np.ascontiguousarray(gids, dtype=np.int32)
    batch = np.ascontiguousarray(batch, dtype=np.float32)
    payload = gids.tobytes() + batch.tobytes()
    header = _HEADER.pack(FRAME_MAGIC, batch.shape[0], batch.shape[1],
                          zlib.crc32(payload) & 0xFFFFFFFF)
    return header + payload


def _decode_frames(raw: bytes) -> Tuple[List[Tuple[np.ndarray, np.ndarray]],
                                        bool]:
    """(frames, clean): parse until EOF or the first torn/corrupt frame."""
    frames: List[Tuple[np.ndarray, np.ndarray]] = []
    off = 0
    while off < len(raw):
        if off + _HEADER.size > len(raw):
            return frames, False                       # torn header
        magic, rows, n, crc = _HEADER.unpack_from(raw, off)
        size = rows * 4 + rows * n * 4
        if magic != FRAME_MAGIC or off + _HEADER.size + size > len(raw):
            return frames, False                       # torn / garbage
        payload = raw[off + _HEADER.size: off + _HEADER.size + size]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            return frames, False                       # torn write
        gids = np.frombuffer(payload[: rows * 4], dtype=np.int32).copy()
        batch = np.frombuffer(payload[rows * 4:], dtype=np.float32
                              ).reshape(rows, n).copy()
        frames.append((gids, batch))
        off += _HEADER.size + size
    return frames, True


class WriteAheadLog:
    """Segmented append-only log under one directory.

    Args:
      root: directory holding the segment files (created if missing;
        existing segments are adopted and appends continue on the highest).
      fsync: fsync after every append (the durability point the crash
        tests rely on; disable only for benchmarks).
    """

    def __init__(self, root, *, fsync: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.appended_bytes = 0           # cumulative, this process
        existing = self.segments()
        self._active_id = existing[-1] if existing else 1
        self._fh = open(self._seg_path(self._active_id), "ab")
        if self._fh.tell() == 0:
            self._fh.write(SEG_MAGIC)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
                fsync_dir(self.root)        # the new dirent itself

    # -- segment bookkeeping ---------------------------------------------
    def _seg_path(self, seg_id: int) -> Path:
        return self.root / f"seg_{seg_id:08d}.wal"

    def segments(self) -> List[int]:
        """Segment ids on disk, ascending (== append order)."""
        return sorted(int(p.stem.split("_")[1])
                      for p in self.root.glob("seg_*.wal"))

    @property
    def active_segment(self) -> int:
        return self._active_id

    def bytes_on_disk(self) -> int:
        return sum(self._seg_path(s).stat().st_size
                   for s in self.segments()
                   if self._seg_path(s).exists())

    # -- the write path ---------------------------------------------------
    def append(self, gids: np.ndarray, batch: np.ndarray) -> int:
        """Durably append one insert batch; returns bytes written."""
        frame = encode_frame(gids, batch)
        self._fh.write(frame)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.appended_bytes += len(frame)
        return len(frame)

    def roll(self) -> int:
        """Freeze the active segment and open the next one.

        Returns the frozen segment id.  Called when the delta is frozen
        for compaction: frames up to here belong to the frozen delta and
        are dropped together once the sealed shard is durable.
        """
        frozen = self._active_id
        self._fh.close()
        self._active_id += 1
        self._fh = open(self._seg_path(self._active_id), "ab")
        if self._fh.tell() == 0:
            self._fh.write(SEG_MAGIC)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
                fsync_dir(self.root)        # the new dirent itself
        return frozen

    def drop(self, seg_ids) -> None:
        """Delete frozen segments (space reclaim after a durable seal)."""
        for seg_id in seg_ids:
            if seg_id == self._active_id:
                raise ValueError(f"cannot drop the active segment {seg_id}")
            self._seg_path(seg_id).unlink(missing_ok=True)

    # -- the read path ----------------------------------------------------
    def replay(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """Every durable frame, in append order: ``(seg_id, gids, batch)``.

        A torn tail in the last segment is silently dropped (the append
        never completed, so the insert was never acknowledged); a torn
        frame anywhere else raises :class:`WalCorruptError`.
        """
        segs = self.segments()
        out: List[Tuple[int, np.ndarray, np.ndarray]] = []
        for i, seg_id in enumerate(segs):
            raw = self._seg_path(seg_id).read_bytes()
            if raw[: len(SEG_MAGIC)] != SEG_MAGIC:
                raise WalCorruptError(f"segment {seg_id}: bad magic")
            frames, clean = _decode_frames(raw[len(SEG_MAGIC):])
            if not clean and i != len(segs) - 1:
                raise WalCorruptError(
                    f"segment {seg_id}: torn frame before the tail segment")
            out.extend((seg_id, g, b) for g, b in frames)
        return out

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __del__(self):  # best-effort: tests create many short-lived logs
        try:
            self.close()
        except Exception:       # noqa: BLE001 — interpreter teardown
            pass
