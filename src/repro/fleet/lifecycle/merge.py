"""LSM-style shard maintenance: merge small neighbours, retire the aged.

Streaming workloads seal many small delta-sized shards; left alone, query
fan-out cost grows linearly with their count forever.  The classic LSM
answer applies directly (the fleet's sealed shards are its sorted runs):

  * **merge** — two *adjacent* sealed shards that are both small are
    rebuilt as one shard over their concatenated records.  Global ids are
    preserved and the raw records are recovered exactly from the partition
    stores (the store scatter is invertible through ``rec_gid``), so exact
    answers over the surviving records are unchanged — only the fan-out
    count and per-shard index quality improve.  Adjacency keeps the merge
    order-preserving: time-range neighbours stay neighbours, and the
    fleet's deterministic shard-order merge fold is undisturbed.
  * **retirement** — shards whose newest content is older than
    ``retire_after`` seconds are dropped entirely (their records leave the
    fleet; the id space is never reused).

Both run under :meth:`repro.fleet.IndexFleet.maintenance`, typically
driven by ``FleetEngine.maintenance()`` ticks between serving batches.
The expensive step (the merged INX rebuild) runs off the fleet lock; the
splice itself is atomic and revalidates that the shard list did not change
underneath it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class MergePolicy:
    """Knobs of one maintenance tick."""

    small_shard_records: int = 1024   # merge-eligible at or below this size
    max_merged_records: int = 8192    # never build a merged shard beyond this
    merges_per_tick: int = 1          # bound the work one tick may do
    retire_after: Optional[float] = None  # seconds since created_at;
                                          # None = shards never age out


def shard_records(handle) -> Tuple[np.ndarray, np.ndarray]:
    """Recover a sealed shard's raw records in original row order.

    Inverts the ``build_store`` scatter: every live slot carries its local
    row id in ``rec_gid``, so ``(data [n, series_len], global_ids [n])``
    comes back bit-exact — which is what makes a merged rebuild answer-
    preserving.
    """
    store = handle.index.store
    gid = np.asarray(store.rec_gid)
    data = np.asarray(store.data)
    live = gid >= 0
    out = np.empty((handle.num_records, data.shape[-1]), np.float32)
    out[gid[live]] = data[live]
    return out, np.asarray(handle.global_ids)


def _retire(fleet, policy: MergePolicy, now: float) -> List[str]:
    """Drop shards past the horizon (fleet lock held)."""
    from repro.fleet.lifecycle.snapshot import write_manifest
    if policy.retire_after is None:
        return []
    retired = []
    keep = []
    for si, shard in enumerate(fleet.shards):
        if shard.created_at and now - shard.created_at > policy.retire_after:
            retired.append(shard.key)
        else:
            keep.append(si)
    if not retired:
        return []
    # splice the router registry in reverse so indices stay valid
    for si in reversed([i for i in range(len(fleet.shards))
                        if i not in keep]):
        if fleet.router is not None:
            fleet.router.replace_span(si, 1)
    fleet.shards = [fleet.shards[i] for i in keep]
    fleet._invalidate_placement()
    fleet.stats.retired_shards += len(retired)
    if fleet.storage_dir is not None:
        import shutil
        # manifest first: a crash must never leave it referencing deleted
        # snapshot dirs (the storage dir would be unopenable)
        old_slugs = [fleet._shard_dirs.pop(key, None) for key in retired]
        write_manifest(fleet, fleet.storage_dir)
        for slug in old_slugs:
            if slug:
                shutil.rmtree(fleet.storage_dir / "shards" / slug,
                              ignore_errors=True)
    return retired


def _pick_merge_pair(fleet, policy: MergePolicy) -> Optional[int]:
    """Index i of the first adjacent sealed pair (i, i+1) worth merging."""
    for i in range(len(fleet.shards) - 1):
        a, b = fleet.shards[i], fleet.shards[i + 1]
        if (a.num_records <= policy.small_shard_records
                and b.num_records <= policy.small_shard_records
                and a.num_records + b.num_records
                <= policy.max_merged_records):
            return i
    return None


def _merge_pair(fleet, i: int) -> Optional[str]:
    """Merge shards[i] and shards[i+1]; returns the new key (or None when
    the shard list changed under the rebuild and the merge was skipped)."""
    from repro.fleet.fleet import ShardHandle
    from repro.fleet.lifecycle.snapshot import write_manifest
    with fleet._lock:
        a, b = fleet.shards[i], fleet.shards[i + 1]
        fleet._merge_count += 1
        key = f"merged:{fleet._merge_count}"
        while any(s.key == key for s in fleet.shards):
            fleet._merge_count += 1
            key = f"merged:{fleet._merge_count}"
        # fold offset 1000+ keeps merge build keys disjoint from the
        # add_shard/seal fold family (len(shards) + 17)
        fold = 1000 + fleet._merge_count
    data_a, gids_a = shard_records(a)
    data_b, gids_b = shard_records(b)
    data = np.concatenate([data_a, data_b], axis=0)
    gids = np.concatenate([gids_a, gids_b])
    index = fleet._build_shard_index(data, fold)    # expensive: off-lock
    handle = ShardHandle(key=key, index=index, global_ids=gids,
                         created_at=max(a.created_at, b.created_at))
    with fleet._lock:
        if (i + 1 >= len(fleet.shards) or fleet.shards[i] is not a
                or fleet.shards[i + 1] is not b):
            return None                 # concurrent mutation: retry next tick
        fleet.shards[i: i + 2] = [handle]
        if fleet.router is not None:
            fleet.router.replace_span(i, 2, key,
                                      fleet.router.summarize(data))
        fleet._invalidate_placement()
        fleet.stats.merges += 1
        if fleet.storage_dir is not None:
            import shutil
            from repro.fleet.lifecycle.snapshot import save_shard, shard_slug
            # crash ordering: new snapshot → manifest (no longer naming the
            # sources) → only then delete the source dirs, so the manifest
            # always references directories that exist
            slug = shard_slug(key, set(fleet._shard_dirs.values()))
            save_shard(fleet.storage_dir / "shards" / slug, handle)
            fleet._shard_dirs[key] = slug
            old_slugs = [fleet._shard_dirs.pop(old.key, None)
                         for old in (a, b)]
            write_manifest(fleet, fleet.storage_dir)
            for old_slug in old_slugs:
                if old_slug:
                    shutil.rmtree(fleet.storage_dir / "shards" / old_slug,
                                  ignore_errors=True)
    return key


def run_maintenance(fleet, policy: Optional[MergePolicy] = None,
                    now: Optional[float] = None) -> dict:
    """One tick: retire first (never merge doomed shards), then merge.

    Implements :meth:`repro.fleet.IndexFleet.maintenance`; ``now`` is
    injectable for tests.  Returns ``{"retired": [...], "merged": [...]}``
    with the shard keys acted on.
    """
    policy = policy or fleet.merge_policy or MergePolicy()
    now = time.time() if now is None else now
    with fleet._lock:
        retired = _retire(fleet, policy, now)
    merged = []
    for _ in range(policy.merges_per_tick):
        with fleet._lock:
            i = _pick_merge_pair(fleet, policy)
        if i is None:
            break
        key = _merge_pair(fleet, i)
        if key is not None:
            merged.append(key)
    return {"retired": retired, "merged": merged}
