"""Sealed-shard snapshots + the fleet-level save/open manifest.

A sealed shard is fully described by its :class:`~repro.core.index.
PartitionStore` arrays, its trie skeleton (:class:`~repro.core.trie.
TrieForest` — plain numpy tables plus three scalars), its pivots/centroids,
and its ``global_ids`` map.  :func:`save_shard` serializes exactly that to
one ``arrays.npz`` plus a JSON ``MANIFEST.json``; :func:`load_shard`
rebuilds the :class:`~repro.core.index.ClimberIndex` (the device trie is
re-derived from the forest, which is deterministic), so a restored shard's
answers are bit-identical to the live shard's.

Atomicity reuses the ``train/checkpoint.py`` pattern: everything is written
into a ``<dir>.tmp`` sibling, fsynced, and published with one
``os.rename`` — a crash mid-write never leaves a half snapshot that
``open`` would pick up.

The fleet-level layout under one storage directory::

    <dir>/
      FLEET_MANIFEST.json     # configs, gid watermark, shard list, router
      ROUTER.npz              # reference pivots + per-shard summaries
      shards/<slug>/          # one atomic snapshot dir per sealed shard
          MANIFEST.json
          arrays.npz
      wal/seg_*.wal           # the delta's write-ahead log (lifecycle.wal)

``save_fleet``/``open_fleet`` implement ``IndexFleet.save``/``.open``:
save persists every sealed shard not yet on disk plus the manifest and
router state (the WAL is already durable — it is written at insert time);
open loads the manifest's shards, restores the router verbatim (routing
decisions survive restart bit-for-bit), and replays the WAL tail into a
fresh delta, skipping frames whose global ids a sealed shard already
covers (the crash window between compact swap and WAL truncate).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
from pathlib import Path
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.index import ClimberIndex, PartitionStore
from repro.core.traversal import TrieDevice
from repro.core.trie import TrieForest
from repro.distributed.store import store_from_arrays, store_to_arrays
from repro.utils.config import ClimberConfig

SNAPSHOT_VERSION = 1

_FOREST_ARRAYS = ("child_start", "edge_pivot", "edge_child", "edge_key",
                  "node_size", "node_depth", "dfs_in", "dfs_out",
                  "part_start", "part_ids", "group_root",
                  "group_default_part")
_FOREST_SCALARS = ("num_partitions", "num_pivots", "max_parts_per_node")


def _atomic_dir(final: Path):
    """Context-ish helper: returns a tmp dir; call :func:`_publish` after."""
    tmp = final.parent / (final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    return tmp


def _publish(tmp: Path, final: Path) -> None:
    from repro.fleet.lifecycle.wal import fsync_dir
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                          # atomic publish
    fsync_dir(final.parent)                        # persist the rename


def _write_json(path: Path, doc: dict) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.flush()
        os.fsync(f.fileno())


def _atomic_json(path: Path, doc: dict) -> None:
    from repro.fleet.lifecycle.wal import fsync_dir
    tmp = path.parent / (path.name + ".tmp")
    _write_json(tmp, doc)
    os.replace(tmp, path)
    fsync_dir(path.parent)


def shard_slug(key: str, taken) -> str:
    """Filesystem-safe, collision-free directory name for a shard key."""
    base = re.sub(r"[^A-Za-z0-9_.-]", "_", key) or "shard"
    slug, i = base, 1
    while slug in taken:
        slug, i = f"{base}_{i}", i + 1
    return slug


# -- one sealed shard -----------------------------------------------------
def save_shard(dir_: Path, handle) -> Path:
    """Atomically snapshot one sealed :class:`~repro.fleet.ShardHandle`."""
    dir_ = Path(dir_)
    idx: ClimberIndex = handle.index
    tmp = _atomic_dir(dir_)
    arrays: Dict[str, np.ndarray] = store_to_arrays(idx.store)
    arrays["pivots"] = np.asarray(idx.pivots)
    arrays["centroid_onehot"] = np.asarray(idx.centroid_onehot)
    arrays["global_ids"] = np.asarray(handle.global_ids)
    for name in _FOREST_ARRAYS:
        arrays["forest_" + name] = np.asarray(getattr(idx.forest, name))
    np.savez(tmp / "arrays.npz", **arrays)
    _write_json(tmp / "MANIFEST.json", {
        "version": SNAPSHOT_VERSION,
        "key": handle.key,
        "created_at": handle.created_at,
        "num_records": int(handle.num_records),
        "cfg": dataclasses.asdict(idx.cfg),
        "forest": {name: int(getattr(idx.forest, name))
                   for name in _FOREST_SCALARS},
    })
    _publish(tmp, dir_)
    return dir_


def load_shard(dir_: Path):
    """Rebuild a :class:`~repro.fleet.ShardHandle` from :func:`save_shard`.

    The store/pivot/forest arrays load bit-exact; the device trie is
    re-derived from the forest (``TrieDevice.from_forest`` is a pure
    function of it), so query answers match the pre-snapshot shard
    bit-for-bit.
    """
    from repro.fleet.fleet import ShardHandle
    dir_ = Path(dir_)
    manifest = json.loads((dir_ / "MANIFEST.json").read_text())
    if manifest["version"] != SNAPSHOT_VERSION:
        raise ValueError(f"{dir_}: snapshot version {manifest['version']} "
                         f"!= {SNAPSHOT_VERSION}")
    arrays = np.load(dir_ / "arrays.npz")
    forest = TrieForest(
        **{name: arrays["forest_" + name] for name in _FOREST_ARRAYS},
        **{name: int(manifest["forest"][name]) for name in _FOREST_SCALARS})
    store: PartitionStore = store_from_arrays(arrays)
    cfg = ClimberConfig(**manifest["cfg"])
    index = ClimberIndex(cfg=cfg, pivots=jnp.asarray(arrays["pivots"]),
                         centroid_onehot=jnp.asarray(
                             arrays["centroid_onehot"]),
                         forest=forest,
                         trie=TrieDevice.from_forest(forest),
                         store=store)
    return ShardHandle(key=manifest["key"], index=index,
                       global_ids=arrays["global_ids"],
                       created_at=float(manifest.get("created_at", 0.0)))


# -- whole fleet ----------------------------------------------------------
def write_manifest(fleet, dir_: Path) -> None:
    """Atomically (re)write FLEET_MANIFEST.json + ROUTER.npz for ``fleet``.

    Caller must hold the fleet lock; every shard listed must already have
    a published snapshot dir (``fleet._shard_dirs``).
    """
    dir_ = Path(dir_)
    fc = dataclasses.asdict(fleet.cfg)
    shard_cfg = fc.pop("shard_cfg")
    router_doc: Optional[dict] = None
    if fleet.router is not None:
        tmp = dir_ / "ROUTER_tmp.npz"   # .npz name so savez won't rename it
        np.savez(tmp,
                 pivots=np.asarray(fleet.router.pivots),
                 summaries=(np.stack(fleet.router._summaries)
                            if fleet.router._summaries
                            else np.zeros((0, fleet.router.pivots.shape[0]),
                                          np.float32)))
        os.replace(tmp, dir_ / "ROUTER.npz")
        router_doc = {"file": "ROUTER.npz", "keys": list(fleet.router.keys)}
    _atomic_json(dir_ / "FLEET_MANIFEST.json", {
        "version": SNAPSHOT_VERSION,
        "fleet": fc,
        "shard_cfg": shard_cfg,
        "next_gid": int(fleet._next_gid),
        "seal_count": int(fleet._seal_count),
        "merge_count": int(fleet._merge_count),
        "shards": [{"key": s.key, "dir": fleet._shard_dirs[s.key],
                    "num_records": int(s.num_records),
                    "created_at": s.created_at}
                   for s in fleet.shards],
        "router": router_doc,
    })


def save_fleet(fleet, dir_: Path) -> Path:
    """Persist every sealed shard + the manifest (``IndexFleet.save``).

    Shards already snapshotted under this directory are skipped (their
    key is in ``fleet._shard_dirs``); the manifest always rewrites, so
    merges/retirements since the last save take effect.
    """
    dir_ = Path(dir_)
    (dir_ / "shards").mkdir(parents=True, exist_ok=True)
    taken = set(fleet._shard_dirs.values())
    for handle in fleet.shards:
        if handle.key in fleet._shard_dirs:
            continue
        slug = shard_slug(handle.key, taken)
        taken.add(slug)
        save_shard(dir_ / "shards" / slug, handle)
        fleet._shard_dirs[handle.key] = slug
    write_manifest(fleet, dir_)
    return dir_


def read_manifest(dir_: Path) -> dict:
    path = Path(dir_) / "FLEET_MANIFEST.json"
    if not path.exists():
        raise FileNotFoundError(f"no fleet manifest under {dir_}")
    manifest = json.loads(path.read_text())
    if manifest["version"] != SNAPSHOT_VERSION:
        raise ValueError(f"{dir_}: manifest version {manifest['version']} "
                         f"!= {SNAPSHOT_VERSION}")
    return manifest


def load_router(dir_: Path, manifest: dict, cfg: ClimberConfig):
    """Restore the SignatureRouter verbatim (pivots + summaries + keys)."""
    from repro.fleet.router import SignatureRouter
    doc = manifest.get("router")
    if not doc:
        return None
    arrays = np.load(Path(dir_) / doc["file"])
    router = SignatureRouter(jnp.asarray(arrays["pivots"]), cfg)
    for key, summary in zip(doc["keys"], arrays["summaries"]):
        router.register(key, summary)
    return router
