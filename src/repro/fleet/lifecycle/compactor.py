"""Background compaction — the INX rebuild off the query path.

The sealing protocol (ParIS/MESSI's lesson: index construction does not
belong on the query thread):

  1. **freeze** (fleet lock): the live delta becomes the *frozen* delta —
     still queried, now immutable — a fresh delta takes over ingest, and
     the WAL rolls so the frozen segments correspond exactly to the frozen
     contents;
  2. **build** (worker thread, no lock): the full CLIMBER-INX rebuild over
     the frozen contents — identical arithmetic and key derivation to the
     synchronous path, so the sealed shard is bit-identical to what a
     blocking ``compact()`` would have produced;
  3. **swap** (fleet lock): snapshot the shard (when storage is attached),
     splice it into the shard list + router, rewrite the manifest, drop
     the frozen delta — atomic from a query's point of view: a query sees
     either ``shards + frozen delta`` or ``shards∪{sealed}``, never both
     and never neither;
  4. **truncate**: the frozen WAL segments are dropped last — crash before
     this point replays them, and replay skips frames whose gids the
     sealed shard's snapshot already covers.

A failed build aborts cleanly: the frozen contents fold back into the live
delta (no acknowledged insert is ever lost) and the error surfaces on the
ticket.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.obs import TRACER


class CompactionTicket:
    """Handle on one in-flight background seal."""

    def __init__(self, fleet):
        self._fleet = fleet
        self._event = threading.Event()
        self.handle = None              # ShardHandle once sealed
        self.error: Optional[BaseException] = None
        self.seconds: float = 0.0       # freeze-to-swap wall time

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None):
        """Block until the seal finishes; returns the new ShardHandle.

        Re-raises the build's exception if it failed; raises TimeoutError
        if ``timeout`` elapses first.
        """
        if not self._event.wait(timeout):
            raise TimeoutError("compaction still running")
        if self.error is not None:
            raise self.error
        return self.handle


def start_background_compaction(fleet) -> Optional[CompactionTicket]:
    """Freeze the delta and seal it on a worker thread.

    Returns the ticket, the already-running ticket if a seal is in
    flight, or None when the delta is empty.  Raises ValueError (before
    any state changes) when the delta is too small to build an index.
    """
    with fleet._lock:
        if fleet._seal_ticket is not None and not fleet._seal_ticket.done():
            return fleet._seal_ticket
        with TRACER.span("compact.freeze"):
            frozen = fleet._freeze()    # may raise ValueError (< num_pivots)
        if frozen is None:
            return None
        ticket = CompactionTicket(fleet)
        fleet._seal_ticket = ticket

    # trace handoff: when a serving tick's maintenance hook triggered this
    # seal, the worker thread's compact.* spans join the triggering
    # request's trace (adopt is a no-op when no span is open — an
    # explicitly-called compaction still roots its own tree as before)
    trigger_ctx = TRACER.current_context()

    def _worker():
        t0 = time.perf_counter()
        with TRACER.adopt(trigger_ctx), \
                TRACER.span("compact.seal", key=frozen.key,
                            records=len(frozen.data)):
            try:
                with TRACER.span("compact.build"):
                    index = fleet._build_shard_index(frozen.data,
                                                     frozen.fold)
                from repro.fleet.fleet import ShardHandle
                handle = ShardHandle(key=frozen.key, index=index,
                                     global_ids=frozen.global_ids,
                                     created_at=time.time())
                with TRACER.span("compact.swap"):
                    fleet._finish_seal(frozen, handle)
                ticket.handle = handle
            except BaseException as exc:  # noqa: BLE001 — surface on ticket
                try:
                    fleet._abort_seal(frozen)
                finally:
                    ticket.error = exc
            finally:
                ticket.seconds = time.perf_counter() - t0
                with fleet._lock:
                    fleet.stats.compaction_ms += ticket.seconds * 1e3
                    if fleet._seal_ticket is ticket:
                        fleet._seal_ticket = None
                fleet.compaction_hist.observe(ticket.seconds * 1e3)
                ticket._event.set()

    thread = threading.Thread(target=_worker, name="fleet-compactor",
                              daemon=True)
    ticket.thread = thread
    thread.start()
    return ticket
