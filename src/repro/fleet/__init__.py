"""Index fleet — sharded multi-index serving with streaming ingest."""
from repro.fleet.fleet import (DeltaShard, FleetConfig, FleetQueryInfo,
                               FleetStats, IndexFleet, ShardHandle)
from repro.fleet.placement import MeshFleetPlacement
from repro.fleet.router import SignatureRouter
from repro.fleet.engine import FleetEngine
from repro.fleet.lifecycle import (CompactionTicket, MergePolicy,
                                   WriteAheadLog)

__all__ = ["IndexFleet", "FleetConfig", "FleetStats", "FleetQueryInfo",
           "ShardHandle", "DeltaShard", "SignatureRouter", "FleetEngine",
           "MeshFleetPlacement", "CompactionTicket", "MergePolicy",
           "WriteAheadLog"]
