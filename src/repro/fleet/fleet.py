"""IndexFleet — sharded multi-index serving with streaming ingest.

The single two-level CLIMBER index is built once and queried forever; a
serving system needs many of them (per-tenant, per-time-range) plus a place
for data that keeps arriving.  The fleet owns:

  * **sealed shards** — immutable :class:`repro.core.ClimberIndex` instances
    keyed by tenant / time-range, each with a ``global_ids`` map from its
    local record ids to fleet-global ids;
  * a **router** (:class:`repro.fleet.router.SignatureRouter`) that fans a
    query out to a shard subset scored on signature-prefix affinity, with
    exhaustive fan-out as the lossless fallback;
  * a **delta shard** — an append-only index with per-partition capacity
    slack that absorbs ``insert()`` batches through the existing assignment
    path (featurize → group → trie → partition scatter) and is always
    queried, so new records are visible immediately;
  * ``compact()`` — seals the delta into an immutable shard by re-running
    the full CLIMBER-INX build (pivot selection, centroids, partitioning)
    over its contents, preserving global ids, so queries always see one
    consistent fleet view.

Cross-shard fusion goes through :func:`repro.core.merge_topk` with
global-id remapping; per-shard answers carry the :data:`repro.core.PAD_DIST`
sentinel for missing slots, which propagates through every merge.  With
exhaustive routing and the ``"exhaustive"`` planner variant the fleet answer
is bit-identical to a single-index ``knn_query`` over the concatenated data
(both are exact ED top-k computed by the same refine arithmetic).

Placement — where the sealed shards execute:

  * ``placement="host"`` — the lossless oracle: a host loop dispatches each
    sealed shard's ``knn_query`` sequentially and fuses on the host;
  * ``placement="mesh"`` — the sealed stores live stacked on the device
    mesh (:class:`repro.fleet.placement.MeshFleetPlacement`) and one
    ``shard_map`` fans the whole batch out: per-device refine over each
    resident shard, one ``all_gather`` + in-order ``merge_topk`` fold.
    Bit-identical to the host loop (same plans, same refine arithmetic,
    same merge order); the delta is always queried host-side and merged
    last on both paths.

``mesh=`` at construction (or :meth:`IndexFleet.attach_mesh`) enables the
mesh path and makes it the default; without a mesh the default stays
``"host"``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import (ClimberIndex, PartitionStore,
                              _route_full_dataset_jit, build_index,
                              build_store)
from repro.core.query import (candidates_scanned, exhaustive_selection,
                              knn_query, plan)
from repro.core.refine import PAD_DIST, dispatch_refine, merge_topk, refine
from repro.distributed.store import concat_stores
from repro.fleet.router import SignatureRouter
from repro.utils.config import ClimberConfig


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs on top of the per-shard :class:`ClimberConfig`."""

    shard_cfg: ClimberConfig
    fanout: int = 2                 # shards the router selects per query
    delta_capacity: int = 4096      # records the delta holds before sealing
    delta_pad: Optional[int] = None  # physical slots per delta partition
                                     # (None => shard_cfg.capacity — full
                                     # capacity slack for in-place appends)
    auto_compact: bool = True       # seal automatically at delta_capacity
    seed: int = 0


@dataclass
class ShardHandle:
    """One immutable member of the fleet."""

    key: str                        # tenant / time-range label
    index: ClimberIndex
    global_ids: np.ndarray          # [n_shard] local row -> global record id
    sealed: bool = True

    @property
    def num_records(self) -> int:
        return int(self.global_ids.shape[0])


@dataclass
class FleetStats:
    """Aggregate serving/ingest counters for the whole fleet."""

    queries: int = 0
    inserts: int = 0
    compactions: int = 0
    delta_rebuilds: int = 0
    delta_occupancy: int = 0
    routed_pairs: int = 0           # (query, shard) executions actually run
    exhaustive_pairs: int = 0       # what exhaustive fan-out would have run
    routing_audits: int = 0
    routing_overlap: float = 0.0    # running sum of audited precision
    per_shard_queries: Dict[str, int] = field(default_factory=dict)
    per_shard_partitions: Dict[str, int] = field(default_factory=dict)

    def observe_shard(self, key: str, queries: int, partitions: int) -> None:
        self.per_shard_queries[key] = \
            self.per_shard_queries.get(key, 0) + queries
        self.per_shard_partitions[key] = \
            self.per_shard_partitions.get(key, 0) + partitions

    @property
    def routing_precision(self) -> float:
        """Mean audited recall of routed vs exhaustive fan-out (1.0 = no
        audit has seen the router drop a true neighbour)."""
        return self.routing_overlap / self.routing_audits \
            if self.routing_audits else 1.0

    @property
    def fanout_savings(self) -> float:
        """Fraction of per-shard executions the router skipped."""
        return 1.0 - self.routed_pairs / self.exhaustive_pairs \
            if self.exhaustive_pairs else 0.0

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["routing_precision"] = self.routing_precision
        d["fanout_savings"] = self.fanout_savings
        return d


@dataclass
class FleetQueryInfo:
    """Per-query execution metrics of one fleet query call."""

    partitions_touched: np.ndarray   # [Q] summed over every shard executed
    candidates_scanned: np.ndarray   # [Q]
    routed_mask: np.ndarray          # [Q, S] sealed shards each query hit


class DeltaShard:
    """Append-only ingest shard with capacity slack.

    Bootstrap: until ``num_pivots`` records exist a CLIMBER index cannot be
    built (pivot selection needs that many samples), so the delta serves
    queries from a single-partition store with an exact scan.  From the
    first rebuild on it is a real ClimberIndex whose partitions carry
    physical slot slack (``delta_pad``); inserts route through the existing
    assignment path and scatter into free slots in place.  A batch that
    overflows its target partition triggers a rebuild (re-running pivot
    selection and partitioning over the accumulated contents).
    """

    def __init__(self, cfg: ClimberConfig, *, pad: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg.replace(
            partition_pad=pad if pad is not None else cfg.capacity)
        self._seed = seed
        self.data = np.zeros((0, cfg.series_len), np.float32)
        self.global_ids = np.zeros((0,), np.int32)
        self.index: Optional[ClimberIndex] = None
        self.rebuilds = 0
        self.min_build = cfg.num_pivots

    @property
    def occupancy(self) -> int:
        return int(self.data.shape[0])

    # -- ingest -----------------------------------------------------------
    def insert(self, batch: np.ndarray, gids: np.ndarray) -> None:
        base = self.occupancy
        self.data = np.concatenate([self.data, batch], axis=0)
        self.global_ids = np.concatenate(
            [self.global_ids, gids.astype(np.int32)])
        if self.index is None:
            if self.occupancy >= self.min_build:
                self._rebuild()
            return
        if not self._scatter(batch, base):
            self._rebuild()

    def _rebuild(self) -> None:
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                 self.occupancy)
        self.index = build_index(key, jnp.asarray(self.data), self.cfg)
        self.rebuilds += 1

    def _scatter(self, batch: np.ndarray, base: int) -> bool:
        """Route a batch through the index's assignment path and append the
        records into free partition slots.  False = some partition is full
        (the caller rebuilds)."""
        idx = self.index
        part, rec_dfs = _route_full_dataset_jit(
            jnp.asarray(batch), idx.pivots, idx.centroid_onehot, idx.trie,
            idx.cfg)
        part = np.asarray(part)
        rec_dfs = np.asarray(rec_dfs)
        store = idx.store
        count = np.asarray(store.count).copy()

        order = np.argsort(part, kind="stable")
        ps = part[order]
        run_start = np.concatenate([[True], ps[1:] != ps[:-1]]) \
            if len(ps) else np.zeros(0, bool)
        first_pos = np.nonzero(run_start)[0]
        run_id = np.cumsum(run_start) - 1
        within = np.arange(len(ps)) - first_pos[run_id]
        slots = count[ps] + within
        if len(slots) and slots.max() >= store.capacity:
            return False

        rows = batch[order].astype(np.float32)
        data_np = np.asarray(store.data).copy()
        norms_np = np.asarray(store.norms).copy()
        dfs_np = np.asarray(store.rec_dfs).copy()
        gid_np = np.asarray(store.rec_gid).copy()
        data_np[ps, slots] = rows
        # same arithmetic as build_store so a later rebuild is bit-identical
        norms_np[ps, slots] = \
            np.sum(rows.astype(np.float64) ** 2, axis=-1).astype(np.float32)
        dfs_np[ps, slots] = rec_dfs[order]
        gid_np[ps, slots] = (base + order).astype(np.int32)
        np.add.at(count, ps, 1)
        new_store = PartitionStore(
            data=jnp.asarray(data_np), norms=jnp.asarray(norms_np),
            rec_dfs=jnp.asarray(dfs_np), rec_gid=jnp.asarray(gid_np),
            count=jnp.asarray(count))
        self.index = dataclasses.replace(idx, store=new_store)
        return True

    def take(self) -> Tuple[np.ndarray, np.ndarray]:
        """Hand the accumulated contents to compaction and reset."""
        out = (self.data, self.global_ids)
        self.data = np.zeros((0, self.cfg.series_len), np.float32)
        self.global_ids = np.zeros((0,), np.int32)
        self.index = None
        return out

    # -- query ------------------------------------------------------------
    def _bootstrap_store(self) -> PartitionStore:
        return build_store(jnp.asarray(self.data),
                           np.zeros(self.occupancy, np.int32),
                           np.zeros(self.occupancy, np.int32), 1)

    def store(self) -> Optional[PartitionStore]:
        if not self.occupancy:
            return None
        return self.index.store if self.index is not None \
            else self._bootstrap_store()

    def query(self, queries: np.ndarray, k: int, *, variant: str,
              use_kernel: Optional[bool] = None):
        """(dist, gid_local, touched, scanned) or None when empty."""
        if not self.occupancy:
            return None
        q = len(queries)
        if self.index is None:
            store = self._bootstrap_store()
            sel = jnp.zeros((q, 1), jnp.int32)
            dist, gid = refine(store, jnp.asarray(queries), sel, sel,
                               sel + 1, k, use_kernel=use_kernel)
            return (np.asarray(dist), np.asarray(gid),
                    np.ones(q, np.int64),
                    np.full(q, self.occupancy, np.int64))
        dist, gid, qp = knn_query(self.index, jnp.asarray(queries), k,
                                  variant=variant, use_kernel=use_kernel)
        return (np.asarray(dist), np.asarray(gid),
                np.asarray(qp.partitions_touched(), np.int64),
                np.asarray(candidates_scanned(qp, self.index.store),
                           np.int64))


class IndexFleet:
    """Several CLIMBER shards + streaming delta behind one query surface."""

    DELTA_KEY = "__delta__"

    def __init__(self, cfg: FleetConfig, *, mesh=None,
                 data_axis: str = "data"):
        self.cfg = cfg
        self.shards: List[ShardHandle] = []
        self.router: Optional[SignatureRouter] = None
        self.delta = DeltaShard(cfg.shard_cfg, pad=cfg.delta_pad,
                                seed=cfg.seed + 1)
        self.stats = FleetStats()
        self._next_gid = 0
        self._seal_count = 0
        self.mesh = mesh
        self.data_axis = data_axis
        self._placement = None          # lazily built MeshFleetPlacement

    # -- mesh placement ---------------------------------------------------
    def attach_mesh(self, mesh, *, data_axis: str = "data") -> None:
        """Enable mesh-resident execution (and make it the default).

        The sealed stores are stacked and laid out over ``mesh``'s
        ``data_axis`` lazily, on the next ``placement="mesh"`` query, and
        re-laid out whenever the sealed shard set changes.
        """
        self.mesh = mesh
        self.data_axis = data_axis
        self._placement = None

    def _resolve_placement(self, placement: Optional[str]) -> str:
        """``None`` → ``"mesh"`` when a mesh is attached, else ``"host"``."""
        if placement is None:
            return "mesh" if self.mesh is not None else "host"
        if placement not in ("host", "mesh"):
            raise ValueError(f"unknown placement {placement!r}; "
                             f"expected 'host' or 'mesh'")
        if placement == "mesh" and self.mesh is None:
            raise ValueError("placement='mesh' needs a mesh: pass mesh= at "
                             "construction or call attach_mesh()")
        return placement

    def _ensure_placement(self):
        from repro.fleet.placement import MeshFleetPlacement
        if self._placement is None:
            self._placement = MeshFleetPlacement(
                self.mesh, self.shards, data_axis=self.data_axis)
        return self._placement

    # -- membership -------------------------------------------------------
    @property
    def total_records(self) -> int:
        return sum(s.num_records for s in self.shards) + self.delta.occupancy

    def _ensure_router(self, sample: np.ndarray) -> None:
        """Build the reference pivots once enough rows exist.

        Pivot selection needs ``num_pivots`` distinct samples; until then
        the router stays None and queries fall back to exhaustive fan-out
        (there is at most a bootstrap delta to scan anyway).
        """
        if self.router is None and \
                len(sample) >= self.cfg.shard_cfg.num_pivots:
            self.router = SignatureRouter.from_sample(
                jax.random.PRNGKey(self.cfg.seed),
                sample[: max(4 * self.cfg.shard_cfg.num_pivots, 256)],
                self.cfg.shard_cfg)

    def add_shard(self, key: str, data: np.ndarray,
                  global_ids: Optional[np.ndarray] = None) -> ShardHandle:
        """Build and register an immutable shard over ``data``.

        ``global_ids`` defaults to the next contiguous fleet-global range.
        """
        data = np.asarray(data, dtype=np.float32)
        if any(s.key == key for s in self.shards):
            raise ValueError(f"duplicate shard key {key!r}")
        if global_ids is None:
            global_ids = np.arange(self._next_gid,
                                   self._next_gid + len(data), dtype=np.int32)
        global_ids = np.asarray(global_ids, dtype=np.int32)
        if len(global_ids):
            self._next_gid = max(self._next_gid, int(global_ids.max()) + 1)
        build_key = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed), len(self.shards) + 17)
        index = build_index(build_key, jnp.asarray(data), self.cfg.shard_cfg)
        self._ensure_router(data)
        handle = ShardHandle(key=key, index=index, global_ids=global_ids)
        self.shards.append(handle)
        self.router.register(key, self.router.summarize(data))
        self._placement = None          # sealed set changed: re-lay out
        return handle

    # -- streaming ingest -------------------------------------------------
    def insert(self, batch: np.ndarray) -> np.ndarray:
        """Append a ``[B, series_len]`` batch into the streaming delta.

        Returns the assigned fleet-global record ids (``[B] int32``,
        contiguous from the current high-water mark) — the ids later
        queries report in their ``gid`` output.  Records are immediately
        visible to queries on every placement (the delta is always
        executed host-side).  When the delta reaches ``delta_capacity``
        and ``auto_compact`` is on, it is sealed into an immutable shard
        (see :meth:`compact`).

        Raises ValueError when the batch is not ``[B, series_len]``.
        """
        batch = np.asarray(batch, dtype=np.float32)
        if batch.ndim != 2 or batch.shape[1] != self.cfg.shard_cfg.series_len:
            raise ValueError(f"insert batch shape {batch.shape} != "
                             f"[B, {self.cfg.shard_cfg.series_len}]")
        gids = np.arange(self._next_gid, self._next_gid + len(batch),
                         dtype=np.int32)
        self._next_gid += len(batch)
        before = self.delta.rebuilds
        self.delta.insert(batch, gids)
        # accumulated delta contents, not just this batch: small first
        # batches must not stop the router from ever being built
        self._ensure_router(self.delta.data)
        self.stats.delta_rebuilds += self.delta.rebuilds - before
        self.stats.inserts += len(batch)
        self.stats.delta_occupancy = self.delta.occupancy
        if self.cfg.auto_compact and \
                self.delta.occupancy >= max(self.cfg.delta_capacity,
                                            self.delta.min_build):
            self.compact()
        return gids

    def compact(self) -> Optional[ShardHandle]:
        """Seal the delta into an immutable shard (full INX rebuild).

        Global ids are preserved, so answers on the same contents are
        unchanged (tested bit-for-bit).  The delta is reset only after the
        shard build succeeds, so a failed build leaves every buffered
        insert queryable in place.  The sealed set changes, so an attached
        mesh placement is re-laid out on the next mesh query.

        Returns the new ShardHandle, or None when the delta is empty;
        raises ValueError when the delta holds fewer than ``num_pivots``
        records (pivot selection needs that many samples).
        """
        if not self.delta.occupancy:
            return None
        if self.delta.occupancy < self.delta.min_build:
            raise ValueError(
                f"cannot compact {self.delta.occupancy} records: pivot "
                f"selection needs >= {self.delta.min_build}; keep inserting "
                f"or lower shard_cfg.num_pivots")
        self._seal_count += 1
        while any(s.key == f"sealed:{self._seal_count}"
                  for s in self.shards):
            self._seal_count += 1
        handle = self.add_shard(f"sealed:{self._seal_count}",
                                self.delta.data,
                                global_ids=self.delta.global_ids)
        self.delta.take()
        self.stats.compactions += 1
        self.stats.delta_occupancy = 0
        return handle

    # -- query ------------------------------------------------------------
    def _query_sealed_host(self, queries: np.ndarray, k: int,
                           mask: np.ndarray, variant: str,
                           use_kernel: Optional[bool],
                           best_d: np.ndarray, best_g: np.ndarray,
                           touched: np.ndarray, scanned: np.ndarray) -> None:
        """The host-loop oracle: one ``knn_query`` dispatch per sealed
        shard, fused on the host in shard order (accumulators in place)."""
        for si, shard in enumerate(self.shards):
            qsel = np.nonzero(mask[:, si])[0]
            if not len(qsel):
                continue
            dist, gid, qp = knn_query(shard.index,
                                      jnp.asarray(queries[qsel]), k,
                                      variant=variant, use_kernel=use_kernel)
            dist, gid = np.asarray(dist), np.asarray(gid)
            gg = np.where(gid >= 0,
                          shard.global_ids[np.maximum(gid, 0)],
                          -1).astype(np.int32)
            md, mg = merge_topk(jnp.asarray(best_d[qsel]),
                                jnp.asarray(best_g[qsel]),
                                jnp.asarray(dist), jnp.asarray(gg), k)
            best_d[qsel] = np.asarray(md)
            best_g[qsel] = np.asarray(mg)
            pt = np.asarray(qp.partitions_touched(), np.int64)
            touched[qsel] += pt
            scanned[qsel] += np.asarray(
                candidates_scanned(qp, shard.index.store), np.int64)
            self.stats.observe_shard(shard.key, len(qsel), int(pt.sum()))

    def _query_sealed_mesh(self, queries: np.ndarray, k: int,
                           mask: np.ndarray, variant: str,
                           use_kernel: Optional[bool],
                           best_d: np.ndarray, best_g: np.ndarray,
                           touched: np.ndarray, scanned: np.ndarray) -> None:
        """Mesh fan-out: plan per shard on the host (each shard has its own
        pivots/trie — cheap), stack the plans to ``[S_pad, Q, MP]`` with
        routing expressed as masked-out rows, and run one shard_map that
        refines every resident shard per device and folds the answers in
        shard order.  Bit-identical to :meth:`_query_sealed_host`."""
        pl = self._ensure_placement()
        qn = len(queries)
        qj = jnp.asarray(queries)
        plans = []
        for si, shard in enumerate(self.shards):
            if not mask[:, si].any():   # host loop skips unrouted shards:
                plans.append(None)      # don't plan what won't execute
                continue
            p4r, _ = shard.index.featurize(qj)
            plans.append(plan(shard.index, p4r, variant=variant))
        if all(qp is None for qp in plans):
            return                      # nothing routed: accumulators stay PAD
        mp = max(int(qp.sel_part.shape[-1]) for qp in plans
                 if qp is not None)
        sp = np.full((pl.num_slots, qn, mp), -1, np.int32)
        lo = np.zeros((pl.num_slots, qn, mp), np.int32)
        hi = np.zeros((pl.num_slots, qn, mp), np.int32)
        for si, (shard, qp) in enumerate(zip(self.shards, plans)):
            if qp is None:
                continue
            w = int(qp.sel_part.shape[-1])
            routed = mask[:, si]
            sp[si, :, :w] = np.where(routed[:, None],
                                     np.asarray(qp.sel_part), -1)
            lo[si, :, :w] = np.asarray(qp.sel_lo)
            hi[si, :, :w] = np.asarray(qp.sel_hi)
            pt = np.asarray(qp.partitions_touched(), np.int64)
            touched += np.where(routed, pt, 0)
            scanned += np.where(
                routed,
                np.asarray(candidates_scanned(qp, shard.index.store),
                           np.int64), 0)
            self.stats.observe_shard(shard.key, int(routed.sum()),
                                     int(pt[routed].sum()))
        dist, gid = pl.dispatch(queries, sp, lo, hi, k,
                                use_kernel=use_kernel)
        best_d[:], best_g[:] = dist, gid

    def query(self, queries: np.ndarray, k: int = 0, *,
              routing: str = "signature", variant: str = "adaptive",
              use_kernel: Optional[bool] = None,
              fanout: Optional[int] = None,
              placement: Optional[str] = None
              ) -> Tuple[np.ndarray, np.ndarray, FleetQueryInfo]:
        """Fan out, per-shard kNN, fuse with ``merge_topk``.

        Args:
          queries: ``[Q, n]`` raw query series.
          k: answer size (0 ⇒ ``shard_cfg.k``).
          routing: ``"signature"`` routes each query to the ``fanout``
            best-scoring sealed shards; ``"exhaustive"`` executes every
            shard (lossless fan-out).  The delta is always executed.
          variant: per-shard planner variant; ``"exhaustive"`` makes each
            shard exact, so exhaustive routing + exhaustive variant equals
            brute-force over the fleet contents.
          use_kernel: per-shard refine implementation (True = streaming
            fused Pallas kernel, False = dense oracle, None = backend
            default — fused on accelerators, dense on CPU).
          placement: where the sealed shards execute — ``"host"`` (the
            sequential per-shard oracle loop), ``"mesh"`` (one shard_map
            over the device-resident stacked stores; needs an attached
            mesh), or None for the default: ``"mesh"`` when a mesh is
            attached, else ``"host"``.  Both placements return bit-
            identical results; the delta is always executed host-side.

        Returns:
          (dist ``[Q, k]`` ascending ED, gid ``[Q, k]`` fleet-global ids,
          info).  Rows with fewer than k candidates across the routed
          shards carry the :data:`repro.core.PAD_DIST` sentinel and
          ``gid = -1``.
        """
        if routing not in ("signature", "exhaustive"):
            raise ValueError(f"unknown routing mode {routing!r}")
        placement = self._resolve_placement(placement)
        queries = np.asarray(queries, dtype=np.float32)
        if queries.ndim != 2:
            raise ValueError(f"queries must be [Q, n], got {queries.shape}")
        k = k or self.cfg.shard_cfg.k
        qn = len(queries)
        best_d = np.full((qn, k), PAD_DIST, np.float32)
        best_g = np.full((qn, k), -1, np.int32)
        touched = np.zeros(qn, np.int64)
        scanned = np.zeros(qn, np.int64)
        s = len(self.shards)

        if routing == "exhaustive" or self.router is None or s == 0:
            mask = np.ones((qn, s), dtype=bool)
        else:
            mask = self.router.route(queries, fanout or self.cfg.fanout)

        if s:
            run_sealed = self._query_sealed_mesh if placement == "mesh" \
                else self._query_sealed_host
            run_sealed(queries, k, mask, variant, use_kernel,
                       best_d, best_g, touched, scanned)

        delta_res = self.delta.query(queries, k, variant=variant,
                                     use_kernel=use_kernel)
        if delta_res is not None:
            dist, gid, dt, dsc = delta_res
            gg = np.where(gid >= 0,
                          self.delta.global_ids[np.maximum(gid, 0)],
                          -1).astype(np.int32)
            md, mg = merge_topk(jnp.asarray(best_d), jnp.asarray(best_g),
                                jnp.asarray(dist), jnp.asarray(gg), k)
            best_d, best_g = np.asarray(md), np.asarray(mg)
            touched += dt
            scanned += dsc
            self.stats.observe_shard(self.DELTA_KEY, qn, int(dt.sum()))

        self.stats.queries += qn
        self.stats.routed_pairs += int(mask.sum())
        self.stats.exhaustive_pairs += qn * s
        return best_d, best_g, FleetQueryInfo(
            partitions_touched=touched, candidates_scanned=scanned,
            routed_mask=mask)

    def scan_exact(self, queries: np.ndarray, k: int = 0, *,
                   use_kernel: Optional[bool] = None, mesh=None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Lossless fallback as a *single* refine over the fused store.

        Concatenates every shard store (global-id remapped) and runs one
        exhaustive ``dispatch_refine`` — the fleet answer without any
        per-shard scatter/gather, equal to exhaustive-routing +
        exhaustive-variant :meth:`query`.

        ``mesh`` (default: the fleet's attached mesh, if any) executes the
        union scan sharded over the mesh's data axis via
        ``refine_sharded`` — here the *partition* axis of the union store
        is what shards over the devices, not the shard axis.

        Returns ``(dist [Q, k], gid [Q, k])`` with the usual
        :data:`repro.core.PAD_DIST` / ``gid = -1`` pad sentinel.
        """
        queries = np.asarray(queries, dtype=np.float32)
        k = k or self.cfg.shard_cfg.k
        mesh = mesh if mesh is not None else self.mesh
        stores = [s.index.store for s in self.shards]
        gid_maps = [s.global_ids for s in self.shards]
        dstore = self.delta.store()
        if dstore is not None:
            stores.append(dstore)
            gid_maps.append(self.delta.global_ids)
        if not stores:
            return (np.full((len(queries), k), PAD_DIST, np.float32),
                    np.full((len(queries), k), -1, np.int32))
        union = concat_stores(stores, gid_maps)
        sel, lo, hi = exhaustive_selection(union.num_partitions,
                                           len(queries))
        dist, gid = dispatch_refine(union, jnp.asarray(queries), sel, lo, hi,
                                    k, mesh=mesh, data_axis=self.data_axis,
                                    use_kernel=use_kernel)
        return np.asarray(dist), np.asarray(gid)

    def audit_routing(self, queries: np.ndarray, k: int = 0, *,
                      variant: str = "adaptive") -> float:
        """Measure routed-mode precision against the exhaustive oracle.

        Returns the mean fraction of the exhaustive fan-out's answers the
        routed fan-out also returned, and folds it into
        ``stats.routing_precision``.
        """
        k = k or self.cfg.shard_cfg.k
        _, g_routed, _ = self.query(queries, k, routing="signature",
                                    variant=variant)
        _, g_full, _ = self.query(queries, k, routing="exhaustive",
                                  variant=variant)
        overlaps = []
        for gr, gf in zip(g_routed, g_full):
            truth = set(int(x) for x in gf if x >= 0)
            if not truth:
                continue
            got = set(int(x) for x in gr if x >= 0)
            overlaps.append(len(got & truth) / len(truth))
        precision = float(np.mean(overlaps)) if overlaps else 1.0
        self.stats.routing_audits += 1
        self.stats.routing_overlap += precision
        return precision
