"""IndexFleet — sharded multi-index serving with streaming ingest.

The single two-level CLIMBER index is built once and queried forever; a
serving system needs many of them (per-tenant, per-time-range) plus a place
for data that keeps arriving.  The fleet owns:

  * **sealed shards** — immutable :class:`repro.core.ClimberIndex` instances
    keyed by tenant / time-range, each with a ``global_ids`` map from its
    local record ids to fleet-global ids;
  * a **router** (:class:`repro.fleet.router.SignatureRouter`) that fans a
    query out to a shard subset scored on signature-prefix affinity, with
    exhaustive fan-out as the lossless fallback;
  * a **delta shard** — an append-only index with per-partition capacity
    slack that absorbs ``insert()`` batches through the existing assignment
    path (featurize → group → trie → partition scatter) and is always
    queried, so new records are visible immediately;
  * ``compact()`` — seals the delta into an immutable shard by re-running
    the full CLIMBER-INX build (pivot selection, centroids, partitioning)
    over its contents, preserving global ids, so queries always see one
    consistent fleet view.

Cross-shard fusion goes through :func:`repro.core.merge_topk` with
global-id remapping; per-shard answers carry the :data:`repro.core.PAD_DIST`
sentinel for missing slots, which propagates through every merge.  With
exhaustive routing and the ``"exhaustive"`` planner variant the fleet answer
is bit-identical to a single-index ``knn_query`` over the concatenated data
(both are exact ED top-k computed by the same refine arithmetic).

Placement — where the sealed shards execute:

  * ``placement="host"`` — the lossless oracle: a host loop dispatches each
    sealed shard's ``knn_query`` sequentially and fuses on the host;
  * ``placement="mesh"`` — the sealed stores *and* trie skeletons live
    stacked on the device mesh
    (:class:`repro.fleet.placement.MeshFleetPlacement`) and one
    ``shard_map`` runs the whole query — featurize → trie descent → plan →
    refine → in-order ``merge_topk`` fold — as a single device program
    (planner variants without a registered device twin fall back to host
    planning + the refine-only fan-out).  Bit-identical to the host loop
    (the device planner reproduces the host plans entry-for-entry, same
    refine arithmetic, same merge order); the delta is always queried
    host-side and merged last on both paths.  Device plans are memoized
    per query in an LRU (:class:`repro.serve.knn_engine.PlanCache`) keyed
    on (placement epoch, planner variant, query bytes); the epoch
    increments whenever the sealed shard set or mesh changes
    (``add_shard`` / seal / merge / retire / ``attach_mesh``), so a hit
    can never replay a plan row from a retired layout.  The host loop
    memoizes through the same cache (per-shard rows keyed on
    (``"host"``, epoch, variant, shard slot, query bytes)), so
    ``FleetQueryInfo.plan_cache_hits`` / ``plan_cache_misses`` report on
    both placements identically.

Observability: every query opens a ``fleet.query`` span with
``fleet.plan`` / ``fleet.refine`` / ``fleet.merge`` children (per sealed
shard on the host loop, per device program on the mesh), ingest opens
``fleet.insert → wal.append / delta.scatter``, and the background
compactor opens ``compact.seal → compact.build / compact.swap`` on its
worker thread — see ``repro.obs`` and docs/OBSERVABILITY.md.  Call
latencies land in the ``fleet.query_latency_ms`` registry histogram
(labelled per fleet instance), which is where the benchmarks read their
p50/p99 columns.

``mesh=`` at construction (or :meth:`IndexFleet.attach_mesh`) enables the
mesh path and makes it the default; without a mesh the default stays
``"host"``.

Lifecycle plane (``repro.fleet.lifecycle``) — what makes the fleet survive
a restart and stay healthy over time:

  * **durability** — with a ``storage_dir`` attached, every ``insert``
    batch is appended to a binary write-ahead log *before* the delta
    scatter, and ``compact`` snapshots the sealed shard before truncating
    the WAL segments it came from.  :meth:`IndexFleet.save` /
    :meth:`IndexFleet.open` persist / restore the whole fleet; restart
    replays the WAL tail batch-for-batch (skipping frames a sealed shard
    already covers), so post-restart answers are bit-identical to the
    never-crashed fleet;
  * **background compaction** — ``compact()`` always runs the INX rebuild
    on a worker thread over a frozen delta; queries keep hitting the
    frozen delta until the sealed shard swaps in atomically.
    ``compact_async()`` returns the ticket instead of waiting
    (``FleetConfig.background_compaction`` makes auto-compaction
    non-blocking too);
  * **merge / retirement** — :meth:`IndexFleet.maintenance` applies an
    LSM-style :class:`repro.fleet.lifecycle.merge.MergePolicy`: small
    adjacent sealed shards are merged (rebuild over their concatenated
    records, global ids preserved) and shards past a time horizon are
    retired.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import weakref
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import (ClimberIndex, PartitionStore,
                              _route_full_dataset_jit, build_index,
                              build_store)
from repro.core.query import (candidates_scanned, exhaustive_selection,
                              knn_query, plan)
from repro.core.refine import PAD_DIST, dispatch_refine, merge_topk, refine
from repro.distributed.store import concat_stores
from repro.fleet.router import SignatureRouter
from repro.obs import REGISTRY, TRACER
from repro.serve.knn_engine import PlanCache
from repro.utils.config import ClimberConfig

# distinguishes each fleet's metric series in the process registry
_FLEET_SEQ = itertools.count()


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs on top of the per-shard :class:`ClimberConfig`."""

    shard_cfg: ClimberConfig
    fanout: int = 2                 # shards the router selects per query
    routing_threshold: float = 0.85  # score-mass cut for routing="adaptive"
                                     # (overridden by a learned
                                     # router.threshold or a per-call arg)
    delta_capacity: int = 4096      # records the delta holds before sealing
    delta_pad: Optional[int] = None  # physical slots per delta partition
                                     # (None => shard_cfg.capacity — full
                                     # capacity slack for in-place appends)
    auto_compact: bool = True       # seal automatically at delta_capacity
    background_compaction: bool = False  # auto-compaction returns before the
                                         # rebuild finishes (ticket-based)
    plan_cache_size: int = 256      # LRU capacity of the per-query plan
                                    # cache (host and mesh placement; 0 = off)
    seed: int = 0


@dataclass
class ShardHandle:
    """One immutable member of the fleet."""

    key: str                        # tenant / time-range label
    index: ClimberIndex
    global_ids: np.ndarray          # [n_shard] local row -> global record id
    sealed: bool = True
    created_at: float = 0.0         # wall-clock seal/registration time
                                    # (drives MergePolicy.retire_after)

    @property
    def num_records(self) -> int:
        return int(self.global_ids.shape[0])


@dataclass
class FleetStats:
    """Aggregate serving/ingest counters for the whole fleet."""

    queries: int = 0
    inserts: int = 0
    compactions: int = 0
    delta_rebuilds: int = 0
    delta_occupancy: int = 0
    routed_pairs: int = 0           # (query, shard) executions actually run
    exhaustive_pairs: int = 0       # what exhaustive fan-out would have run
    routing_audits: int = 0
    routing_overlap: float = 0.0    # running sum of audited precision
    compaction_ms: float = 0.0      # cumulative seal wall time (build+swap)
    wal_bytes: int = 0              # pending WAL bytes (frames not yet sealed)
    merges: int = 0                 # shard pairs merged by maintenance()
    retired_shards: int = 0         # shards aged out by maintenance()
    per_shard_queries: Dict[str, int] = field(default_factory=dict)
    per_shard_partitions: Dict[str, int] = field(default_factory=dict)

    def observe_shard(self, key: str, queries: int, partitions: int) -> None:
        self.per_shard_queries[key] = \
            self.per_shard_queries.get(key, 0) + queries
        self.per_shard_partitions[key] = \
            self.per_shard_partitions.get(key, 0) + partitions

    @property
    def routing_precision(self) -> float:
        """Mean audited recall of routed vs exhaustive fan-out (1.0 = no
        audit has seen the router drop a true neighbour)."""
        return self.routing_overlap / self.routing_audits \
            if self.routing_audits else 1.0

    @property
    def fanout_savings(self) -> float:
        """Fraction of per-shard executions the router skipped."""
        return 1.0 - self.routed_pairs / self.exhaustive_pairs \
            if self.exhaustive_pairs else 0.0

    def lifecycle_snapshot(self) -> dict:
        """Just the lifecycle counters (rides on ``FleetQueryInfo``)."""
        return {"compaction_ms": self.compaction_ms,
                "wal_bytes": self.wal_bytes,
                "merges": self.merges,
                "retired_shards": self.retired_shards}

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["routing_precision"] = self.routing_precision
        d["fanout_savings"] = self.fanout_savings
        return d


@dataclass
class FleetQueryInfo:
    """Per-query execution metrics of one fleet query call."""

    partitions_touched: np.ndarray   # [Q] summed over every shard executed
    candidates_scanned: np.ndarray   # [Q]
    routed_mask: np.ndarray          # [Q, S] sealed shards each query hit
    lifecycle: Optional[dict] = None  # FleetStats.lifecycle_snapshot() at
                                      # query time (compaction_ms, wal_bytes,
                                      # merges, retired_shards)
    stage_ms: Optional[dict] = None   # wall-ms per stage of this call:
                                      # plan_ms (host planning / plan-cache
                                      # work), refine_ms (sealed-shard
                                      # execution — on the fused mesh path
                                      # this is the whole device program,
                                      # planning included), merge_ms
                                      # (host-side merge folds + delta)
    plan_cache_hits: int = 0          # per-query plan-cache hits of this
    plan_cache_misses: int = 0        # call (host and mesh placement)


class DeltaShard:
    """Append-only ingest shard with capacity slack.

    Bootstrap: until ``num_pivots`` records exist a CLIMBER index cannot be
    built (pivot selection needs that many samples), so the delta serves
    queries from a single-partition store with an exact scan.  From the
    first rebuild on it is a real ClimberIndex whose partitions carry
    physical slot slack (``delta_pad``); inserts route through the existing
    assignment path and scatter into free slots in place.  A batch that
    overflows its target partition triggers a rebuild (re-running pivot
    selection and partitioning over the accumulated contents).
    """

    def __init__(self, cfg: ClimberConfig, *, pad: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg.replace(
            partition_pad=pad if pad is not None else cfg.capacity)
        self._seed = seed
        self.data = np.zeros((0, cfg.series_len), np.float32)
        self.global_ids = np.zeros((0,), np.int32)
        self.index: Optional[ClimberIndex] = None
        self.rebuilds = 0
        self.min_build = cfg.num_pivots

    @property
    def occupancy(self) -> int:
        return int(self.data.shape[0])

    # -- ingest -----------------------------------------------------------
    def insert(self, batch: np.ndarray, gids: np.ndarray) -> None:
        base = self.occupancy
        self.data = np.concatenate([self.data, batch], axis=0)
        self.global_ids = np.concatenate(
            [self.global_ids, gids.astype(np.int32)])
        if self.index is None:
            if self.occupancy >= self.min_build:
                self._rebuild()
            return
        if not self._scatter(batch, base):
            self._rebuild()

    def _rebuild(self) -> None:
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                 self.occupancy)
        self.index = build_index(key, jnp.asarray(self.data), self.cfg)
        self.rebuilds += 1

    def _scatter(self, batch: np.ndarray, base: int) -> bool:
        """Route a batch through the index's assignment path and append the
        records into free partition slots.  False = some partition is full
        (the caller rebuilds)."""
        idx = self.index
        part, rec_dfs = _route_full_dataset_jit(
            jnp.asarray(batch), idx.pivots, idx.centroid_onehot, idx.trie,
            idx.cfg)
        part = np.asarray(part)
        rec_dfs = np.asarray(rec_dfs)
        store = idx.store
        count = np.asarray(store.count).copy()

        order = np.argsort(part, kind="stable")
        ps = part[order]
        run_start = np.concatenate([[True], ps[1:] != ps[:-1]]) \
            if len(ps) else np.zeros(0, bool)
        first_pos = np.nonzero(run_start)[0]
        run_id = np.cumsum(run_start) - 1
        within = np.arange(len(ps)) - first_pos[run_id]
        slots = count[ps] + within
        if len(slots) and slots.max() >= store.capacity:
            return False

        rows = batch[order].astype(np.float32)
        data_np = np.asarray(store.data).copy()
        norms_np = np.asarray(store.norms).copy()
        dfs_np = np.asarray(store.rec_dfs).copy()
        gid_np = np.asarray(store.rec_gid).copy()
        data_np[ps, slots] = rows
        # same arithmetic as build_store so a later rebuild is bit-identical
        norms_np[ps, slots] = \
            np.sum(rows.astype(np.float64) ** 2, axis=-1).astype(np.float32)
        dfs_np[ps, slots] = rec_dfs[order]
        gid_np[ps, slots] = (base + order).astype(np.int32)
        np.add.at(count, ps, 1)
        new_store = PartitionStore(
            data=jnp.asarray(data_np), norms=jnp.asarray(norms_np),
            rec_dfs=jnp.asarray(dfs_np), rec_gid=jnp.asarray(gid_np),
            count=jnp.asarray(count))
        self.index = dataclasses.replace(idx, store=new_store)
        return True

    def take(self) -> Tuple[np.ndarray, np.ndarray]:
        """Hand the accumulated contents to compaction and reset."""
        out = (self.data, self.global_ids)
        self.data = np.zeros((0, self.cfg.series_len), np.float32)
        self.global_ids = np.zeros((0,), np.int32)
        self.index = None
        return out

    # -- query ------------------------------------------------------------
    def _bootstrap_store(self) -> PartitionStore:
        return build_store(jnp.asarray(self.data),
                           np.zeros(self.occupancy, np.int32),
                           np.zeros(self.occupancy, np.int32), 1)

    def store(self) -> Optional[PartitionStore]:
        if not self.occupancy:
            return None
        return self.index.store if self.index is not None \
            else self._bootstrap_store()

    def query(self, queries: np.ndarray, k: int, *, variant: str,
              use_kernel: Optional[bool] = None):
        """(dist, gid_local, touched, scanned) or None when empty."""
        if not self.occupancy:
            return None
        q = len(queries)
        if self.index is None:
            store = self._bootstrap_store()
            sel = jnp.zeros((q, 1), jnp.int32)
            dist, gid = refine(store, jnp.asarray(queries), sel, sel,
                               sel + 1, k, use_kernel=use_kernel)
            return (np.asarray(dist), np.asarray(gid),
                    np.ones(q, np.int64),
                    np.full(q, self.occupancy, np.int64))
        dist, gid, qp = knn_query(self.index, jnp.asarray(queries), k,
                                  variant=variant, use_kernel=use_kernel)
        return (np.asarray(dist), np.asarray(gid),
                np.asarray(qp.partitions_touched(), np.int64),
                np.asarray(candidates_scanned(qp, self.index.store),
                           np.int64))


@dataclass
class FrozenDelta:
    """A delta frozen for sealing: contents + the WAL segments backing it.

    Built by :meth:`IndexFleet._freeze` under the fleet lock; the build
    runs over ``data``/``global_ids`` off the lock while queries keep
    hitting the frozen :class:`DeltaShard` (still registered as
    ``fleet._sealing``).
    """

    delta: DeltaShard
    frames: List[Tuple[np.ndarray, np.ndarray]]   # (gids, batch) in order
    segs: List[int]                               # WAL segments to drop
    fold: int                                     # build-key fold (shard
                                                  # count at freeze + 17)
    key: str                                      # sealed shard key

    @property
    def data(self) -> np.ndarray:
        return self.delta.data

    @property
    def global_ids(self) -> np.ndarray:
        return self.delta.global_ids


def _frame_nbytes(gids: np.ndarray, batch: np.ndarray) -> int:
    from repro.fleet.lifecycle.wal import _HEADER
    return _HEADER.size + gids.size * 4 + batch.size * 4


class IndexFleet:
    """Several CLIMBER shards + streaming delta behind one query surface."""

    DELTA_KEY = "__delta__"

    def __init__(self, cfg: FleetConfig, *, mesh=None,
                 data_axis: str = "data",
                 storage_dir: Optional[str] = None):
        self.cfg = cfg
        self.shards: List[ShardHandle] = []
        self.router: Optional[SignatureRouter] = None
        self.delta = DeltaShard(cfg.shard_cfg, pad=cfg.delta_pad,
                                seed=cfg.seed + 1)
        self.stats = FleetStats()
        self._next_gid = 0
        self._seal_count = 0
        self._merge_count = 0
        self.mesh = mesh
        self.data_axis = data_axis
        self._placement = None          # lazily built MeshFleetPlacement
        self._placement_epoch = 0       # bumps with every sealed-set change
        self._plan_cache = PlanCache(cfg.plan_cache_size)
        self.merge_policy = None        # default MergePolicy for maintenance
        # -- lifecycle state (repro.fleet.lifecycle) ----------------------
        self._lock = threading.RLock()
        self.wal = None                 # WriteAheadLog when storage attached
        self.storage_dir: Optional[Path] = None
        self._shard_dirs: Dict[str, str] = {}   # shard key -> snapshot slug
        self._frames: List[Tuple[np.ndarray, np.ndarray]] = []  # active delta
        self._delta_segs: List[int] = []        # WAL segments backing it
        self._sealing: Optional[DeltaShard] = None   # frozen mid-compaction
        self._sealing_frames: List[Tuple[np.ndarray, np.ndarray]] = []
        self._sealing_segs: List[int] = []
        self._seal_ticket = None        # in-flight CompactionTicket
        # -- observability (repro.obs) ------------------------------------
        # per-instance label: benchmark cells build fresh fleets and must
        # not share latency series; FleetStats keeps its exact dataclass
        # shape (snapshot() keys are tier-1-tested), so derived rates are
        # exposed through a weakref collector instead of new fields
        self.obs_label = f"fleet{next(_FLEET_SEQ)}"
        self.query_hist = REGISTRY.histogram("fleet.query_latency_ms",
                                             fleet=self.obs_label)
        self.compaction_hist = REGISTRY.histogram("fleet.compaction_ms",
                                                  fleet=self.obs_label)
        # per-query partitions-touched distribution: the live signal the
        # recall-targeted planner calibrates against (repro.eval.target)
        self.touched_hist = REGISTRY.histogram("fleet.partitions_touched",
                                               fleet=self.obs_label)
        # (scores, true-hit counts) pairs recorded by audit_routing(...,
        # record=True); SignatureRouter.learn_threshold consumes them
        self.routing_traces: List[Tuple[np.ndarray, np.ndarray]] = []
        # online recall sentinel (repro.obs.sentinel.RecallSentinel
        # installs itself here); query() hands it each answered batch to
        # shadow-sample — a pure observer, never on the answer path
        self.sentinel = None
        ref = weakref.ref(self)

        def _collect():
            fleet = ref()
            if fleet is None:
                return None
            s = fleet.stats
            return {"fleet.queries": s.queries,
                    "fleet.inserts": s.inserts,
                    "fleet.compactions": s.compactions,
                    "fleet.delta_occupancy": s.delta_occupancy,
                    "fleet.wal_bytes": s.wal_bytes,
                    "fleet.routing_precision": s.routing_precision,
                    "fleet.fanout_savings": s.fanout_savings,
                    "fleet.shards": len(fleet.shards)}

        REGISTRY.add_collector(_collect, fleet=self.obs_label)
        if storage_dir is not None:
            self.attach_storage(storage_dir)

    def reset_metrics(self) -> None:
        """Zero the aggregate stats and this fleet's latency histograms
        (benchmarks call it between warmup and the timed window)."""
        with self._lock:
            self.stats = FleetStats()
            self._refresh_gauges()
        self.query_hist.reset()
        self.compaction_hist.reset()
        self.touched_hist.reset()

    # -- mesh placement ---------------------------------------------------
    def attach_mesh(self, mesh, *, data_axis: str = "data") -> None:
        """Enable mesh-resident execution (and make it the default).

        The sealed stores are stacked and laid out over ``mesh``'s
        ``data_axis`` lazily, on the next ``placement="mesh"`` query, and
        re-laid out whenever the sealed shard set changes.
        """
        with self._lock:
            self.mesh = mesh
            self.data_axis = data_axis
            self._invalidate_placement()

    def _invalidate_placement(self) -> None:
        """Drop the lazy mesh layout and advance the placement epoch.

        Called (under the fleet lock) whenever the sealed shard set or the
        mesh changes — ``add_shard``, seal, lifecycle merge/retire,
        ``attach_mesh``.  The epoch bump also orphans every device-plan
        cache entry keyed on the old layout: plan rows are ``[S_pad, ...]``
        stacks in shard-slot order, so replaying one across a layout change
        would refine against the wrong shards.
        """
        self._placement = None
        self._placement_epoch += 1

    def _resolve_placement(self, placement: Optional[str]) -> str:
        """``None`` → ``"mesh"`` when a mesh is attached, else ``"host"``."""
        if placement is None:
            return "mesh" if self.mesh is not None else "host"
        if placement not in ("host", "mesh"):
            raise ValueError(f"unknown placement {placement!r}; "
                             f"expected 'host' or 'mesh'")
        if placement == "mesh" and self.mesh is None:
            raise ValueError("placement='mesh' needs a mesh: pass mesh= at "
                             "construction or call attach_mesh()")
        return placement

    def _ensure_placement(self):
        from repro.fleet.placement import MeshFleetPlacement
        if self._placement is None:
            self._placement = MeshFleetPlacement(
                self.mesh, self.shards, data_axis=self.data_axis)
        return self._placement

    # -- durable storage --------------------------------------------------
    def attach_storage(self, storage_dir) -> None:
        """Make the fleet durable under ``storage_dir``.

        Opens (or creates) the write-ahead log — subsequent ``insert``
        batches are appended there before the delta scatter — and flushes
        any batches buffered in memory before attachment.  Restoring an
        existing fleet directory goes through :meth:`open` instead; this
        method refuses a WAL that already holds frames (it cannot know
        whether they are in the delta).
        """
        from repro.fleet.lifecycle.snapshot import save_fleet
        from repro.fleet.lifecycle.wal import WriteAheadLog
        with self._lock:
            storage_dir = Path(storage_dir)
            if self.storage_dir is not None:
                if storage_dir != self.storage_dir:
                    raise ValueError(
                        f"fleet already attached to {self.storage_dir}; "
                        f"cannot re-attach to {storage_dir}")
                return
            wal = WriteAheadLog(storage_dir / "wal")
            if wal.replay():
                wal.close()
                raise ValueError(
                    f"{storage_dir} already holds WAL frames; use "
                    f"IndexFleet.open() to restore it")
            self.storage_dir = storage_dir
            self.wal = wal
            # flush memory-buffered batches: the frozen delta's frames get
            # their own (immediately rolled) segment so the segment ↔ delta
            # correspondence holds for the in-flight seal's truncation
            if self._sealing_frames:
                for g, b in self._sealing_frames:
                    self.wal.append(g, b)
                self._sealing_segs = [self.wal.roll()]
            for g, b in self._frames:
                self.wal.append(g, b)
            self._delta_segs = [self.wal.active_segment]
            save_fleet(self, storage_dir)

    def save(self, storage_dir=None) -> Path:
        """Persist the fleet: sealed-shard snapshots + manifest (+ WAL).

        ``storage_dir`` defaults to the attached storage directory; a
        fleet without one is attached first (from then on every insert is
        WAL-durable there).  Returns the directory.  Restore with
        :meth:`open`.
        """
        from repro.fleet.lifecycle.snapshot import save_fleet
        with self._lock:
            if storage_dir is None:
                if self.storage_dir is None:
                    raise ValueError("no storage attached: pass a directory")
                storage_dir = self.storage_dir
            self.attach_storage(storage_dir)
            return save_fleet(self, Path(storage_dir))

    @classmethod
    def open(cls, storage_dir, *, mesh=None,
             data_axis: str = "data") -> "IndexFleet":
        """Restore a fleet saved under ``storage_dir``.

        Sealed shards load from their snapshots (bit-exact arrays), the
        router restores verbatim, and the WAL tail replays batch-for-batch
        into a fresh delta — skipping frames whose global ids a sealed
        shard already covers (the crash window between compact swap and
        WAL truncate).  Replay reproduces the exact insert sequence, so
        the restored delta's rebuild history — and therefore every query
        answer, routed or exhaustive — is bit-identical to the
        never-crashed fleet (``tests/test_fleet_lifecycle.py``).
        """
        from repro.fleet.lifecycle.snapshot import (load_router, load_shard,
                                                    read_manifest)
        from repro.fleet.lifecycle.wal import WriteAheadLog
        storage_dir = Path(storage_dir)
        _recover_wal_rebase(storage_dir)
        manifest = read_manifest(storage_dir)
        shard_cfg = ClimberConfig(**manifest["shard_cfg"])
        cfg = FleetConfig(shard_cfg=shard_cfg, **manifest["fleet"])
        fleet = cls(cfg, mesh=mesh, data_axis=data_axis)
        fleet._seal_count = int(manifest["seal_count"])
        fleet._merge_count = int(manifest["merge_count"])
        for entry in manifest["shards"]:
            handle = load_shard(storage_dir / "shards" / entry["dir"])
            fleet.shards.append(handle)
            fleet._shard_dirs[handle.key] = entry["dir"]
        fleet.router = load_router(storage_dir, manifest, shard_cfg)
        fleet._next_gid = int(manifest["next_gid"])

        # replay the WAL tail in memory-frame mode (storage attaches after,
        # via an atomic rebase, so a replay-time auto-compaction can never
        # drop segments that still hold un-replayed frames)
        wal_dir = storage_dir / "wal"
        frames = []
        if wal_dir.exists():
            wal = WriteAheadLog(wal_dir)
            frames = wal.replay()
            wal.close()
        sealed = np.sort(np.concatenate(
            [s.global_ids for s in fleet.shards])) \
            if fleet.shards else np.zeros(0, np.int32)
        for _seg, gids, batch in frames:
            if len(sealed) and bool(np.isin(gids, sealed).all()):
                continue            # sealed before the crash; already durable
            with fleet._lock:
                fleet._log_frame(gids, batch)
                fleet._ingest(batch, gids)
                fleet._next_gid = max(fleet._next_gid, int(gids.max()) + 1) \
                    if len(gids) else fleet._next_gid
            fleet._maybe_auto_compact()
        fleet._attach_storage_rebased(storage_dir)
        return fleet

    def _attach_storage_rebased(self, storage_dir: Path) -> None:
        """Adopt ``storage_dir`` after a replay: atomically rewrite the WAL
        so it holds exactly the frames still pending in the delta.

        Ordering matters: shards sealed *during* the replay (an
        auto-compaction re-run) exist only in memory until ``save_fleet``
        snapshots them, so the manifest is made durable **before** the old
        WAL — whose frames are their only other copy — is rewritten.  A
        crash before the swap then replays the old WAL against the updated
        manifest (sealed frames skip by gid); a crash during the swap is
        finished by :func:`_recover_wal_rebase`.
        """
        import shutil

        from repro.fleet.lifecycle.snapshot import save_fleet
        from repro.fleet.lifecycle.wal import WriteAheadLog
        with self._lock:
            self.storage_dir = storage_dir
            save_fleet(self, storage_dir)       # replay-sealed shards first
            wal_dir = storage_dir / "wal"
            rebase = storage_dir / "wal.rebase"
            if rebase.exists():
                shutil.rmtree(rebase)
            wal = WriteAheadLog(rebase)
            for g, b in self._frames:
                wal.append(g, b)
            wal.close()
            old = storage_dir / "wal.old"
            if old.exists():
                shutil.rmtree(old)
            if wal_dir.exists():
                wal_dir.rename(old)
            rebase.rename(wal_dir)              # atomic publish
            if old.exists():
                shutil.rmtree(old)
            self.wal = WriteAheadLog(wal_dir)
            self._delta_segs = [self.wal.active_segment]
            self._refresh_gauges()

    # -- membership -------------------------------------------------------
    @property
    def total_records(self) -> int:
        with self._lock:
            sealed = sum(s.num_records for s in self.shards)
            frozen = self._sealing.occupancy if self._sealing else 0
            return sealed + frozen + self.delta.occupancy

    def _ensure_router(self, sample: np.ndarray) -> None:
        """Build the reference pivots once enough rows exist.

        Pivot selection needs ``num_pivots`` distinct samples; until then
        the router stays None and queries fall back to exhaustive fan-out
        (there is at most a bootstrap delta to scan anyway).
        """
        if self.router is None and \
                len(sample) >= self.cfg.shard_cfg.num_pivots:
            self.router = SignatureRouter.from_sample(
                jax.random.PRNGKey(self.cfg.seed),
                sample[: max(4 * self.cfg.shard_cfg.num_pivots, 256)],
                self.cfg.shard_cfg)

    def _build_shard_index(self, data: np.ndarray, fold: int) -> ClimberIndex:
        """Deterministic INX build for a fleet member (no lock needed)."""
        build_key = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed), fold)
        return build_index(build_key, jnp.asarray(data), self.cfg.shard_cfg)

    def add_shard(self, key: str, data: np.ndarray,
                  global_ids: Optional[np.ndarray] = None) -> ShardHandle:
        """Build and register an immutable shard over ``data``.

        ``global_ids`` defaults to the next contiguous fleet-global range.
        """
        data = np.asarray(data, dtype=np.float32)
        with self._lock:
            if any(s.key == key for s in self.shards):
                raise ValueError(f"duplicate shard key {key!r}")
            if global_ids is None:
                global_ids = np.arange(
                    self._next_gid, self._next_gid + len(data),
                    dtype=np.int32)
            global_ids = np.asarray(global_ids, dtype=np.int32)
            if len(global_ids):
                self._next_gid = max(self._next_gid,
                                     int(global_ids.max()) + 1)
            fold = len(self.shards) + 17
        index = self._build_shard_index(data, fold)
        handle = ShardHandle(key=key, index=index, global_ids=global_ids,
                             created_at=time.time())
        with self._lock:
            self._ensure_router(data)
            self.shards.append(handle)
            self.router.register(key, self.router.summarize(data))
            self._invalidate_placement()    # sealed set changed: re-lay out
            self._persist_shard(handle)
        return handle

    def _persist_shard(self, handle: ShardHandle) -> None:
        """Snapshot one sealed shard + rewrite the manifest (lock held)."""
        if self.storage_dir is None:
            return
        from repro.fleet.lifecycle.snapshot import (save_shard, shard_slug,
                                                    write_manifest)
        slug = shard_slug(handle.key, set(self._shard_dirs.values()))
        save_shard(self.storage_dir / "shards" / slug, handle)
        self._shard_dirs[handle.key] = slug
        write_manifest(self, self.storage_dir)

    # -- streaming ingest -------------------------------------------------
    def _log_frame(self, gids: np.ndarray, batch: np.ndarray) -> None:
        """Record one insert batch: WAL append (the durability point —
        strictly before the delta scatter) + the in-memory frame list."""
        with TRACER.span("wal.append", rows=len(gids),
                         durable=self.wal is not None):
            if self.wal is not None:
                self.wal.append(gids, batch)
            self._frames.append((gids, batch))

    def _ingest(self, batch: np.ndarray, gids: np.ndarray) -> None:
        """Apply one logged batch to the delta (lock held; no WAL write —
        shared by live inserts and WAL replay)."""
        with TRACER.span("delta.scatter", rows=len(batch)):
            before = self.delta.rebuilds
            self.delta.insert(batch, gids)
            # accumulated delta contents, not just this batch: small first
            # batches must not stop the router from ever being built
            self._ensure_router(self.delta.data)
            self.stats.delta_rebuilds += self.delta.rebuilds - before
        self.stats.inserts += len(batch)
        self._refresh_gauges()

    def _refresh_gauges(self) -> None:
        frozen = self._sealing.occupancy if self._sealing else 0
        self.stats.delta_occupancy = self.delta.occupancy + frozen
        self.stats.wal_bytes = sum(
            _frame_nbytes(g, b)
            for g, b in self._frames + self._sealing_frames)

    def _maybe_auto_compact(self) -> None:
        """Seal when the delta crosses capacity (called off the lock so a
        synchronous compact can join an in-flight background ticket)."""
        if not self.cfg.auto_compact:
            return
        with self._lock:
            due = self.delta.occupancy >= max(self.cfg.delta_capacity,
                                              self.delta.min_build)
        if not due:
            return
        if self.cfg.background_compaction:
            self.compact_async()
        else:
            self.compact()

    def insert(self, batch: np.ndarray) -> np.ndarray:
        """Append a ``[B, series_len]`` batch into the streaming delta.

        Returns the assigned fleet-global record ids (``[B] int32``,
        contiguous from the current high-water mark) — the ids later
        queries report in their ``gid`` output.  With storage attached the
        batch is appended to the write-ahead log *before* the delta
        scatter, so an acknowledged insert survives a crash (replayed by
        :meth:`open`).  Records are immediately visible to queries on
        every placement (the delta is always executed host-side).  When
        the delta reaches ``delta_capacity`` and ``auto_compact`` is on,
        it is sealed into an immutable shard (see :meth:`compact`; with
        ``background_compaction`` the seal happens off-thread and insert
        returns immediately).

        Raises ValueError when the batch is not ``[B, series_len]``.
        """
        batch = np.asarray(batch, dtype=np.float32)
        if batch.ndim != 2 or batch.shape[1] != self.cfg.shard_cfg.series_len:
            raise ValueError(f"insert batch shape {batch.shape} != "
                             f"[B, {self.cfg.shard_cfg.series_len}]")
        with TRACER.span("fleet.insert", rows=len(batch)):
            with self._lock:
                gids = np.arange(self._next_gid, self._next_gid + len(batch),
                                 dtype=np.int32)
                self._next_gid += len(batch)
                self._log_frame(gids, batch)
                self._ingest(batch, gids)
            self._maybe_auto_compact()
        return gids

    # -- compaction (freeze → build off-lock → swap) ----------------------
    def _next_seal_key(self) -> str:
        self._seal_count += 1
        while any(s.key == f"sealed:{self._seal_count}"
                  for s in self.shards):
            self._seal_count += 1
        return f"sealed:{self._seal_count}"

    def _freeze(self) -> Optional[FrozenDelta]:
        """Freeze the delta for sealing (lock held by the caller).

        The frozen delta stays registered (queries keep hitting it); a
        fresh delta takes over ingest, and the WAL rolls so the frozen
        segments correspond exactly to the frozen contents.  Returns None
        when the delta is empty; raises when it cannot build an index yet.
        """
        if self._sealing is not None:
            raise RuntimeError("a compaction is already in flight")
        if not self.delta.occupancy:
            return None
        if self.delta.occupancy < self.delta.min_build:
            raise ValueError(
                f"cannot compact {self.delta.occupancy} records: pivot "
                f"selection needs >= {self.delta.min_build}; keep inserting "
                f"or lower shard_cfg.num_pivots")
        frozen = FrozenDelta(delta=self.delta, frames=self._frames,
                             segs=list(self._delta_segs),
                             fold=len(self.shards) + 17,
                             key=self._next_seal_key())
        self._sealing = self.delta
        self._sealing_frames = self._frames
        self._sealing_segs = frozen.segs
        self.delta = DeltaShard(self.cfg.shard_cfg, pad=self.cfg.delta_pad,
                                seed=self.cfg.seed + 1)
        self._frames = []
        if self.wal is not None:
            self.wal.roll()
            self._delta_segs = [self.wal.active_segment]
        else:
            self._delta_segs = []
        self._refresh_gauges()
        return frozen

    def _finish_seal(self, frozen: FrozenDelta,
                     handle: ShardHandle) -> None:
        """Swap the sealed shard in atomically, then reclaim WAL space.

        Snapshot (when storage is attached) happens before the swap; the
        frozen segments are dropped only after the manifest lists the new
        shard, so every kill point leaves a replayable log: frames whose
        gids a sealed shard covers are skipped at replay.
        """
        from repro.fleet.lifecycle.snapshot import save_shard, shard_slug
        with self._lock:
            storage = self.storage_dir
            slug = shard_slug(handle.key, set(self._shard_dirs.values())) \
                if storage is not None else None
        if storage is not None:             # the slow write, off the lock
            save_shard(storage / "shards" / slug, handle)
        with self._lock:
            if storage is None and self.storage_dir is not None:
                # attach_storage() raced the build: it already flushed the
                # frozen frames into a rolled segment, so the snapshot must
                # exist before those segments are dropped below
                storage = self.storage_dir
                slug = shard_slug(handle.key, set(self._shard_dirs.values()))
                save_shard(storage / "shards" / slug, handle)
            self.shards.append(handle)
            self._ensure_router(frozen.data)
            self.router.register(handle.key,
                                 self.router.summarize(frozen.data))
            self._invalidate_placement()
            if storage is not None:
                from repro.fleet.lifecycle.snapshot import write_manifest
                self._shard_dirs[handle.key] = slug
                write_manifest(self, storage)
            self._sealing = None
            self._sealing_frames = []
            segs, self._sealing_segs = self._sealing_segs, []
            self.stats.compactions += 1
            self._refresh_gauges()
        if self.wal is not None and segs:
            self.wal.drop(segs)

    def _abort_seal(self, frozen: FrozenDelta) -> None:
        """Undo a failed seal: fold the frozen contents back into one live
        delta (replaying the logged frames in order) so no buffered insert
        is lost and a later compact retries over everything."""
        with self._lock:
            frames = self._sealing_frames + self._frames
            restored = DeltaShard(self.cfg.shard_cfg, pad=self.cfg.delta_pad,
                                  seed=self.cfg.seed + 1)
            for g, b in frames:
                restored.insert(b, g)
            self.delta = restored
            self._frames = frames
            self._delta_segs = self._sealing_segs + self._delta_segs
            self._sealing = None
            self._sealing_frames = []
            self._sealing_segs = []
            self._refresh_gauges()

    def compact(self) -> Optional[ShardHandle]:
        """Seal the delta into an immutable shard (full INX rebuild).

        The rebuild always runs on a worker thread over a frozen delta —
        queries keep hitting the frozen contents until the sealed shard
        swaps in atomically — and this method waits for it, so the
        synchronous contract is unchanged: global ids are preserved and
        answers on the same contents are bit-identical (tested).  A failed
        build folds the frozen contents back into the live delta, so every
        buffered insert stays queryable.  With storage attached, the
        sealed shard is snapshotted and the manifest rewritten *before*
        the WAL segments are truncated.  Use :meth:`compact_async` for the
        non-blocking ticket.

        Returns the new ShardHandle, or None when the delta is empty;
        raises ValueError when the delta holds fewer than ``num_pivots``
        records (pivot selection needs that many samples).
        """
        ticket = self._seal_ticket
        if ticket is not None:
            ticket.wait()
        ticket = self.compact_async()
        return ticket.wait() if ticket is not None else None

    def compact_async(self):
        """Trigger a background seal; returns a
        :class:`repro.fleet.lifecycle.compactor.CompactionTicket` (or None
        when the delta is empty, or the in-flight ticket when one is
        already running).  Raises like :meth:`compact` when the delta is
        too small to build."""
        from repro.fleet.lifecycle.compactor import \
            start_background_compaction
        return start_background_compaction(self)

    # -- maintenance (LSM merge + retirement) -----------------------------
    def maintenance(self, policy=None, *, now: Optional[float] = None) -> dict:
        """One lifecycle tick: retire aged shards, merge small neighbours.

        ``policy`` defaults to ``self.merge_policy`` (or the
        :class:`repro.fleet.lifecycle.merge.MergePolicy` defaults).  Exact
        answers over the surviving records are unchanged by merging —
        global ids are preserved and the merged shard is rebuilt over the
        concatenated records.  Returns a report dict (``merged``,
        ``retired`` key lists).
        """
        from repro.fleet.lifecycle.merge import run_maintenance
        return run_maintenance(self, policy=policy, now=now)

    # -- query ------------------------------------------------------------
    def _query_sealed_host(self, shards, queries: np.ndarray, k: int,
                           mask: np.ndarray, variant: str,
                           use_kernel: Optional[bool],
                           best_d: np.ndarray, best_g: np.ndarray,
                           touched: np.ndarray, scanned: np.ndarray,
                           stage: dict, epoch: int) -> None:
        """The host-loop oracle: one featurize→plan→refine dispatch per
        sealed shard (the arithmetic of ``knn_query``, staged under
        ``fleet.plan`` / ``fleet.refine`` / ``fleet.merge`` spans so the
        per-stage timers see plan vs refine vs merge), fused on the host
        in shard order (accumulators in place).

        Planning memoizes per (shard, query) through the fleet's
        :class:`PlanCache` under ``("host", epoch, variant, shard slot,
        query bytes)`` — disjoint from the mesh path's 3-tuple keys, and
        epoch-invalidated the same way.  A shard whose routed rows all hit
        assembles the plan on the host and skips its featurize+plan jits;
        cached rows are exactly a prior plan's output, so caching never
        changes results."""
        cache = self._plan_cache if self.cfg.plan_cache_size else None
        for si, shard in enumerate(shards):
            qsel = np.nonzero(mask[:, si])[0]
            if not len(qsel):
                continue
            qj = jnp.asarray(queries[qsel])
            with TRACER.span("fleet.plan", shard=shard.key) as sp_plan:
                keys = rows = None
                if cache is not None:
                    keys = [("host", epoch, variant, si,
                             queries[i].tobytes()) for i in qsel]
                    rows = [cache.get(kk) for kk in keys]
                if rows is not None and all(r is not None for r in rows):
                    nq, mp = len(qsel), rows[0][0].shape[-1]
                    sel_part = np.empty((nq, mp), np.int32)
                    sel_lo = np.empty((nq, mp), np.int32)
                    sel_hi = np.empty((nq, mp), np.int32)
                    pt = np.empty(nq, np.int64)
                    sc = np.empty(nq, np.int64)
                    for i, r in enumerate(rows):
                        sel_part[i], sel_lo[i], sel_hi[i], pt[i], sc[i] = r
                    sel_part, sel_lo, sel_hi = (jnp.asarray(sel_part),
                                                jnp.asarray(sel_lo),
                                                jnp.asarray(sel_hi))
                else:
                    p4r, _ = shard.index.featurize(qj)
                    qp = plan(shard.index, p4r, variant=variant)
                    jax.block_until_ready(qp.sel_part)
                    sel_part, sel_lo, sel_hi = (qp.sel_part, qp.sel_lo,
                                                qp.sel_hi)
                    pt = np.asarray(qp.partitions_touched(), np.int64)
                    sc = np.asarray(
                        candidates_scanned(qp, shard.index.store), np.int64)
                    if cache is not None:
                        sp_np, lo_np, hi_np = (np.asarray(qp.sel_part),
                                               np.asarray(qp.sel_lo),
                                               np.asarray(qp.sel_hi))
                        for i, kk in enumerate(keys):
                            cache.put(kk, (sp_np[i], lo_np[i], hi_np[i],
                                           pt[i], sc[i]))
            with TRACER.span("fleet.refine", shard=shard.key) as sp_ref:
                dist, gid = dispatch_refine(shard.index.store, qj,
                                            sel_part, sel_lo, sel_hi,
                                            k, use_kernel=use_kernel)
                dist, gid = np.asarray(dist), np.asarray(gid)
            with TRACER.span("fleet.merge", shard=shard.key) as sp_mrg:
                gg = np.where(gid >= 0,
                              shard.global_ids[np.maximum(gid, 0)],
                              -1).astype(np.int32)
                md, mg = merge_topk(jnp.asarray(best_d[qsel]),
                                    jnp.asarray(best_g[qsel]),
                                    jnp.asarray(dist), jnp.asarray(gg), k)
                best_d[qsel] = np.asarray(md)
                best_g[qsel] = np.asarray(mg)
            stage["plan_ms"] += sp_plan.duration_ms
            stage["refine_ms"] += sp_ref.duration_ms
            stage["merge_ms"] += sp_mrg.duration_ms
            touched[qsel] += pt
            scanned[qsel] += sc
            self.stats.observe_shard(shard.key, len(qsel), int(pt.sum()))

    def _query_sealed_mesh(self, shards, pl, queries: np.ndarray, k: int,
                           mask: np.ndarray, variant: str,
                           use_kernel: Optional[bool],
                           best_d: np.ndarray, best_g: np.ndarray,
                           touched: np.ndarray, scanned: np.ndarray,
                           stage: dict, epoch: int) -> None:
        """Mesh fan-out, device-resident planning.

        The default path runs featurize → trie descent → plan → refine →
        merge as ONE device program (``MeshFleetPlacement.query``) with
        routing applied as a device-side plan mask; per-query plan rows
        come back and are memoized in the fleet's :class:`PlanCache` under
        ``(placement epoch, variant, query bytes)``.  When every query of
        a batch hits, the plans are assembled on the host and only the
        refine fan-out (``pl.dispatch``) runs.  Planner variants without a
        registered device twin fall back to host planning + refine-only
        dispatch.  All paths are bit-identical to
        :meth:`_query_sealed_host`."""
        if not pl.supports_device_planning(variant):
            self._query_sealed_mesh_hostplan(
                shards, pl, queries, k, mask, variant, use_kernel,
                best_d, best_g, touched, scanned, stage)
            return
        qn = len(queries)
        routed_t = np.zeros((pl.num_slots, qn), dtype=bool)
        routed_t[: len(shards)] = mask.T
        cache = self._plan_cache
        with TRACER.span("fleet.plan", path="mesh") as sp_plan:
            keys = [(epoch, variant, queries[i].tobytes())
                    for i in range(qn)]
            rows = [cache.get(kk) for kk in keys]
            all_hit = bool(qn) and all(r is not None for r in rows)
            if all_hit:
                b = rows[0][0].shape[-1]
                sp = np.empty((pl.num_slots, qn, b), np.int32)
                lo = np.empty((pl.num_slots, qn, b), np.int32)
                hi = np.empty((pl.num_slots, qn, b), np.int32)
                pt_all = np.empty((pl.num_slots, qn), np.int64)
                sc_all = np.empty((pl.num_slots, qn), np.int64)
                for i, r in enumerate(rows):
                    sp[:, i], lo[:, i], hi[:, i], pt_all[:, i], \
                        sc_all[:, i] = r
                spm = np.where(routed_t[:, :, None], sp, -1)
        stage["plan_ms"] += sp_plan.duration_ms
        if all_hit:
            with TRACER.span("fleet.refine", path="mesh") as sp_ref:
                dist, gid = pl.dispatch(queries, spm, lo, hi, k,
                                        use_kernel=use_kernel)
            stage["refine_ms"] += sp_ref.duration_ms
        else:
            # the fused pass plans on device, inseparably from refine
            with TRACER.span("fleet.refine", path="mesh",
                             fused=True) as sp_ref:
                dist, gid, sp, lo, hi, pt_all, sc_all = pl.query(
                    queries, routed_t, k, variant=variant,
                    use_kernel=use_kernel)
            stage["refine_ms"] += sp_ref.duration_ms
            with TRACER.span("fleet.plan", path="mesh") as sp_put:
                for i, kk in enumerate(keys):
                    cache.put(kk, (sp[:, i], lo[:, i], hi[:, i],
                                   pt_all[:, i].astype(np.int64),
                                   sc_all[:, i].astype(np.int64)))
                pt_all = pt_all.astype(np.int64)
                sc_all = sc_all.astype(np.int64)
            stage["plan_ms"] += sp_put.duration_ms
        best_d[:], best_g[:] = dist, gid
        for si, shard in enumerate(shards):
            routed = mask[:, si]
            if not routed.any():        # host loop never executes it either
                continue
            touched += np.where(routed, pt_all[si], 0)
            scanned += np.where(routed, sc_all[si], 0)
            self.stats.observe_shard(shard.key, int(routed.sum()),
                                     int(pt_all[si][routed].sum()))

    def _query_sealed_mesh_hostplan(self, shards, pl, queries: np.ndarray,
                                    k: int, mask: np.ndarray, variant: str,
                                    use_kernel: Optional[bool],
                                    best_d: np.ndarray, best_g: np.ndarray,
                                    touched: np.ndarray,
                                    scanned: np.ndarray,
                                    stage: dict) -> None:
        """Host-planned mesh fallback: plan per shard on the host (each
        shard has its own pivots/trie — cheap), stack the plans to
        ``[S_pad, Q, MP]`` with routing expressed as masked-out rows, and
        run one shard_map that refines every resident shard per device and
        folds the answers in shard order.  Used for planner variants with
        no registered device twin; never cached (plan widths are
        batch-dependent here)."""
        qn = len(queries)
        qj = jnp.asarray(queries)
        with TRACER.span("fleet.plan", path="mesh-hostplan") as sp_plan:
            plans = []
            for si, shard in enumerate(shards):
                if not mask[:, si].any():  # host loop skips unrouted shards:
                    plans.append(None)     # don't plan what won't execute
                    continue
                p4r, _ = shard.index.featurize(qj)
                plans.append(plan(shard.index, p4r, variant=variant))
            if all(qp is None for qp in plans):
                return                  # nothing routed: accumulators stay PAD
            mp = max(int(qp.sel_part.shape[-1]) for qp in plans
                     if qp is not None)
            sp = np.full((pl.num_slots, qn, mp), -1, np.int32)
            lo = np.zeros((pl.num_slots, qn, mp), np.int32)
            hi = np.zeros((pl.num_slots, qn, mp), np.int32)
            for si, (shard, qp) in enumerate(zip(shards, plans)):
                if qp is None:
                    continue
                w = int(qp.sel_part.shape[-1])
                routed = mask[:, si]
                sp[si, :, :w] = np.where(routed[:, None],
                                         np.asarray(qp.sel_part), -1)
                lo[si, :, :w] = np.asarray(qp.sel_lo)
                hi[si, :, :w] = np.asarray(qp.sel_hi)
                pt = np.asarray(qp.partitions_touched(), np.int64)
                touched += np.where(routed, pt, 0)
                scanned += np.where(
                    routed,
                    np.asarray(candidates_scanned(qp, shard.index.store),
                               np.int64), 0)
                self.stats.observe_shard(shard.key, int(routed.sum()),
                                         int(pt[routed].sum()))
        stage["plan_ms"] += sp_plan.duration_ms
        with TRACER.span("fleet.refine", path="mesh-hostplan") as sp_ref:
            dist, gid = pl.dispatch(queries, sp, lo, hi, k,
                                    use_kernel=use_kernel)
        stage["refine_ms"] += sp_ref.duration_ms
        best_d[:], best_g[:] = dist, gid

    def _merge_delta_answer(self, delta: DeltaShard, queries: np.ndarray,
                            k: int, variant: str,
                            use_kernel: Optional[bool],
                            best_d: np.ndarray, best_g: np.ndarray,
                            touched: np.ndarray, scanned: np.ndarray):
        """Fold one delta's (frozen or active) answer into the accumulators
        in place; returns the updated (best_d, best_g)."""
        res = delta.query(queries, k, variant=variant, use_kernel=use_kernel)
        if res is None:
            return best_d, best_g
        dist, gid, dt, dsc = res
        gg = np.where(gid >= 0,
                      delta.global_ids[np.maximum(gid, 0)],
                      -1).astype(np.int32)
        md, mg = merge_topk(jnp.asarray(best_d), jnp.asarray(best_g),
                            jnp.asarray(dist), jnp.asarray(gg), k)
        touched += dt
        scanned += dsc
        self.stats.observe_shard(self.DELTA_KEY, len(queries), int(dt.sum()))
        return np.asarray(md), np.asarray(mg)

    def query(self, queries: np.ndarray, k: int = 0, *,
              routing: str = "signature", variant: str = "adaptive",
              use_kernel: Optional[bool] = None,
              fanout: Optional[int] = None,
              threshold: Optional[float] = None,
              placement: Optional[str] = None
              ) -> Tuple[np.ndarray, np.ndarray, FleetQueryInfo]:
        """Fan out, per-shard kNN, fuse with ``merge_topk``.

        Args:
          queries: ``[Q, n]`` raw query series.
          k: answer size (0 ⇒ ``shard_cfg.k``).
          routing: ``"signature"`` routes each query to the ``fanout``
            best-scoring sealed shards; ``"adaptive"`` sizes the fan-out
            per query by score mass (``SignatureRouter.route_adaptive`` —
            ``threshold`` arg, else the router's learned threshold, else
            ``cfg.routing_threshold``; ``fanout`` then acts as a per-query
            cap); ``"exhaustive"`` executes every shard (lossless
            fan-out).  The delta is always executed.
          threshold: adaptive-routing score-mass cut for this call
            (ignored by the other routing modes).  ``>= 1.0`` is
            bit-identical to exhaustive routing; ``<= 0.0`` degrades to
            top-1.
          variant: per-shard planner variant; ``"exhaustive"`` makes each
            shard exact, so exhaustive routing + exhaustive variant equals
            brute-force over the fleet contents.
          use_kernel: per-shard refine implementation (True = streaming
            fused Pallas kernel, False = dense oracle, None = backend
            default — fused on accelerators, dense on CPU).
          placement: where the sealed shards execute — ``"host"`` (the
            sequential per-shard oracle loop), ``"mesh"`` (one shard_map
            over the device-resident stacked stores; needs an attached
            mesh), or None for the default: ``"mesh"`` when a mesh is
            attached, else ``"host"``.  Both placements return bit-
            identical results; the delta is always executed host-side.

        During a background compaction the frozen delta keeps serving
        (merged between the sealed shards and the live delta), so answers
        over unchanged contents are identical before, during, and after
        the seal.

        Returns:
          (dist ``[Q, k]`` ascending ED, gid ``[Q, k]`` fleet-global ids,
          info).  Rows with fewer than k candidates across the routed
          shards carry the :data:`repro.core.PAD_DIST` sentinel and
          ``gid = -1``.
        """
        if routing not in ("signature", "adaptive", "exhaustive"):
            raise ValueError(f"unknown routing mode {routing!r}")
        placement = self._resolve_placement(placement)
        queries = np.asarray(queries, dtype=np.float32)
        if queries.ndim != 2:
            raise ValueError(f"queries must be [Q, n], got {queries.shape}")
        k = k or self.cfg.shard_cfg.k
        qn = len(queries)
        best_d = np.full((qn, k), PAD_DIST, np.float32)
        best_g = np.full((qn, k), -1, np.int32)
        touched = np.zeros(qn, np.int64)
        scanned = np.zeros(qn, np.int64)
        stage = {"plan_ms": 0.0, "refine_ms": 0.0, "merge_ms": 0.0}

        with TRACER.span("fleet.query", placement=placement,
                         queries=qn) as sp_root:
            # consistent view: shard list + both deltas are captured under
            # the lock; the (slow) sealed-shard execution then runs
            # off-lock.  The captured delta object stays correct even if a
            # freeze/seal re-points ``self.delta`` meanwhile — freezing
            # never mutates it.
            with self._lock:
                shards = list(self.shards)
                sealing = self._sealing
                delta = self.delta
                s = len(shards)
                pl = self._ensure_placement() \
                    if placement == "mesh" and s else None
                epoch = self._placement_epoch
                cache = self._plan_cache
                h0, m0 = cache.hits, cache.misses
                lifecycle = self.stats.lifecycle_snapshot()
                # mask under the same lock: the router registry is only
                # ever resized (seal/merge/retire) while it is held, so the
                # mask width always matches the captured shard list
                if routing == "exhaustive" or self.router is None or s == 0:
                    mask = np.ones((qn, s), dtype=bool)
                elif routing == "adaptive":
                    th = threshold
                    if th is None:
                        th = self.router.threshold
                    if th is None:
                        th = self.cfg.routing_threshold
                    mask = self.router.route_adaptive(
                        queries, float(th), max_fanout=fanout)
                else:
                    mask = self.router.route(queries,
                                             fanout or self.cfg.fanout)

            if s:
                if placement == "mesh":
                    self._query_sealed_mesh(shards, pl, queries, k, mask,
                                            variant, use_kernel, best_d,
                                            best_g, touched, scanned,
                                            stage, epoch)
                else:
                    self._query_sealed_host(shards, queries, k, mask,
                                            variant, use_kernel, best_d,
                                            best_g, touched, scanned,
                                            stage, epoch)

            with TRACER.span("fleet.merge", shard=self.DELTA_KEY) as sp_mrg:
                if sealing is not None:   # frozen mid-compaction: immutable
                    best_d, best_g = self._merge_delta_answer(
                        sealing, queries, k, variant, use_kernel,
                        best_d, best_g, touched, scanned)
                with self._lock:          # live delta: serialize vs inserts
                    best_d, best_g = self._merge_delta_answer(
                        delta, queries, k, variant, use_kernel,
                        best_d, best_g, touched, scanned)
                    self.stats.queries += qn
                    self.stats.routed_pairs += int(mask.sum())
                    self.stats.exhaustive_pairs += qn * s
            stage["merge_ms"] += sp_mrg.duration_ms
        self.query_hist.observe(sp_root.duration_ms)
        for t in touched:
            self.touched_hist.observe(float(t))
        if self.sentinel is not None:
            # shadow-sampling copies (query, answer) pairs aside for the
            # off-path exhaustive audit; it never mutates the arrays it is
            # handed, so served answers are bit-identical with sampling
            # on or off (tests/test_sentinel.py holds this line to it)
            self.sentinel.observe(queries, k, best_d, best_g)
        return best_d, best_g, FleetQueryInfo(
            partitions_touched=touched, candidates_scanned=scanned,
            routed_mask=mask, lifecycle=lifecycle, stage_ms=stage,
            plan_cache_hits=cache.hits - h0,
            plan_cache_misses=cache.misses - m0)

    def scan_exact(self, queries: np.ndarray, k: int = 0, *,
                   use_kernel: Optional[bool] = None, mesh=None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Lossless fallback as a *single* refine over the fused store.

        Concatenates every shard store (global-id remapped) and runs one
        exhaustive ``dispatch_refine`` — the fleet answer without any
        per-shard scatter/gather, equal to exhaustive-routing +
        exhaustive-variant :meth:`query`.

        ``mesh`` (default: the fleet's attached mesh, if any) executes the
        union scan sharded over the mesh's data axis via
        ``refine_sharded`` — here the *partition* axis of the union store
        is what shards over the devices, not the shard axis.

        Returns ``(dist [Q, k], gid [Q, k])`` with the usual
        :data:`repro.core.PAD_DIST` / ``gid = -1`` pad sentinel.
        """
        queries = np.asarray(queries, dtype=np.float32)
        k = k or self.cfg.shard_cfg.k
        mesh = mesh if mesh is not None else self.mesh
        with self._lock:
            stores = [s.index.store for s in self.shards]
            gid_maps = [s.global_ids for s in self.shards]
            for delta in (self._sealing, self.delta):
                if delta is None:
                    continue
                dstore = delta.store()
                if dstore is not None:
                    stores.append(dstore)
                    gid_maps.append(delta.global_ids)
        if not stores:
            return (np.full((len(queries), k), PAD_DIST, np.float32),
                    np.full((len(queries), k), -1, np.int32))
        union = concat_stores(stores, gid_maps)
        sel, lo, hi = exhaustive_selection(union.num_partitions,
                                           len(queries))
        dist, gid = dispatch_refine(union, jnp.asarray(queries), sel, lo, hi,
                                    k, mesh=mesh, data_axis=self.data_axis,
                                    use_kernel=use_kernel)
        return np.asarray(dist), np.asarray(gid)

    MAX_ROUTING_TRACES = 4096       # bound on recorded audit traces

    def audit_routing(self, queries: np.ndarray, k: int = 0, *,
                      variant: str = "adaptive",
                      record: bool = False) -> float:
        """Measure routed-mode precision against the exhaustive oracle.

        Returns the mean fraction of the exhaustive fan-out's answers the
        routed fan-out also returned, and folds it into
        ``stats.routing_precision``.

        ``record=True`` additionally appends one ``(scores, true_hits)``
        trace per query to ``self.routing_traces`` — the router's ``[S]``
        shard scores and the count of the exhaustive answer's gids living
        in each sealed shard.  :meth:`calibrate_routing` learns the
        adaptive-routing threshold from these.
        """
        k = k or self.cfg.shard_cfg.k
        _, g_routed, _ = self.query(queries, k, routing="signature",
                                    variant=variant)
        _, g_full, _ = self.query(queries, k, routing="exhaustive",
                                  variant=variant)
        overlaps = []
        for gr, gf in zip(g_routed, g_full):
            truth = set(int(x) for x in gf if x >= 0)
            if not truth:
                continue
            got = set(int(x) for x in gr if x >= 0)
            overlaps.append(len(got & truth) / len(truth))
        precision = float(np.mean(overlaps)) if overlaps else 1.0
        self.stats.routing_audits += 1
        self.stats.routing_overlap += precision
        if record and self.router is not None and self.router.num_shards:
            with self._lock:
                gid_sets = [s.global_ids for s in self.shards]
            scores = self.router.score(queries)            # [Q, S]
            for i, gf in enumerate(g_full):
                valid = gf[gf >= 0]
                hits = np.array([int(np.isin(valid, g).sum())
                                 for g in gid_sets], np.int64)
                self.routing_traces.append((scores[i].copy(), hits))
            del self.routing_traces[:-self.MAX_ROUTING_TRACES]
        return precision

    def calibrate_routing(self, target_recall: float = 0.95) -> float:
        """Learn the adaptive-routing threshold from recorded audit traces
        (``audit_routing(..., record=True)``) and install it on the router.

        Returns the learned threshold (also left on ``router.threshold``,
        where ``routing="adaptive"`` picks it up by default).  Raises if
        there is no router or no trace has been recorded.
        """
        if self.router is None:
            raise RuntimeError("fleet has no router to calibrate")
        if not self.routing_traces:
            raise RuntimeError("no routing traces recorded — call "
                               "audit_routing(..., record=True) first")
        return self.router.learn_threshold(self.routing_traces,
                                           target_recall=target_recall)


def _recover_wal_rebase(storage_dir: Path) -> None:
    """Finish a WAL rebase interrupted by a crash (see
    :meth:`IndexFleet._attach_storage_rebased`): ``wal.rebase`` is only
    renamed into place after it is fully written, so whichever directory
    survives is complete."""
    import shutil
    wal_dir = storage_dir / "wal"
    rebase = storage_dir / "wal.rebase"
    old = storage_dir / "wal.old"
    if not wal_dir.exists() and rebase.exists():
        rebase.rename(wal_dir)          # crash between the two renames
    for leftover in (rebase, old):
        if leftover.exists():
            shutil.rmtree(leftover)
