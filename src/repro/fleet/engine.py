"""FleetEngine — one serving engine over a whole IndexFleet.

The same fixed-shape batched admission as :class:`repro.serve.ClimberEngine`
(identical queue / tick / metrics machinery via
:class:`repro.serve.BatchedServingLoop`), but a tick executes
``IndexFleet.query``: route → per-shard kNN → ``merge_topk`` fusion, so one
engine serves every tenant's shard plus the streaming delta.  Per-query
metrics aggregate over every shard a query touched.

The engine also drives the fleet's lifecycle plane: every
``maintenance_every`` queue ticks it runs :meth:`maintenance` between
batches — triggering a background compaction when the delta is at capacity
and applying the LSM merge/retirement policy
(:class:`repro.fleet.lifecycle.merge.MergePolicy`) — so index upkeep rides
the serving loop without ever blocking a query on an INX rebuild.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.refine import PAD_DIST, resolve_use_kernel
from repro.fleet.fleet import IndexFleet
from repro.obs import TRACER
from repro.serve import api
from repro.serve.knn_engine import BatchedServingLoop


class FleetEngine(BatchedServingLoop):
    """Batched request serving across all shards of a fleet.

    Args:
      fleet: the IndexFleet to serve (may keep ingesting between ticks —
        the fleet query path always sees the current shard set + delta).
      routing: ``"signature"`` (top-``fanout`` router fan-out),
        ``"adaptive"`` (per-query score-mass fan-out), or
        ``"exhaustive"``.
      variant: per-shard planner variant.
      mesh: attach a device mesh to the fleet (shorthand for
        ``fleet.attach_mesh``) so sealed shards execute mesh-resident.
      placement: per-tick sealed-shard execution — ``"host"`` (sequential
        oracle loop), ``"mesh"`` (one shard_map over the stacked stores),
        or None for the fleet default (mesh when one is attached).
      maintenance_every: run :meth:`maintenance` after every Nth queue
        tick (0 = only when called explicitly).
      merge_policy: the :class:`~repro.fleet.lifecycle.merge.MergePolicy`
        maintenance applies (None = the fleet's / the policy defaults).

    All of the above may instead arrive bundled in one
    :class:`repro.serve.api.ServingConfig` via ``config=`` (exclusive
    with the individual kwargs) — the same object ``ClimberEngine`` and
    the network server consume; ``mesh`` / ``data_axis`` stay separate
    runtime resources.
    """

    _CONFIG_KEYS = ("batch_size", "k", "routing", "variant", "use_kernel",
                    "fanout", "placement", "maintenance_every",
                    "merge_policy", "trace_ring", "sentinel_rate",
                    "sentinel_recalibrate_every")

    def __init__(self, fleet: IndexFleet, *,
                 config: Optional[api.ServingConfig] = None,
                 mesh=None, data_axis: str = "data", **kwargs):
        scfg = api.resolve_config(config, kwargs, self._CONFIG_KEYS)
        self.config = scfg
        if scfg.routing not in ("signature", "adaptive", "exhaustive"):
            raise ValueError(f"unknown routing mode {scfg.routing!r}")
        if mesh is not None:
            fleet.attach_mesh(mesh, data_axis=data_axis)
        fleet._resolve_placement(scfg.placement)  # fail fast when bad
        if scfg.trace_ring:
            TRACER.set_capacity(scfg.trace_ring)
        cfg = fleet.cfg.shard_cfg
        super().__init__(series_len=cfg.series_len,
                         batch_size=scfg.batch_size, k=scfg.k or cfg.k)
        self.fleet = fleet
        self.routing = scfg.routing
        self.variant = scfg.variant
        self.use_kernel = resolve_use_kernel(scfg.use_kernel)
        self.fanout = scfg.fanout
        self.placement = scfg.placement
        self.maintenance_every = scfg.maintenance_every
        self.merge_policy = scfg.merge_policy
        self.last_maintenance: dict = {"retired": [], "merged": []}
        # online recall sentinel: shadow-samples served queries and audits
        # them exhaustively on the _after_tick hook — off the latency path
        self.sentinel = None
        if scfg.sentinel_rate > 0.0:
            from repro.obs.sentinel import RecallSentinel
            self.sentinel = RecallSentinel(
                fleet, sample_rate=scfg.sentinel_rate,
                recalibrate_every=scfg.sentinel_recalibrate_every)

    def tenant_load(self, tenant: str) -> float:
        """The tenant's share of the fleet's per-shard query load —
        ``FleetStats.per_shard_queries[tenant]`` over the total — the
        signal the net server's hot-tenant quota guard rides on.
        Unknown tenants (or an unqueried fleet) report 0.0."""
        loads = self.fleet.stats.per_shard_queries
        total = sum(loads.values())
        return loads.get(tenant, 0) / total if total else 0.0

    def reset_metrics(self) -> None:
        """Zero both the loop's and the underlying fleet's metrics."""
        super().reset_metrics()
        self.fleet.reset_metrics()

    def _execute(self, qbatch: np.ndarray, nlive: int):
        """One tick: fleet-query the live rows, pad results back out.

        Unlike the single-index engine the fleet path is host-orchestrated,
        so the zero-padded tail rows are simply not executed.
        """
        t0 = time.perf_counter()
        dist, gid, info = self.fleet.query(
            qbatch[:nlive], k=self.k, routing=self.routing,
            variant=self.variant, use_kernel=self.use_kernel,
            fanout=self.fanout, placement=self.placement)
        dt = time.perf_counter() - t0
        # surface the fleet's plan-cache traffic (host and mesh placement)
        # through the same EngineStats counters the single-index engine uses
        self.stats.plan_cache_hits += info.plan_cache_hits
        self.stats.plan_cache_misses += info.plan_cache_misses
        bs = self.batch_size
        d = np.full((bs, self.k), PAD_DIST, np.float32)
        g = np.full((bs, self.k), -1, np.int32)
        touched = np.zeros(bs, np.int64)
        scanned = np.zeros(bs, np.int64)
        d[:nlive], g[:nlive] = dist, gid
        touched[:nlive] = info.partitions_touched
        scanned[:nlive] = info.candidates_scanned
        return d, g, touched, scanned, dt

    # -- lifecycle upkeep -------------------------------------------------
    def maintenance(self) -> dict:
        """One lifecycle tick, between serving batches.

        Kicks a *background* compaction when the delta is at capacity
        (non-blocking: the INX rebuild runs on the compactor thread while
        subsequent ticks keep serving the frozen delta), then applies the
        merge/retirement policy.  Returns the maintenance report.
        """
        fleet = self.fleet
        with TRACER.span("fleet.maintenance"):
            if fleet.cfg.auto_compact and \
                    fleet.delta.occupancy >= max(fleet.cfg.delta_capacity,
                                                 fleet.delta.min_build):
                fleet.compact_async()
            self.last_maintenance = \
                fleet.maintenance(policy=self.merge_policy)
        return self.last_maintenance

    def _after_tick(self) -> None:
        if self.maintenance_every and \
                self.stats.ticks % self.maintenance_every == 0:
            self.maintenance()
        if self.sentinel is not None:
            # audit a couple of shadow samples between batches; queries
            # land faster than audits drain, so the sentinel's bounded
            # pending deque (not the serve path) absorbs the difference
            self.sentinel.drain(max_audits=2)
