"""Mesh-resident fleet placement — one shard_map instead of S dispatches.

The host-loop fleet query (``IndexFleet.query(placement="host")``) executes
the sealed shards sequentially: S separate ``knn_query`` dispatches, each a
featurize → plan → refine round-trip, fused on the host with ``merge_topk``.
That is the lossless oracle, but it serializes S device round-trips per
query batch — exactly the per-node scan overlap the distributed-series
literature (Odyssey) says a fleet must not give up.

:class:`MeshFleetPlacement` keeps the sealed shards *device-resident*
instead:

  * every sealed shard's :class:`~repro.core.index.PartitionStore` is
    stacked on a new leading shard axis (ragged partition counts / slot
    capacities padded with inert ``rec_gid = -1`` slots, local record ids
    remapped to fleet-global ids at stack time) via
    :func:`repro.distributed.store.stack_stores`;
  * every sealed shard's trie skeleton, pivot set and centroid table are
    stacked the same way (:func:`repro.fleet.device_plan.stack_tries` —
    ragged node/edge/group counts padded with inert entries that can never
    match a probe or contribute a partition);
  * the shard axis is padded to a multiple of the mesh's data-axis size
    (``pad_store`` / all-inert pad tries — a pad shard is a no-op under
    ``merge_topk``) and laid out with
    :func:`repro.distributed.store.store_pspecs`, so device d owns whole
    shards ``[d·per, (d+1)·per)``;
  * :meth:`query` then runs the WHOLE query — featurize → trie descent →
    plan → budgeted compaction → refine → merge — as ONE jitted shard_map:
    each device featurizes the (replicated) query batch against its
    resident shards' pivots, plans against their stacked skeletons via the
    registered device planner (``repro.core.query.get_device_planner``,
    with a :class:`~repro.core.query.ShardPlanContext` carrying the real
    vs padded counts), refines, and a single ``all_gather`` +
    in-shard-order ``merge_topk`` fold produces the global ``[Q, k]``
    answer.  No host round-trip between planning and refine.

Routing is expressed *in the plan* — a query not routed to a shard gets
that shard's plan row masked to ``-1``, which the refine stage turns into
``PAD_DIST``/``gid = -1`` answers that lose every merge.  Because the
device planner reproduces the host planner's live plan entries in the same
order (ShardPlanContext masking + the shared ``compact_plan``), and the
fold merges shards in the same order the host loop does (shard 0, 1, …,
with the delta merged afterwards on the host), the mesh answer is
bit-identical to the host loop.

:meth:`dispatch` (refine-only, host-stacked plans) remains for plans
computed elsewhere — the fleet's plan-cache hit path and planner variants
without a registered device twin.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import signatures as sig_mod
from repro.core.index import PartitionStore
from repro.core.paa import paa as _paa
from repro.core.query import (QueryPlan, ShardPlanContext, candidates_scanned,
                              compact_plan, default_slot_budget,
                              get_device_planner, get_planner)
from repro.core.refine import (PAD_DIST, merge_topk, refine,
                               resolve_use_kernel)
from repro.distributed.store import pad_store, stack_stores, store_pspecs
from repro.fleet.device_plan import ShardView, stack_tries, trie_row


class MeshFleetPlacement:
    """Sealed shard stores + skeletons laid out over the mesh, plus the jits.

    Built from the fleet's current sealed shard list; the fleet invalidates
    and rebuilds it whenever that list changes (``add_shard`` /
    ``compact``).  The stacked store and trie tables are device-resident
    *copies* of the shard state — the host copies inside each
    ``ClimberIndex`` stay authoritative for planning oracles and rebuilds.

    Args:
      mesh: a jax Mesh with a ``data_axis`` dimension.
      shards: the fleet's ``ShardHandle`` list (order defines merge order).
      data_axis: mesh axis name the shard axis is laid out over.
    """

    def __init__(self, mesh, shards, *, data_axis: str = "data"):
        if not shards:
            raise ValueError("mesh placement needs at least one sealed shard")
        self.mesh = mesh
        self.data_axis = data_axis
        self.num_shards = len(shards)
        n_dev = mesh.shape[data_axis]
        stacked = stack_stores([s.index.store for s in shards],
                               [s.global_ids for s in shards])
        stacked = pad_store(stacked, n_dev)       # ragged S % n_dev
        self.num_slots = int(stacked.data.shape[0])   # S_pad
        specs = store_pspecs(data_axis)
        shard_put = lambda x: jax.device_put(
            x, NamedSharding(mesh, PS(data_axis)))
        self.store = PartitionStore(*[
            jax.device_put(x, NamedSharding(mesh, s))
            for x, s in zip(stacked, specs)])

        # ---- device-resident planning inputs (uniform-cfg fleets) -------
        self._indexes = [s.index for s in shards]
        self.cfg = self._indexes[0].cfg
        self._device_plan_ready = all(ix.cfg == self.cfg
                                      for ix in self._indexes)
        if self._device_plan_ready:
            s_pad, pad_n = self.num_slots, self.num_slots - self.num_shards
            tables = stack_tries([ix.trie for ix in self._indexes],
                                 pad_to=s_pad)
            self.tables = jax.tree_util.tree_map(shard_put, tables)
            r, w = self.cfg.num_pivots, self.cfg.paa_segments
            piv = np.zeros((s_pad, r, w), np.float32)
            gmax = int(tables.group_root.shape[-1])
            cent = np.zeros((s_pad, gmax, r), np.float32)
            for j, ix in enumerate(self._indexes):
                piv[j] = np.asarray(ix.pivots)
                c = np.asarray(ix.centroid_onehot)
                cent[j, : c.shape[0]] = c
            g_real = np.array([ix.num_groups for ix in self._indexes]
                              + [1] * pad_n, np.int32)
            t_real = np.maximum(
                np.minimum(self.cfg.candidate_groups, g_real - 1), 1)
            self.pivots = shard_put(jnp.asarray(piv))
            self.centroids = shard_put(jnp.asarray(cent))
            self.t_real = shard_put(jnp.asarray(t_real.astype(np.int32)))
            # static widths of the fused pass
            self._t_static = min(self.cfg.candidate_groups, gmax - 1) or 1
            self._p_static = int(self.store.data.shape[1])
        # (k, use_kernel) -> jitted refine-only shard_map; jit re-traces per
        # Q/MP shape on its own
        self._dispatch: Dict[Tuple, object] = {}
        # (variant, k, use_kernel, B) -> jitted fused featurize→plan→refine
        self._query: Dict[Tuple, object] = {}
        self._plan_widths: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # device-resident planning (the fused pass)
    # ------------------------------------------------------------------
    def supports_device_planning(self, variant: str) -> bool:
        """True when ``variant`` has a registered device planner and the
        fleet's shard configs are uniform (stacked featurize needs one
        pivot-count/segment geometry)."""
        return self._device_plan_ready \
            and get_device_planner(variant) is not None

    def plan_width(self, variant: str) -> int:
        """B — the fused pass's static plan width for ``variant``.

        The max over shards of the width the HOST planner would produce
        after budget resolution (``plan()``'s logic: explicit
        ``cfg.query_max_slots``, else the lossless
        :func:`~repro.core.query.default_slot_budget`) — so a device plan
        row compacted to B holds exactly the host plan's live entries (and
        drops the same ones when the budget is deliberately lossy).
        Shapes come from ``jax.eval_shape`` — no planning is executed.
        """
        b = self._plan_widths.get(variant)
        if b is None:
            widths = []
            for ix in self._indexes:
                spec = jax.ShapeDtypeStruct((1, ix.cfg.prefix_len), jnp.int32)
                shape = jax.eval_shape(
                    lambda p4, ix=ix: get_planner(variant)(ix, p4), spec)
                raw = int(shape.sel_part.shape[-1])
                budget = ix.cfg.query_max_slots
                if budget is None:
                    budget = default_slot_budget(ix, variant)
                widths.append(raw if budget is None else min(budget, raw))
            b = self._plan_widths[variant] = max(widths)
        return b

    def _build_query(self, variant: str, k: int, use_kernel: bool, b: int):
        """Compile the fused featurize→descend→plan→refine→merge pass."""
        from jax.experimental.shard_map import shard_map

        axis = self.data_axis
        n_dev = self.mesh.shape[axis]
        per = self.num_slots // n_dev
        s_pad = self.num_slots
        cfg = self.cfg
        planner = get_device_planner(variant)
        t_static, p_static = self._t_static, self._p_static
        m, r, w = cfg.prefix_len, cfg.num_pivots, cfg.paa_segments

        def local_fn(data, norms, rdfs, rgid, count, tab, piv, cent,
                     t_real, q, routed):
            # data…count: [per, ...] this device's resident shards;
            # tab/piv/cent/t_real: their stacked skeletons + planner inputs;
            # routed: [per, Q] fan-out mask.  Queries are replicated.
            # named_scope markers label the fused stages on captured
            # profiler traces (see repro.obs.profile)
            with jax.named_scope("climber.featurize"):
                z = _paa(q, w)                     # shard-independent
            d_l, g_l, sp_l, lo_l, hi_l, pt_l, sc_l = ([] for _ in range(7))
            for j in range(per):                   # static unroll
                st = PartitionStore(data=data[j], norms=norms[j],
                                    rec_dfs=rdfs[j], rec_gid=rgid[j],
                                    count=count[j])
                with jax.named_scope("climber.plan"):
                    p4r = sig_mod.rank_signature(z, piv[j], m)
                    trie = trie_row(tab, j, num_pivots=r,
                                    num_partitions=p_static)
                    view = ShardView(cfg, cent[j], trie)
                    ctx = ShardPlanContext(
                        num_groups=tab.num_groups[j],
                        num_candidates=t_real[j],
                        num_partitions=tab.num_partitions[j],
                        t_static=t_static, p_static=p_static)
                    qp = planner(view, p4r, ctx)
                    if qp.sel_part.shape[-1] > b:  # live-first, host's drops
                        qp = compact_plan(qp, b)
                    sp, lo, hi = qp.sel_part, qp.sel_lo, qp.sel_hi
                    if sp.shape[-1] < b:
                        pad2 = ((0, 0), (0, b - sp.shape[-1]))
                        sp = jnp.pad(sp, pad2, constant_values=-1)
                        lo, hi = jnp.pad(lo, pad2), jnp.pad(hi, pad2)
                    qp_b = QueryPlan(sel_part=sp, sel_lo=lo, sel_hi=hi,
                                     node=qp.node, pathlen=qp.pathlen)
                    # metrics from the unmasked plan — the host loop
                    # computes them per shard before the routing mask
                    pt_l.append(qp_b.partitions_touched())
                    sc_l.append(candidates_scanned(qp_b, st))
                with jax.named_scope("climber.refine"):
                    spm = jnp.where(routed[j][:, None], sp, -1)
                    d, g = refine(st, q, spm, lo, hi, k,
                                  use_kernel=use_kernel)
                d_l.append(d)
                g_l.append(g)
                sp_l.append(sp)
                lo_l.append(lo)
                hi_l.append(hi)
            with jax.named_scope("climber.merge"):
                d_loc, g_loc = jnp.stack(d_l), jnp.stack(g_l)  # [per, Q, k]
                # one collective: every device sees every shard's top-k
                d_all = jax.lax.all_gather(d_loc, axis, axis=0)
                g_all = jax.lax.all_gather(g_loc, axis, axis=0)
                d_all = d_all.reshape(s_pad, *d_loc.shape[1:])  # shard order
                g_all = g_all.reshape(s_pad, *g_loc.shape[1:])
                # fold in global shard order — the host loop's merge order,
                # so results (incl. tie-breaks) are bit-identical
                best_d = jnp.full(d_loc.shape[1:], PAD_DIST, jnp.float32)
                best_g = jnp.full(g_loc.shape[1:], -1, jnp.int32)
                for s in range(s_pad):
                    best_d, best_g = merge_topk(best_d, best_g,
                                                d_all[s], g_all[s], k)
            return (best_d, best_g, jnp.stack(sp_l), jnp.stack(lo_l),
                    jnp.stack(hi_l), jnp.stack(pt_l), jnp.stack(sc_l))

        fn = shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(PS(axis), PS(axis), PS(axis), PS(axis), PS(axis),
                      PS(axis), PS(axis), PS(axis), PS(axis),
                      PS(), PS(axis)),
            out_specs=(PS(), PS(), PS(axis), PS(axis), PS(axis),
                       PS(axis), PS(axis)),
            check_rep=False)
        return jax.jit(fn)

    def query(self, queries: np.ndarray, routed: np.ndarray, k: int, *,
              variant: str = "adaptive", use_kernel: Optional[bool] = None):
        """ONE device program: featurize → plan → refine → merge, fused.

        Args:
          queries: ``[Q, n]`` raw query series (replicated to every device).
          routed: ``[S_pad, Q]`` bool fan-out mask (pad-shard rows False);
            an unrouted (query, shard) pair gets its plan row masked to
            ``-1`` before refine, exactly like the host-stacked path.
          k: answer size.
          variant: a planner with a registered device twin
            (:meth:`supports_device_planning`).
          use_kernel: per-device refine implementation (None = backend
            default — fused kernel on accelerators, dense oracle on CPU).

        Returns:
          ``(dist [Q, k], gid [Q, k], sel_part, sel_lo, sel_hi
          [S_pad, Q, B], touched [S_pad, Q], scanned [S_pad, Q])`` numpy
          arrays — the answer plus the UNMASKED per-shard plans and plan
          metrics, which the fleet feeds its epoch-keyed plan cache (a
          later hit replays them through :meth:`dispatch` with a fresh
          routing mask).
        """
        if not self.supports_device_planning(variant):
            raise ValueError(
                f"variant {variant!r} has no device planner "
                "(or shard configs are not uniform); use host planning")
        use_kernel = resolve_use_kernel(use_kernel)
        b = self.plan_width(variant)
        key = (variant, k, use_kernel, b)
        fn = self._query.get(key)
        if fn is None:
            fn = self._query[key] = self._build_query(variant, k,
                                                      use_kernel, b)
        st = self.store
        with jax.profiler.TraceAnnotation("fleet.mesh.query"):
            outs = fn(st.data, st.norms, st.rec_dfs, st.rec_gid, st.count,
                      self.tables, self.pivots, self.centroids, self.t_real,
                      jnp.asarray(queries, jnp.float32),
                      jnp.asarray(routed, bool))
            return tuple(np.asarray(o) for o in outs)

    # ------------------------------------------------------------------
    # refine-only fan-out (host-computed / cache-replayed plans)
    # ------------------------------------------------------------------
    def _build_dispatch(self, k: int, use_kernel: bool):
        """Compile the single-collective fan-out for one (shapes, k) combo."""
        from jax.experimental.shard_map import shard_map

        axis = self.data_axis
        n_dev = self.mesh.shape[axis]
        per = self.num_slots // n_dev
        s_pad = self.num_slots

        def local_fn(data, norms, rdfs, rgid, count, q, sp, lo, hi):
            # data: [per, P, cap, n] — this device's resident shards;
            # sp/lo/hi: [per, Q, MP] — their (routing-masked) plans.
            local_d, local_g = [], []
            with jax.named_scope("climber.refine"):
                for j in range(per):                 # static unroll
                    st = PartitionStore(data=data[j], norms=norms[j],
                                        rec_dfs=rdfs[j], rec_gid=rgid[j],
                                        count=count[j])
                    d, g = refine(st, q, sp[j], lo[j], hi[j], k,
                                  use_kernel=use_kernel)
                    local_d.append(d)
                    local_g.append(g)
            with jax.named_scope("climber.merge"):
                d_loc = jnp.stack(local_d)           # [per, Q, k]
                g_loc = jnp.stack(local_g)
                # one collective: every device sees every shard's top-k
                d_all = jax.lax.all_gather(d_loc, axis, axis=0)
                g_all = jax.lax.all_gather(g_loc, axis, axis=0)
                d_all = d_all.reshape(s_pad, *d_loc.shape[1:])  # shard order
                g_all = g_all.reshape(s_pad, *g_loc.shape[1:])
                # fold in global shard order — the host loop's merge order,
                # so results (incl. tie-breaks) are bit-identical
                best_d = jnp.full(d_loc.shape[1:], PAD_DIST, jnp.float32)
                best_g = jnp.full(g_loc.shape[1:], -1, jnp.int32)
                for s in range(s_pad):
                    best_d, best_g = merge_topk(best_d, best_g,
                                                d_all[s], g_all[s], k)
            return best_d, best_g

        fn = shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(PS(axis), PS(axis), PS(axis), PS(axis), PS(axis),
                      PS(), PS(axis), PS(axis), PS(axis)),
            out_specs=(PS(), PS()),
            check_rep=False)
        return jax.jit(fn)

    def dispatch(self, queries: np.ndarray, sel_part: np.ndarray,
                 sel_lo: np.ndarray, sel_hi: np.ndarray, k: int,
                 use_kernel: Optional[bool] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Run the refine-only fan-out over host-provided stacked plans.

        Args:
          queries: ``[Q, n]`` raw query series (replicated to every device).
          sel_part / sel_lo / sel_hi: ``[S_pad, Q, MP]`` stacked per-shard
            plans; ``sel_part = -1`` marks pad slots *and* (whole rows of)
            queries not routed to that shard.
          k: answer size.
          use_kernel: per-device refine implementation (None = backend
            default — fused kernel on accelerators, dense oracle on CPU).

        Returns:
          (dist ``[Q, k]``, gid ``[Q, k]``): fused over every sealed shard,
          fleet-global ids, ``PAD_DIST``/``-1`` where fewer than k real
          candidates were routed.
        """
        use_kernel = resolve_use_kernel(use_kernel)
        key = (k, use_kernel)
        fn = self._dispatch.get(key)
        if fn is None:
            fn = self._dispatch[key] = self._build_dispatch(k, use_kernel)
        st = self.store
        with jax.profiler.TraceAnnotation("fleet.mesh.dispatch"):
            d, g = fn(st.data, st.norms, st.rec_dfs, st.rec_gid, st.count,
                      jnp.asarray(queries, jnp.float32),
                      jnp.asarray(sel_part, jnp.int32),
                      jnp.asarray(sel_lo, jnp.int32),
                      jnp.asarray(sel_hi, jnp.int32))
            return np.asarray(d), np.asarray(g)
