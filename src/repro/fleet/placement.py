"""Mesh-resident fleet placement — one shard_map instead of S dispatches.

The host-loop fleet query (``IndexFleet.query(placement="host")``) executes
the sealed shards sequentially: S separate ``knn_query`` dispatches, each a
featurize → plan → refine round-trip, fused on the host with ``merge_topk``.
That is the lossless oracle, but it serializes S device round-trips per
query batch — exactly the per-node scan overlap the distributed-series
literature (Odyssey) says a fleet must not give up.

:class:`MeshFleetPlacement` keeps the sealed shards *device-resident*
instead:

  * every sealed shard's :class:`~repro.core.index.PartitionStore` is
    stacked on a new leading shard axis (ragged partition counts / slot
    capacities padded with inert ``rec_gid = -1`` slots, local record ids
    remapped to fleet-global ids at stack time) via
    :func:`repro.distributed.store.stack_stores`;
  * the shard axis is padded to a multiple of the mesh's data-axis size
    (``pad_store`` — an all-pad shard is a no-op under ``merge_topk``) and
    laid out with :func:`repro.distributed.store.store_pspecs`, so device d
    owns whole shards ``[d·per, (d+1)·per)``;
  * one ``shard_map`` fans a query batch out: each device runs the refine
    stage (the streaming fused ``refine_topk`` kernel on accelerators, the
    dense jnp oracle on CPU) over each of its resident shards, then a
    single ``all_gather`` + in-shard-order ``merge_topk`` fold produces the
    global ``[Q, k]`` answer — one collective instead of S sequential
    dispatches.

Planning stays on the host: each shard has its own pivots/trie, so the
per-shard plans are computed (cheaply) against each shard skeleton and
stacked to ``[S_pad, Q, MP]``; routing is expressed *in the plan* — a query
not routed to a shard gets that shard's plan row masked to ``-1``, which
the refine stage turns into ``PAD_DIST``/``gid = -1`` answers that lose
every merge.  Because the fold merges shards in the same order the host
loop does (shard 0, 1, …, with the delta merged afterwards on the host),
the mesh answer is bit-identical to the host loop.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core.index import PartitionStore
from repro.core.refine import (PAD_DIST, merge_topk, refine,
                               resolve_use_kernel)
from repro.distributed.store import pad_store, stack_stores, store_pspecs


class MeshFleetPlacement:
    """Sealed shard stores laid out over the mesh, plus the fan-out jit.

    Built from the fleet's current sealed shard list; the fleet invalidates
    and rebuilds it whenever that list changes (``add_shard`` /
    ``compact``).  The stacked store is a device-resident *copy* of the
    shard stores — the host copies inside each ``ClimberIndex`` stay
    authoritative for planning and rebuilds.

    Args:
      mesh: a jax Mesh with a ``data_axis`` dimension.
      shards: the fleet's ``ShardHandle`` list (order defines merge order).
      data_axis: mesh axis name the shard axis is laid out over.
    """

    def __init__(self, mesh, shards, *, data_axis: str = "data"):
        if not shards:
            raise ValueError("mesh placement needs at least one sealed shard")
        self.mesh = mesh
        self.data_axis = data_axis
        self.num_shards = len(shards)
        n_dev = mesh.shape[data_axis]
        stacked = stack_stores([s.index.store for s in shards],
                               [s.global_ids for s in shards])
        stacked = pad_store(stacked, n_dev)       # ragged S % n_dev
        self.num_slots = int(stacked.data.shape[0])   # S_pad
        specs = store_pspecs(data_axis)
        self.store = PartitionStore(*[
            jax.device_put(x, NamedSharding(mesh, s))
            for x, s in zip(stacked, specs)])
        # (k, use_kernel) -> jitted shard_map dispatch (jit re-traces per
        # Q/MP shape on its own)
        self._dispatch: Dict[Tuple, object] = {}

    def _build_dispatch(self, k: int, use_kernel: bool):
        """Compile the single-collective fan-out for one (shapes, k) combo."""
        from jax.experimental.shard_map import shard_map

        axis = self.data_axis
        n_dev = self.mesh.shape[axis]
        per = self.num_slots // n_dev
        s_pad = self.num_slots

        def local_fn(data, norms, rdfs, rgid, count, q, sp, lo, hi):
            # data: [per, P, cap, n] — this device's resident shards;
            # sp/lo/hi: [per, Q, MP] — their (routing-masked) plans.
            local_d, local_g = [], []
            for j in range(per):                     # static unroll
                st = PartitionStore(data=data[j], norms=norms[j],
                                    rec_dfs=rdfs[j], rec_gid=rgid[j],
                                    count=count[j])
                d, g = refine(st, q, sp[j], lo[j], hi[j], k,
                              use_kernel=use_kernel)
                local_d.append(d)
                local_g.append(g)
            d_loc = jnp.stack(local_d)               # [per, Q, k]
            g_loc = jnp.stack(local_g)
            # one collective: every device sees every shard's local top-k
            d_all = jax.lax.all_gather(d_loc, axis, axis=0)  # [D, per, Q, k]
            g_all = jax.lax.all_gather(g_loc, axis, axis=0)
            d_all = d_all.reshape(s_pad, *d_loc.shape[1:])   # shard order
            g_all = g_all.reshape(s_pad, *g_loc.shape[1:])
            # fold in global shard order — the host loop's merge order, so
            # results (incl. tie-breaks) are bit-identical to the oracle
            best_d = jnp.full(d_loc.shape[1:], PAD_DIST, jnp.float32)
            best_g = jnp.full(g_loc.shape[1:], -1, jnp.int32)
            for s in range(s_pad):
                best_d, best_g = merge_topk(best_d, best_g,
                                            d_all[s], g_all[s], k)
            return best_d, best_g

        fn = shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(PS(axis), PS(axis), PS(axis), PS(axis), PS(axis),
                      PS(), PS(axis), PS(axis), PS(axis)),
            out_specs=(PS(), PS()),
            check_rep=False)
        return jax.jit(fn)

    def dispatch(self, queries: np.ndarray, sel_part: np.ndarray,
                 sel_lo: np.ndarray, sel_hi: np.ndarray, k: int,
                 use_kernel: Optional[bool] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Run the fan-out: one shard_map over every sealed shard at once.

        Args:
          queries: ``[Q, n]`` raw query series (replicated to every device).
          sel_part / sel_lo / sel_hi: ``[S_pad, Q, MP]`` stacked per-shard
            plans; ``sel_part = -1`` marks pad slots *and* (whole rows of)
            queries not routed to that shard.
          k: answer size.
          use_kernel: per-device refine implementation (None = backend
            default — fused kernel on accelerators, dense oracle on CPU).

        Returns:
          (dist ``[Q, k]``, gid ``[Q, k]``): fused over every sealed shard,
          fleet-global ids, ``PAD_DIST``/``-1`` where fewer than k real
          candidates were routed.
        """
        use_kernel = resolve_use_kernel(use_kernel)
        key = (k, use_kernel)
        fn = self._dispatch.get(key)
        if fn is None:
            fn = self._dispatch[key] = self._build_dispatch(k, use_kernel)
        st = self.store
        d, g = fn(st.data, st.norms, st.rec_dfs, st.rec_gid, st.count,
                  jnp.asarray(queries, jnp.float32),
                  jnp.asarray(sel_part, jnp.int32),
                  jnp.asarray(sel_lo, jnp.int32),
                  jnp.asarray(sel_hi, jnp.int32))
        return np.asarray(d), np.asarray(g)
