"""Stacked trie skeletons — device-resident planning inputs for the fleet.

The mesh placement's fused query pass (``MeshFleetPlacement.query``) runs
featurize → descend → plan → refine as ONE device program, which means every
sealed shard's :class:`~repro.core.traversal.TrieDevice` skeleton must live
on the mesh next to its partition store.  Shards are ragged (different node
/ edge / group / partition counts), so the skeletons are padded to
fleet-wide maxima with *inert* entries (:func:`repro.core.traversal.pad_trie`
— int32-max edge keys that no probe can match, an inert node with an empty
DFS interval and no partitions, pad groups rooted at it) and stacked on a
new leading shard axis — the exact trie analogue of
:func:`repro.distributed.store.stack_stores`:

  * :func:`stack_tries`    — ``[TrieDevice] → TrieTables [S, ...]`` (+ pad
    shards up to a mesh-divisible slot count, mirroring ``pad_store``);
  * :func:`trie_row`       — reconstruct one shard's ``TrieDevice`` view
    from the stacked tables *inside* a traced program (the NamedTuple's
    static int fields cannot ride through vmap/shard_map, so the view is
    rebuilt per shard at trace time);
  * :func:`descend_stacked` — batched descent over the shard axis, the
    property-test surface for host↔stacked parity;
  * :class:`ShardView`     — the duck-typed ``ClimberIndex`` stand-in the
    registered device planners (``repro.core.query``) plan against.

Padding can never change a plan: pad edges never match, pad groups descend
to the inert node (size 0, no partitions), pad shards plan only ``-1``
entries — all of which the refine stage already treats as absent.  The
per-shard *real* counts ride alongside as ``[S]`` arrays and become the
traced :class:`~repro.core.query.ShardPlanContext` scalars.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.traversal import TrieDevice, descend, pad_trie


class TrieTables(NamedTuple):
    """Stacked ``[S, ...]`` trie skeletons (an all-array pytree).

    Field-for-field the arrays of :class:`TrieDevice` with a new leading
    shard axis, plus the per-shard real counts.  Every leaf is an array, so
    a TrieTables can be passed straight through jit/shard_map/vmap with a
    leading-axis PartitionSpec — the static ints of TrieDevice
    (``num_pivots``/``num_partitions``) are re-attached by :func:`trie_row`.
    """

    edge_key: jnp.ndarray            # [S, E] int32, pad = int32 max
    edge_child: jnp.ndarray          # [S, E] int32
    has_children: jnp.ndarray        # [S, N] bool
    node_size: jnp.ndarray           # [S, N] float32
    node_depth: jnp.ndarray          # [S, N] int32
    dfs_in: jnp.ndarray              # [S, N] int32
    dfs_out: jnp.ndarray             # [S, N] int32
    part_start: jnp.ndarray          # [S, N + 1] int32
    part_ids_pad: jnp.ndarray        # [S, N, maxP] int32, -1 padded
    group_root: jnp.ndarray          # [S, G] int32, pad groups → inert node
    group_default_part: jnp.ndarray  # [S, G] int32, pad = -1
    num_groups: jnp.ndarray          # [S] int32 — real centroid rows
    num_partitions: jnp.ndarray      # [S] int32 — real partition count

    @property
    def num_slots(self) -> int:
        return int(self.edge_key.shape[0])


def _inert_row(n1: int, emax: int, gmax: int, maxp: int) -> TrieDevice:
    """A whole-shard pad slot: one inert trie that plans nothing."""
    i32max = jnp.iinfo(jnp.int32).max
    return TrieDevice(
        edge_key=jnp.full((emax,), i32max, jnp.int32),
        edge_child=jnp.zeros((emax,), jnp.int32),
        has_children=jnp.zeros((n1,), bool),
        node_size=jnp.zeros((n1,), jnp.float32),
        node_depth=jnp.zeros((n1,), jnp.int32),
        dfs_in=jnp.zeros((n1,), jnp.int32),
        dfs_out=jnp.zeros((n1,), jnp.int32),
        part_start=jnp.zeros((n1 + 1,), jnp.int32),
        part_ids_pad=jnp.full((n1, maxp), -1, jnp.int32),
        group_root=jnp.full((gmax,), n1 - 1, jnp.int32),
        group_default_part=jnp.full((gmax,), -1, jnp.int32),
        num_pivots=0, num_partitions=0)


def stack_tries(tries: Sequence[TrieDevice], *,
                pad_to: Optional[int] = None) -> TrieTables:
    """Stack shard skeletons on a NEW leading shard axis (``S`` first).

    Ragged node/edge/group/partition-list counts are padded to the maxima
    with inert entries (see :func:`repro.core.traversal.pad_trie`); the node
    axis always gains one guaranteed-inert node at the top index, which pad
    groups (and whole pad shards) root at.  ``pad_to`` appends all-inert pad
    shards up to that slot count (``S % n_dev`` raggedness, exactly like
    ``pad_store`` on the stacked stores) — a pad shard's real counts are
    ``num_groups = 1`` / ``num_partitions = 0`` so a masked device planner
    emits only ``-1`` entries for it.

    Args:
      tries: per-shard device skeletons (same ``num_pivots``).
      pad_to: total slot count after padding (>= len(tries)).

    Returns:
      :class:`TrieTables` with every field stacked to ``[S_pad, ...]``.
    """
    tries = list(tries)
    if not tries:
        raise ValueError("stack_tries needs at least one trie")
    pivs = {t.num_pivots for t in tries}
    if len(pivs) != 1:
        raise ValueError(f"tries disagree on num_pivots: {sorted(pivs)}")
    s = len(tries)
    pad_to = s if pad_to is None else pad_to
    if pad_to < s:
        raise ValueError(f"pad_to={pad_to} < {s} shards")
    n1 = max(int(t.has_children.shape[0]) for t in tries) + 1
    emax = max(int(t.edge_key.shape[0]) for t in tries)
    gmax = max(int(t.group_root.shape[0]) for t in tries)
    maxp = max(int(t.part_ids_pad.shape[1]) for t in tries)
    rows = [pad_trie(t, num_nodes=n1, num_edges=emax,
                     max_parts=maxp, num_groups=gmax) for t in tries]
    rows += [_inert_row(n1, emax, gmax, maxp)] * (pad_to - s)
    stacked = [jnp.stack(x) for x in zip(*(r[:11] for r in rows))]
    g_real = np.array([int(t.group_root.shape[0]) for t in tries]
                      + [1] * (pad_to - s), np.int32)
    p_real = np.array([t.num_partitions for t in tries]
                      + [0] * (pad_to - s), np.int32)
    return TrieTables(*stacked, num_groups=jnp.asarray(g_real),
                      num_partitions=jnp.asarray(p_real))


def trie_row(tables: TrieTables, j, *, num_pivots: int,
             num_partitions: int = 0) -> TrieDevice:
    """Shard ``j``'s TrieDevice view of the stacked tables.

    Usable inside a traced program (``j`` may be a python int into local
    shard_map slices); the static int fields are re-attached from the
    caller's config, which is what keeps TrieDevice out of vmapped pytrees.
    """
    return TrieDevice(
        edge_key=tables.edge_key[j], edge_child=tables.edge_child[j],
        has_children=tables.has_children[j], node_size=tables.node_size[j],
        node_depth=tables.node_depth[j], dfs_in=tables.dfs_in[j],
        dfs_out=tables.dfs_out[j], part_start=tables.part_start[j],
        part_ids_pad=tables.part_ids_pad[j],
        group_root=tables.group_root[j],
        group_default_part=tables.group_default_part[j],
        num_pivots=num_pivots, num_partitions=num_partitions)


def descend_stacked(tables: TrieTables, p4_rank: jnp.ndarray,
                    group: jnp.ndarray, *, num_pivots: int):
    """Batched descent over the shard axis (vmapped ``descend``).

    Args:
      tables: stacked skeletons ``[S, ...]``.
      p4_rank: ``[S, ..., m]`` rank signatures (per-shard pivots differ, so
        the caller featurizes per shard).
      group: ``[S, ...]`` group ids.

    Returns:
      (node, pathlen, parent), each ``[S, ...]`` — row ``s`` identical to
      ``descend(tries[s], p4_rank[s], group[s])`` on the unstacked skeleton
      (the parity property ``tests/test_device_plan.py`` checks).
    """
    def one(tab: TrieTables, p4, grp):
        trie = TrieDevice(*tab[:11], num_pivots=num_pivots, num_partitions=0)
        return descend(trie, p4, grp)
    return jax.vmap(one)(tables, p4_rank, group)


class ShardView:
    """Duck-typed ``ClimberIndex`` stand-in for planning on device.

    The registered planners only touch ``index.cfg``, ``index.trie`` and
    ``index.centroid_onehot`` (plus ``index.store.num_partitions``, which
    the device path replaces with ``ShardPlanContext.p_static``), so a view
    of one shard's padded rows is all a device planner needs.
    """

    __slots__ = ("cfg", "centroid_onehot", "trie")

    def __init__(self, cfg, centroid_onehot: jnp.ndarray, trie: TrieDevice):
        self.cfg = cfg
        self.centroid_onehot = centroid_onehot
        self.trie = trie

    @property
    def num_groups(self) -> int:
        return int(self.centroid_onehot.shape[0])
