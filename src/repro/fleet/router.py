"""Signature-prefix query routing across index shards.

Every shard of a fleet is a full CLIMBER index with its *own* pivots, so a
query's per-shard signature is only computable by featurizing against each
shard — too expensive as a routing primitive.  The router therefore owns one
fleet-level reference pivot set and describes each shard by a **pivot
summary**: the decay-weighted frequency profile of the shard's records'
P4→ rank-signature prefixes under those reference pivots (Def. 9 weights —
the same decay the OD/WD ladder uses, so a pivot that is the nearest
neighbour of many shard records dominates the summary).

Routing scores a query's own weighted signature profile against every
summary with one ``[Q, r] @ [r, S]`` matmul and fans out to the top
``fanout`` shards per query.  Exhaustive fan-out (every shard) is the
lossless fallback — the Lernaean-Hydra lesson is that naive candidate
pruning collapses recall, so the routed mode is always an explicit,
measurable trade (``IndexFleet.audit_routing`` reports its precision
against the exhaustive oracle).

A global top-``fanout`` constant spends the same budget on every query,
which is exactly what the Hydra evaluations show collapsing recall: easy
queries waste fan-out while ambiguous ones are starved.
:meth:`SignatureRouter.route_adaptive` instead selects, per query, the
smallest score-ordered shard prefix covering a ``threshold`` fraction of
the query's total score mass — confident queries route to one shard,
ambiguous ones to many.  ``threshold → 0`` degrades to top-1 routing and
``threshold >= 1`` is exactly exhaustive fan-out; the mask grows
monotonically with the threshold in between (property-tested).  The
threshold itself can be learned from ``IndexFleet.audit_routing`` traces
via :meth:`learn_threshold` (smallest threshold whose predicted coverage
of the true answers reaches a recall target).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paa import paa
from repro.core.pivots import select_pivots
from repro.core.signatures import (decay_weights, rank_signature,
                                   weighted_onehot)
from repro.utils.config import ClimberConfig


class SignatureRouter:
    """Scores query signature profiles against per-shard pivot summaries."""

    def __init__(self, pivots: jnp.ndarray, cfg: ClimberConfig):
        self.pivots = pivots                       # [r, w] reference pivots
        self.cfg = cfg
        self._weights = decay_weights(cfg.prefix_len, cfg.decay,
                                      cfg.decay_lambda)
        self.keys: List[str] = []
        self._summaries: List[np.ndarray] = []     # each [r], L2-normalized
        self.threshold: Optional[float] = None     # learned score-mass cut

    @classmethod
    def from_sample(cls, key: jax.Array, sample: np.ndarray,
                    cfg: ClimberConfig, *,
                    pivot_method: str = "random") -> "SignatureRouter":
        """Build the reference pivots from the first data the fleet sees."""
        z = paa(jnp.asarray(sample, dtype=jnp.float32), cfg.paa_segments)
        pivots = select_pivots(key, z, cfg.num_pivots, method=pivot_method)
        return cls(pivots, cfg)

    @property
    def num_shards(self) -> int:
        return len(self._summaries)

    # -- profiles ---------------------------------------------------------
    def signature_profile(self, series: np.ndarray) -> np.ndarray:
        """``[N, r]`` decay-weighted P4→ profile under the reference pivots."""
        z = paa(jnp.asarray(series, dtype=jnp.float32),
                self.cfg.paa_segments)
        p4r = rank_signature(z, self.pivots, self.cfg.prefix_len)
        prof = weighted_onehot(p4r, self.pivots.shape[0], self._weights)
        return np.asarray(prof)

    def summarize(self, series: np.ndarray) -> np.ndarray:
        """One shard's pivot summary: its records' mean profile, normalized."""
        prof = self.signature_profile(series).sum(axis=0)
        norm = float(np.linalg.norm(prof))
        return (prof / norm if norm else prof).astype(np.float32)

    # -- shard registry (parallel to the fleet's shard list) --------------
    def register(self, key: str, summary: np.ndarray) -> None:
        self.keys.append(key)
        self._summaries.append(np.asarray(summary, dtype=np.float32))

    def replace_span(self, pos: int, count: int, key: Optional[str] = None,
                     summary: Optional[np.ndarray] = None) -> None:
        """Splice the registry: drop ``count`` entries at ``pos`` and, when
        ``key`` is given, insert its ``(key, summary)`` in their place.

        The registry must stay index-parallel to the fleet's shard list;
        this is how lifecycle maintenance (shard merge / retirement —
        ``repro.fleet.lifecycle.merge``) keeps it that way.
        """
        ins_keys = [key] if key is not None else []
        ins_sums = [np.asarray(summary, dtype=np.float32)] \
            if key is not None else []
        self.keys[pos: pos + count] = ins_keys
        self._summaries[pos: pos + count] = ins_sums

    # -- routing ----------------------------------------------------------
    def score(self, queries: np.ndarray) -> np.ndarray:
        """``[Q, S]`` affinity of each query to each registered shard."""
        if not self._summaries:
            return np.zeros((len(queries), 0), np.float32)
        prof = self.signature_profile(queries)             # [Q, r]
        return prof @ np.stack(self._summaries, axis=1)    # [Q, S]

    def route(self, queries: np.ndarray, fanout: int,
              scores: Optional[np.ndarray] = None) -> np.ndarray:
        """Boolean ``[Q, S]`` mask of the top-``fanout`` shards per query."""
        s = self.num_shards
        mask = np.zeros((len(queries), s), dtype=bool)
        if s == 0:
            return mask
        if fanout >= s:
            mask[:] = True
            return mask
        sc = self.score(queries) if scores is None else scores
        top = np.argpartition(-sc, fanout - 1, axis=-1)[:, :fanout]
        np.put_along_axis(mask, top, True, axis=-1)
        return mask

    def route_adaptive(self, queries: np.ndarray, threshold: float, *,
                       min_fanout: int = 1,
                       max_fanout: Optional[int] = None,
                       scores: Optional[np.ndarray] = None) -> np.ndarray:
        """Boolean ``[Q, S]`` mask covering ``threshold`` of the score mass.

        Shards are visited in descending score order and a query keeps
        adding shards while the mass *before* the next shard is still below
        ``threshold`` — so every query gets its best shard, a confident
        query stops there, and an ambiguous one (flat scores) fans wide.

        Contracts (property-tested):
          * ``threshold >= 1.0`` → all-True, bit-identical to exhaustive.
          * ``threshold <= 0.0`` → exactly the top-``min_fanout`` shards.
          * the mask grows monotonically with ``threshold`` and is always
            a superset of :meth:`route` at ``fanout=min_fanout``.
          * ``max_fanout`` caps the per-query row sum when given.
        """
        s = self.num_shards
        mask = np.zeros((len(queries), s), dtype=bool)
        if s == 0:
            return mask
        if threshold >= 1.0 and max_fanout is None:
            mask[:] = True                 # exhaustive short-circuit: no
            return mask                    # float cumsum at the boundary
        sc = self.score(queries) if scores is None else scores
        sc = np.asarray(sc, dtype=np.float64)
        order = np.argsort(-sc, axis=-1, kind="stable")   # ties → low index
        # strictly positive mass keeps the prefix rule meaningful even for
        # all-zero or negative score rows (degrades to uniform mass)
        mass = np.take_along_axis(sc, order, axis=-1)
        mass = np.maximum(mass - mass.min(axis=-1, keepdims=True), 0.0)
        mass = mass + 1e-9
        total = mass.sum(axis=-1, keepdims=True)
        frac_before = (np.cumsum(mass, axis=-1) - mass) / total
        rank = np.arange(s)[None, :]
        sel = (frac_before < threshold) | (rank < max(1, min_fanout))
        if max_fanout is not None:
            sel &= rank < max_fanout
        np.put_along_axis(mask, order, sel, axis=-1)
        return mask

    def learn_threshold(self, traces, target_recall: float = 0.95, *,
                        grid: Optional[np.ndarray] = None) -> float:
        """Fit the score-mass threshold from ``audit_routing`` traces.

        ``traces`` is a sequence of ``(scores, true_hits)`` pairs — per
        query, the router's ``[S]`` shard scores and the ``[S]`` count of
        exhaustive-oracle answers living in each shard.  For each candidate
        threshold the predicted recall is the fraction of true answers
        inside the shards :meth:`route_adaptive` would select; the learned
        threshold is the smallest one whose mean predicted recall reaches
        ``target_recall`` (else the largest grid point).  Stored on
        ``self.threshold`` and returned.
        """
        if grid is None:
            grid = np.linspace(0.0, 1.0, 21)
        traces = [(np.asarray(sc, np.float64), np.asarray(h, np.float64))
                  for sc, h in traces]
        traces = [(sc, h) for sc, h in traces if h.sum() > 0]
        if not traces:
            self.threshold = float(grid[-1])
            return self.threshold
        sc_all = np.stack([sc for sc, _ in traces])        # [T, S]
        hits = np.stack([h for _, h in traces])            # [T, S]
        best = float(grid[-1])
        for th in grid:
            m = self.route_adaptive(np.empty((len(sc_all), 0)), float(th),
                                    scores=sc_all)
            covered = (hits * m).sum(axis=-1) / hits.sum(axis=-1)
            if float(covered.mean()) >= target_recall:
                best = float(th)
                break
        self.threshold = best
        return best
