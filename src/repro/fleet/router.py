"""Signature-prefix query routing across index shards.

Every shard of a fleet is a full CLIMBER index with its *own* pivots, so a
query's per-shard signature is only computable by featurizing against each
shard — too expensive as a routing primitive.  The router therefore owns one
fleet-level reference pivot set and describes each shard by a **pivot
summary**: the decay-weighted frequency profile of the shard's records'
P4→ rank-signature prefixes under those reference pivots (Def. 9 weights —
the same decay the OD/WD ladder uses, so a pivot that is the nearest
neighbour of many shard records dominates the summary).

Routing scores a query's own weighted signature profile against every
summary with one ``[Q, r] @ [r, S]`` matmul and fans out to the top
``fanout`` shards per query.  Exhaustive fan-out (every shard) is the
lossless fallback — the Lernaean-Hydra lesson is that naive candidate
pruning collapses recall, so the routed mode is always an explicit,
measurable trade (``IndexFleet.audit_routing`` reports its precision
against the exhaustive oracle).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paa import paa
from repro.core.pivots import select_pivots
from repro.core.signatures import (decay_weights, rank_signature,
                                   weighted_onehot)
from repro.utils.config import ClimberConfig


class SignatureRouter:
    """Scores query signature profiles against per-shard pivot summaries."""

    def __init__(self, pivots: jnp.ndarray, cfg: ClimberConfig):
        self.pivots = pivots                       # [r, w] reference pivots
        self.cfg = cfg
        self._weights = decay_weights(cfg.prefix_len, cfg.decay,
                                      cfg.decay_lambda)
        self.keys: List[str] = []
        self._summaries: List[np.ndarray] = []     # each [r], L2-normalized

    @classmethod
    def from_sample(cls, key: jax.Array, sample: np.ndarray,
                    cfg: ClimberConfig, *,
                    pivot_method: str = "random") -> "SignatureRouter":
        """Build the reference pivots from the first data the fleet sees."""
        z = paa(jnp.asarray(sample, dtype=jnp.float32), cfg.paa_segments)
        pivots = select_pivots(key, z, cfg.num_pivots, method=pivot_method)
        return cls(pivots, cfg)

    @property
    def num_shards(self) -> int:
        return len(self._summaries)

    # -- profiles ---------------------------------------------------------
    def signature_profile(self, series: np.ndarray) -> np.ndarray:
        """``[N, r]`` decay-weighted P4→ profile under the reference pivots."""
        z = paa(jnp.asarray(series, dtype=jnp.float32),
                self.cfg.paa_segments)
        p4r = rank_signature(z, self.pivots, self.cfg.prefix_len)
        prof = weighted_onehot(p4r, self.pivots.shape[0], self._weights)
        return np.asarray(prof)

    def summarize(self, series: np.ndarray) -> np.ndarray:
        """One shard's pivot summary: its records' mean profile, normalized."""
        prof = self.signature_profile(series).sum(axis=0)
        norm = float(np.linalg.norm(prof))
        return (prof / norm if norm else prof).astype(np.float32)

    # -- shard registry (parallel to the fleet's shard list) --------------
    def register(self, key: str, summary: np.ndarray) -> None:
        self.keys.append(key)
        self._summaries.append(np.asarray(summary, dtype=np.float32))

    def replace_span(self, pos: int, count: int, key: Optional[str] = None,
                     summary: Optional[np.ndarray] = None) -> None:
        """Splice the registry: drop ``count`` entries at ``pos`` and, when
        ``key`` is given, insert its ``(key, summary)`` in their place.

        The registry must stay index-parallel to the fleet's shard list;
        this is how lifecycle maintenance (shard merge / retirement —
        ``repro.fleet.lifecycle.merge``) keeps it that way.
        """
        ins_keys = [key] if key is not None else []
        ins_sums = [np.asarray(summary, dtype=np.float32)] \
            if key is not None else []
        self.keys[pos: pos + count] = ins_keys
        self._summaries[pos: pos + count] = ins_sums

    # -- routing ----------------------------------------------------------
    def score(self, queries: np.ndarray) -> np.ndarray:
        """``[Q, S]`` affinity of each query to each registered shard."""
        if not self._summaries:
            return np.zeros((len(queries), 0), np.float32)
        prof = self.signature_profile(queries)             # [Q, r]
        return prof @ np.stack(self._summaries, axis=1)    # [Q, S]

    def route(self, queries: np.ndarray, fanout: int,
              scores: Optional[np.ndarray] = None) -> np.ndarray:
        """Boolean ``[Q, S]`` mask of the top-``fanout`` shards per query."""
        s = self.num_shards
        mask = np.zeros((len(queries), s), dtype=bool)
        if s == 0:
            return mask
        if fanout >= s:
            mask[:] = True
            return mask
        sc = self.score(queries) if scores is None else scores
        top = np.argpartition(-sc, fanout - 1, axis=-1)[:, :fanout]
        np.put_along_axis(mask, top, True, axis=-1)
        return mask
