"""Parameter-spec system: one source of truth for shapes, init and sharding.

Every model builder returns a pytree of :class:`ParamInfo` leaves.  From that
single tree we derive
  * randomly initialised parameters (smoke tests / real training),
  * abstract ``ShapeDtypeStruct`` parameters (dry-run lowering — no memory),
  * ``PartitionSpec`` trees via the logical-axis rules (MaxText-style).

Logical axes used across the zoo:
  embed   — d_model rows/cols         → FSDP-sharded over the data axis
  vocab   — embedding/output vocab    → model axis
  heads   — attention heads           → model axis
  kv_heads— KV heads                  → model axis iff divisible, else replicated
  ff      — MLP hidden                → model axis
  experts — MoE expert dim            → replicated (experts are TP-sharded on ff)
  layers  — scan dimension            → replicated
  (None)  — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]    # one logical axis name per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def init_params(tree, key: jax.Array):
    """Materialise random parameters from a ParamInfo tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_info)
    keys = jax.random.split(key, len(leaves))

    def one(info: ParamInfo, k):
        if info.init == "zeros":
            return jnp.zeros(info.shape, info.dtype)
        if info.init == "ones":
            return jnp.ones(info.shape, info.dtype)
        return (jax.random.normal(k, info.shape, jnp.float32)
                * info.scale).astype(info.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(i, k) for i, k in zip(leaves, keys)])


def abstract_params(tree):
    """ShapeDtypeStruct tree for .lower() — never allocates."""
    return jax.tree_util.tree_map(
        lambda i: jax.ShapeDtypeStruct(i.shape, i.dtype), tree,
        is_leaf=_is_info)


# logical axis name → mesh axis (or None).  The data axis doubles as the
# FSDP axis (weights sharded over it, gathered per layer inside scan).
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",       # dropped at spec time if not divisible
    "ff": "model",
    "experts": None,
    "layers": None,
    "state": None,
    "hd": None,
    "conv": None,
    "lora": None,
    "groups": None,
}


def param_pspecs(tree, mesh_axis_sizes: Dict[str, int],
                 rules: Optional[Dict[str, Optional[str]]] = None):
    """PartitionSpec tree; silently replicates axes that don't divide."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def one(info: ParamInfo):
        spec = []
        for dim, name in zip(info.shape, info.logical):
            axis = rules.get(name) if name else None
            if axis is not None and axis in mesh_axis_sizes \
                    and dim % mesh_axis_sizes[axis] == 0:
                spec.append(axis)
            else:
                spec.append(None)
        return PS(*spec)

    return jax.tree_util.tree_map(one, tree, is_leaf=_is_info)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_info)
    return int(sum(np.prod(l.shape) if _is_info(l) else l.size
                   for l in leaves))
