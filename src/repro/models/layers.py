"""Core layers shared by every assigned architecture.

Everything is a pure function over explicit parameter dicts (no framework
modules), so graphs stay small under scan-over-layers and sharding is fully
controlled by the caller.  Attention is a chunked, online-softmax ("flash")
formulation in pure JAX — at 32k prefill a materialised score matrix would be
tens of GB per device, so the chunked path is the only runnable one; XLA maps
each chunk's matmuls onto the MXU.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamInfo
from repro.utils.config import ModelConfig

NEG_INF = -2.0e38


# ----------------------------------------------------------------------
# normalisation + positional encoding
# ----------------------------------------------------------------------
def rmsnorm_info(d: int) -> ParamInfo:
    return ParamInfo((d,), ("embed",), init="ones")


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [S] or [B, S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # [hd/2]
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
        angles = angles[None, :, None, :]                  # [1, S, 1, hd/2]
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs
        angles = angles[:, :, None, :]                     # [B, S, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# chunked online-softmax attention
# ----------------------------------------------------------------------
# Set True (via set_inner_unroll) for dry-run *cost* compiles: inner KV/SSD
# chunk scans fully unroll so XLA cost analysis counts every chunk (while
# bodies are otherwise counted once).  The full-config memory-proof compiles
# keep the rolled loops.
INNER_SCAN_UNROLL = False

# §Perf knobs (set by the perf harness before lowering):
#  FLASH_BF16        — keep flash-attention operands in bf16 (f32 accumulation
#                      via preferred_element_type); halves score-side HBM and
#                      resharding traffic vs the all-f32 baseline.
#  CACHE_UPDATE_MASKED — decode-cache write via one-hot select instead of
#                      dynamic-update-slice: a DUS on a sequence-sharded cache
#                      makes GSPMD replicate the whole cache ("involuntary
#                      full rematerialization"); the masked form is purely
#                      elementwise and stays sharded.
FLASH_BF16 = False
CACHE_UPDATE_MASKED = False

#  DECODE_SHARD — (mesh, batch_axes) or None.  When set, decode attention
#  over a sequence-sharded KV cache runs as explicit flash-decoding under
#  shard_map: local partial softmax per seq shard + pmax/psum combine
#  (~0.2 MB collectives/layer) instead of GSPMD's full-cache all-gather
#  (~1 GB/layer measured on starcoder2 decode_32k).
DECODE_SHARD = None


def set_inner_unroll(flag: bool) -> None:
    global INNER_SCAN_UNROLL
    INNER_SCAN_UNROLL = bool(flag)


def set_flash_bf16(flag: bool) -> None:
    global FLASH_BF16
    FLASH_BF16 = bool(flag)


def set_cache_update_masked(flag: bool) -> None:
    global CACHE_UPDATE_MASKED
    CACHE_UPDATE_MASKED = bool(flag)


def set_decode_shard(mesh, batch_axes=("data",)) -> None:
    global DECODE_SHARD
    DECODE_SHARD = (mesh, tuple(batch_axes)) if mesh is not None else None


def _flash_decode_sharded(q: jnp.ndarray, cache_k: jnp.ndarray,
                          cache_v: jnp.ndarray, valid: jnp.ndarray
                          ) -> jnp.ndarray:
    """Explicit flash-decoding over a seq-sharded cache (see DECODE_SHARD).

    q: [B, 1, H, hd]; cache_k/v: [B, S, KV, hd] (S sharded over `model`);
    valid: [B, S].  Returns [B, 1, H, hd].
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    mesh, ba = DECODE_SHARD
    b, _, h, hd = q.shape
    kv = cache_k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    bspec = ba if b % int(np.prod([mesh.shape[a] for a in ba])) == 0 else None

    def local(qf, k_l, v_l, valid_l):
        # grouped-query einsum: NO materialised KV expansion — inside
        # shard_map the [KV, G] split is local, so the repeat() that the
        # GSPMD path needed (32 GB/device of expanded f32 K/V on starcoder2
        # decode) is unnecessary.  K/V stay bf16; scores accumulate in f32.
        bq = qf.shape[0]
        q_g = (qf.astype(jnp.float32) * scale).reshape(bq, 1, kv, g, hd)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q_g,
                       k_l.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        s = jnp.where(valid_l[:, None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_g[..., None])
        denom = jax.lax.psum(jnp.sum(p, axis=-1), "model")
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, v_l.astype(jnp.float32))
        acc = jax.lax.psum(pv, "model")
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return out.reshape(bq, 1, h, hd).astype(qf.dtype)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(PS(bspec, None, None, None),      # q replicated over model
                  PS(bspec, "model", None, None),   # cache: seq-sharded
                  PS(bspec, "model", None, None),
                  PS(bspec, "model")),
        out_specs=PS(bspec, None, None, None),
        check_rep=False)
    return fn(q, cache_k, cache_v, valid)


def _cache_write(cache: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Write one token at ``pos`` along axis 1 of a [B, S, ...] cache."""
    if not CACHE_UPDATE_MASKED:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), pos, axis=1)
    s_max = cache.shape[1]
    onehot = (jnp.arange(s_max) == pos).reshape(
        (1, s_max) + (1,) * (cache.ndim - 2))
    return jnp.where(onehot, new.astype(cache.dtype), cache)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, q_offset: int = 0,
                    kv_chunk: int = 2048,
                    kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Online-softmax attention, scanned over KV chunks.

    q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd] (grouped-query: H = KV * G).
    KV heads are expanded to H *per chunk inside the scan body* — the
    transient is one chunk, and the einsum operands keep a clean
    heads-sharded layout under GSPMD (no [KV, G] split dims to re-shard).
    q_offset: absolute position of q[0] (causal masking in decode/chunked
    prefill).  kv_valid: [B, Skv] bool cache-validity mask.
    Returns [B, Sq, H, hd] in q.dtype; softmax in fp32.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    nchunks = max(skv // kv_chunk, 1)
    chunk = skv // nchunks
    assert skv % nchunks == 0, (skv, nchunks)

    op_dtype = jnp.bfloat16 if FLASH_BF16 else jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(op_dtype)  # [B, Sq, H, hd]
    q_pos = q_offset + jnp.arange(sq)

    def expand(t):
        return jnp.repeat(t, g, axis=2) if g > 1 else t

    def step(acc, m, denom, k_c, v_c, kpos_c, valid_c):
        k_e = expand(k_c).astype(op_dtype)                 # [B, c, H, hd]
        v_e = expand(v_c).astype(op_dtype)
        s = jnp.einsum("bqhd,bchd->bqhc", qf, k_e,
                       preferred_element_type=jnp.float32)
        mask = valid_c[:, None, None, :]
        if causal:
            cm = q_pos[:, None] >= kpos_c[None, :]         # [Sq, chunk]
            mask = mask & cm[None, :, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhc,bchd->bqhd", p.astype(op_dtype), v_e,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return acc, m_new, denom

    if kv_valid is None:
        kv_valid = jnp.ones((b, skv), bool)

    hd_v = v.shape[-1]                        # MLA: v head dim != qk head dim
    acc0 = jnp.zeros((b, sq, h, hd_v), jnp.float32)
    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    d0 = jnp.zeros((b, sq, h), jnp.float32)

    if nchunks == 1:
        acc, m, denom = step(acc0, m0, d0, k, v, jnp.arange(skv), kv_valid)
    else:
        k_r = k.reshape(b, nchunks, chunk, kv, k.shape[-1]).transpose(1, 0, 2, 3, 4)
        v_r = v.reshape(b, nchunks, chunk, kv, hd_v).transpose(1, 0, 2, 3, 4)
        kpos = jnp.arange(skv).reshape(nchunks, chunk)
        valid_r = kv_valid.reshape(b, nchunks, chunk).transpose(1, 0, 2)

        def body(carry, inputs):
            return step(*carry, *inputs), None

        # checkpoint the chunk body: score matrices are NEVER saved for the
        # backward pass (flash-attention backward recomputes them).  Without
        # this, a `dots` remat policy would stash every [Sq, chunk] score
        # tile and blow HBM at 32k sequence lengths.
        (acc, m, denom), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, d0),
                                          (k_r, v_r, kpos, valid_r),
                                          unroll=INNER_SCAN_UNROLL or 1)
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# grouped-query attention (GQA / MQA / MHA)
# ----------------------------------------------------------------------
def gqa_infos(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamInfo((d, h, hd), ("embed", "heads", "hd")),
        "wk": ParamInfo((d, kv, hd), ("embed", "kv_heads", "hd")),
        "wv": ParamInfo((d, kv, hd), ("embed", "kv_heads", "hd")),
        "wo": ParamInfo((h, hd, d), ("heads", "hd", "embed")),
    }


def gqa_project_kv(p, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    return k, v


def gqa_attention(p, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool = True,
                  positions: Optional[jnp.ndarray] = None,
                  kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                  kv_valid: Optional[jnp.ndarray] = None,
                  q_offset: int = 0, kv_chunk: int = 2048) -> jnp.ndarray:
    """Full-sequence GQA (train / prefill / encoder / cross-attention).

    kv_override: use externally produced (k, v) — cross-attention or cache.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dqh->bsqh", x, p["wq"])
    if kv_override is None:
        k, v = gqa_project_kv(p, x)
    else:
        k, v = kv_override
    if positions is None:
        positions = jnp.arange(s)
    if cfg.use_rope and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.use_rope:
        q = apply_rope(q, q_offset + jnp.arange(s), cfg.rope_theta)
    out = flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                          kv_chunk=kv_chunk, kv_valid=kv_valid)
    return jnp.einsum("bsqh,qhd->bsd", out, p["wo"])


def gqa_prefill(p, x: jnp.ndarray, cfg: ModelConfig, *,
                kv_chunk: int = 2048):
    """Causal attention over the prompt, returning (out, k, v) for caching.

    The returned k is post-RoPE — exactly what ``gqa_decode`` appends to.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dqh->bsqh", x, p["wq"])
    k, v = gqa_project_kv(p, x)
    if cfg.use_rope:
        positions = jnp.arange(s)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
    return jnp.einsum("bsqh,qhd->bsd", out, p["wo"]), k, v


def gqa_decode(p, x: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
               cache_len: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, ...]:
    """One-token decode against a [B, S_max, KV, hd] cache.

    Returns (out, new_k, new_v): caches updated at position cache_len.
    """
    b, one, _ = x.shape
    q = jnp.einsum("bsd,dqh->bsqh", x, p["wq"])
    k_new = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v_new = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.use_rope:
        pos = jnp.full((1,), cache_len, dtype=jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    cache_k = _cache_write(cache_k, k_new, cache_len)
    cache_v = _cache_write(cache_v, v_new, cache_len)
    s_max = cache_k.shape[1]
    valid = (jnp.arange(s_max) <= cache_len)[None, :] \
        * jnp.ones((b, 1), bool)
    if DECODE_SHARD is not None \
            and s_max % DECODE_SHARD[0].shape["model"] == 0:
        out = _flash_decode_sharded(q, cache_k, cache_v, valid)
    else:
        # single chunk — scores [B,1,H,S]; NOTE (measured): GSPMD gathers
        # the full seq-sharded cache here; prefer DECODE_SHARD on a mesh.
        out = flash_attention(q, cache_k, cache_v, causal=False,
                              kv_valid=valid, kv_chunk=s_max)
    out = jnp.einsum("bsqh,qhd->bsd", out, p["wo"])
    return out, cache_k, cache_v


# ----------------------------------------------------------------------
# multi-head latent attention (MLA — minicpm3 / deepseek-v2 style)
# ----------------------------------------------------------------------
def mla_infos(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d, h = cfg.d_model, cfg.num_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "q_down": ParamInfo((d, ql), ("embed", "lora")),
        "q_up": ParamInfo((ql, h, dn + dr), ("lora", "heads", "hd")),
        "kv_down": ParamInfo((d, kl + dr), ("embed", "lora")),
        "kv_up": ParamInfo((kl, h, dn + dv), ("lora", "heads", "hd")),
        "wo": ParamInfo((h, dv, d), ("heads", "hd", "embed")),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    """Project to per-head q/k/v from the compressed latents."""
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dl,lqh->bsqh", x, p["q_down"], p["q_up"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dl->bsl", x, p["kv_down"])       # [B,S,kl+dr]
    c, k_rope = ckv[..., :kl], ckv[..., kl:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    kv = jnp.einsum("bsl,lqh->bsqh", c, p["kv_up"])
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_rope_b = jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (dr,))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return q_full, k_full, v, ckv


def mla_attention(p, x: jnp.ndarray, cfg: ModelConfig, *,
                  q_offset: int = 0, kv_chunk: int = 2048) -> jnp.ndarray:
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v, _ = _mla_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, causal=True, q_offset=q_offset,
                          kv_chunk=kv_chunk)
    return jnp.einsum("bsqh,qhd->bsd", out, p["wo"])


def mla_prefill(p, x: jnp.ndarray, cfg: ModelConfig, *, kv_chunk: int = 2048):
    """MLA prefill returning (out, ckv_store [B, S, kl+dr]).

    The stored latent is [compressed c, post-RoPE k_rope] — the exact layout
    ``mla_decode`` appends to and re-expands.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v, ckv = _mla_qkv(p, x, cfg, positions)
    kl = cfg.kv_lora_rank
    c, k_rope_raw = ckv[..., :kl], ckv[..., kl:]
    k_roped = apply_rope(k_rope_raw[:, :, None, :], positions,
                         cfg.rope_theta)[:, :, 0, :]
    ckv_store = jnp.concatenate([c, k_roped], axis=-1)
    out = flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
    return jnp.einsum("bsqh,qhd->bsd", out, p["wo"]), ckv_store


def mla_decode(p, x: jnp.ndarray, cache_ckv: jnp.ndarray,
               cache_len: jnp.ndarray, cfg: ModelConfig):
    """MLA decode with the *compressed* cache [B, S_max, kl + dr].

    The latent cache is MLA's point: per token only kl+dr floats are stored;
    k/v are re-expanded per step through kv_up (a matmul against the cache).
    """
    b = x.shape[0]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    pos = jnp.full((1,), cache_len, dtype=jnp.int32)
    q = jnp.einsum("bsd,dl,lqh->bsqh", x, p["q_down"], p["q_up"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv_new = jnp.einsum("bsd,dl->bsl", x, p["kv_down"])
    c_new, kr_new = ckv_new[..., :kl], ckv_new[..., kl:]
    kr_new = apply_rope(kr_new[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    ckv_store = jnp.concatenate([c_new, kr_new], axis=-1)
    cache_ckv = _cache_write(cache_ckv, ckv_store, cache_len)

    c_all = cache_ckv[..., :kl]
    kr_all = cache_ckv[..., kl:]
    kv = jnp.einsum("bsl,lqh->bsqh", c_all, p["kv_up"])
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  k_nope.shape[:-1] + (dr,))], axis=-1)
    s_max = cache_ckv.shape[1]
    valid = (jnp.arange(s_max) <= cache_len)[None, :] * jnp.ones((b, 1), bool)
    # single-KV-group layout for flash_attention: [B, S, H, hd] per head
    out = flash_attention(q_full, k_full, v, causal=False, kv_valid=valid,
                          kv_chunk=s_max)
    out = jnp.einsum("bsqh,qhd->bsd", out, p["wo"])
    return out, cache_ckv


# ----------------------------------------------------------------------
# MLPs + embedding
# ----------------------------------------------------------------------
def swiglu_infos(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamInfo]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": ParamInfo((d, f), ("embed", "ff")),
        "w_up": ParamInfo((d, f), ("embed", "ff")),
        "w_down": ParamInfo((f, d), ("ff", "embed")),
    }


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])


def embedding_infos(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    return {
        "tok": ParamInfo((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                         scale=1.0 / (cfg.d_model ** 0.5)),
        "out": ParamInfo((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
        "final_norm": rmsnorm_info(cfg.d_model),
    }


def embed(p, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["tok"][tokens]


def unembed(p, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(x, p["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, p["out"])
