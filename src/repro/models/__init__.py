from repro.models.model import Model, cross_entropy
from repro.models.decoding import (cache_shapes, decode_step, init_cache,
                                   prefill)
from repro.models.params import (ParamInfo, abstract_params, count_params,
                                 init_params, param_pspecs)

__all__ = ["Model", "cross_entropy", "cache_shapes", "decode_step",
           "init_cache", "prefill", "ParamInfo", "abstract_params",
           "count_params", "init_params", "param_pspecs"]
