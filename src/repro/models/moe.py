"""Mixture-of-Experts block (olmoe 64e top-8; qwen2-moe 60e top-4 + shared).

Dispatch design (TPU-native, recorded in DESIGN.md):
  * top-k routing with softmax gates, normalised over the selected experts;
  * capacity-based dispatch (GShard/Switch style): tokens are sorted by
    expert id *locally per data shard* and gathered into a dense
    ``[E, C, D]`` block, so the expert computation is one batched MXU einsum
    — no [T, E, C] one-hot dispatch tensor, no ragged ops;
  * expert weights are **tensor-parallel over the ff dim** (each model-axis
    shard holds F/model columns of every expert).  That keeps the MoE layer's
    collective cost identical to a dense MLP (one reduce over `model`) and
    avoids the all-to-all of expert-parallel placement — the trade-off is
    analysed in EXPERIMENTS.md §Perf.  Tokens over capacity are dropped
    (standard dropping-MoE semantics; capacity_factor configures slack).

The local math (`moe_local`) is pure and shard-free; `moe_apply` wraps it in
shard_map when a mesh is given so the sort/gather stay device-local.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamInfo
from repro.utils.config import ModelConfig


def moe_infos(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    infos = {
        "router": ParamInfo((d, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": ParamInfo((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamInfo((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamInfo((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.num_shared_experts:
        fs = cfg.shared_expert_d_ff
        infos.update({
            "s_gate": ParamInfo((d, fs), ("embed", "ff")),
            "s_up": ParamInfo((d, fs), ("embed", "ff")),
            "s_down": ParamInfo((fs, d), ("ff", "embed")),
        })
    return infos


def _capacity(tokens: int, k: int, e: int, cf: float) -> int:
    return int(min(tokens, max(math.ceil(tokens * k / e * cf), 8)))


def moe_local(p, x: jnp.ndarray, cfg: ModelConfig,
              capacity_factor: float = 1.25) -> jnp.ndarray:
    """Routed experts over local tokens.  x: [T, D] → [T, D] (partial over
    the ff shard when weights are column-sharded; caller reduces)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = _capacity(t, k, e, capacity_factor)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                       # [T, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # sort the (token, expert) pairs by expert id; position within an expert
    # group = slot; beyond capacity → dropped (scatter mode='drop').
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(t * k, dtype=jnp.int32) - start[se].astype(jnp.int32)
    keep = pos < c
    se_s = jnp.where(keep, se, e)                                # OOB → drop

    slot_tok = jnp.full((e, c), t, dtype=jnp.int32)              # t = pad row
    slot_tok = slot_tok.at[se_s, pos].set(st, mode="drop")
    slot_w = jnp.zeros((e, c), x.dtype).at[se_s, pos].set(sw, mode="drop")

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[slot_tok]                                         # [E, C, D]
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    y = y * slot_w[..., None]

    out = jnp.zeros((t + 1, d), y.dtype)
    out = out.at[slot_tok.reshape(-1)].add(y.reshape(-1, d))[:t]

    if cfg.num_shared_experts:
        g = jnp.einsum("td,df->tf", x, p["s_gate"])
        uu = jnp.einsum("td,df->tf", x, p["s_up"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * uu, p["s_down"])
    return out.astype(x.dtype)


def moe_apply(p, x: jnp.ndarray, cfg: ModelConfig, *,
              mesh=None, batch_axes=("data",), model_axis: str = "model",
              capacity_factor: float = 1.25) -> jnp.ndarray:
    """MoE over x: [B, S, D].  With a mesh: shard_map so the per-shard sort
    and gather never cross devices; ff-sharded experts psum over `model`."""
    b, s, d = x.shape
    if mesh is None:
        return moe_local(p, x.reshape(-1, d), cfg,
                         capacity_factor).reshape(b, s, d)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    def local_fn(p_l, x_l):
        bl, sl, _ = x_l.shape
        y = moe_local(p_l, x_l.reshape(-1, d), cfg, capacity_factor)
        y = jax.lax.psum(y, model_axis)
        return y.reshape(bl, sl, d)

    p_specs = {
        "router": PS(),                               # replicated (fp32)
        "w_gate": PS(None, None, model_axis),
        "w_up": PS(None, None, model_axis),
        "w_down": PS(None, model_axis, None),
    }
    if cfg.num_shared_experts:
        p_specs.update({"s_gate": PS(None, model_axis),
                        "s_up": PS(None, model_axis),
                        "s_down": PS(model_axis, None)})
    x_spec = PS(batch_axes, None, None)
    fn = shard_map(local_fn, mesh=mesh, in_specs=(p_specs, x_spec),
                   out_specs=x_spec, check_rep=False)
    return fn(p, x)
